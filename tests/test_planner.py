"""Planner subsystem (repro.planner): cache bucketing + LRU + persistence,
fingerprint isomorphism, calibration round-trip, skew-aware selection, and
the PlannerService facade the launch hot paths use."""
import math

import pytest

from repro.core import cost_model as cm, plans as plans_mod
from repro.core.sync import plan_axes_gentree
from repro.core.topology import TopoNode, single_switch, symmetric_tree
from repro.planner.cache import PlanCache, plan_from_json, plan_to_json
from repro.planner.calibrate import CalibrationConfig, calibrate_levels
from repro.planner.fingerprint import (axis_key, fingerprint_params,
                                       fingerprint_topo, plan_key)
from repro.planner.service import PlannerService
from repro.planner.skew import (SkewModel, arrival_gated_time, draw_offsets,
                                expected_time, pick_plan_under_skew)


# ---------------------------------------------------------------------------
# Cache: geometric size buckets
# ---------------------------------------------------------------------------
class TestBuckets:
    def test_base_is_its_own_bucket(self):
        c = PlanCache(bucket_base=4096, bucket_growth=2.0)
        assert c.bucket(4096) == 4096
        assert c.bucket(1) == 4096
        assert c.bucket(0) == 4096

    def test_boundary_rolls_to_next_bucket(self):
        c = PlanCache(bucket_base=4096, bucket_growth=2.0)
        assert c.bucket(4097) == 8192
        assert c.bucket(8192) == 8192
        assert c.bucket(8193) == 16384

    def test_idempotent_and_monotonic(self):
        c = PlanCache(bucket_base=4096, bucket_growth=2.0)
        prev = 0
        for nbytes in (1, 4096, 5000, 1 << 20, 1 << 26, 3.7e9):
            b = c.bucket(nbytes)
            assert b >= nbytes
            assert c.bucket(b) == b, "bucket must be a fixed point"
            assert b >= prev
            prev = b

    def test_sizes_inside_one_bucket_share_it(self):
        c = PlanCache(bucket_base=4096, bucket_growth=2.0)
        assert c.bucket(9000) == c.bucket(16384) == 16384

    def test_non_integer_growth(self):
        c = PlanCache(bucket_base=1000, bucket_growth=1.5)
        b = c.bucket(1001)
        assert b == 1500
        assert c.bucket(1500) == 1500
        assert c.bucket(1501) == 2250

    def test_bad_config_rejected(self):
        with pytest.raises(ValueError):
            PlanCache(capacity=0)
        with pytest.raises(ValueError):
            PlanCache(bucket_growth=1.0)


# ---------------------------------------------------------------------------
# Cache: LRU + stats
# ---------------------------------------------------------------------------
class TestCacheLRU:
    def test_miss_then_hit(self):
        c = PlanCache(capacity=4)
        assert c.get("k") is None
        c.put("k", {"v": 1})
        assert c.get("k") == {"v": 1}
        assert c.stats.misses == 1 and c.stats.hits == 1
        assert c.stats.hit_rate == 0.5

    def test_lru_eviction_order(self):
        c = PlanCache(capacity=2)
        c.put("a", {"v": 1})
        c.put("b", {"v": 2})
        assert c.get("a")          # refresh a; b is now LRU
        c.put("c", {"v": 3})       # evicts b
        assert "a" in c and "c" in c and "b" not in c
        assert c.stats.evictions == 1
        assert len(c) == 2

    def test_put_updates_existing_without_eviction(self):
        c = PlanCache(capacity=2)
        c.put("a", {"v": 1})
        c.put("a", {"v": 2})
        assert len(c) == 1 and c.stats.evictions == 0
        assert c.get("a") == {"v": 2}


# ---------------------------------------------------------------------------
# Cache: disk persistence
# ---------------------------------------------------------------------------
class TestPersistence:
    def test_plan_json_round_trip(self):
        plan = plans_mod.hcps([2, 3], 600.0)
        d = plan_to_json(plan)
        back = plan_from_json(d)
        assert back.name == plan.name and back.n == plan.n
        assert len(back.steps) == len(plan.steps)
        for a, b in zip(back.steps, plan.steps):
            assert a.transfers == b.transfers
            assert a.reduces == b.reduces

    def test_save_load_round_trip(self, tmp_path):
        path = str(tmp_path / "plans.json")
        c = PlanCache(capacity=8)
        c.put("k1", {"algo": "cps", "_obj": object()})   # _obj not persisted
        c.put("k2", {"algo": "ring"})
        c.save(path)

        c2 = PlanCache(capacity=8, path=path)
        assert c2.stats.disk_loads == 2
        assert c2.get("k1") == {"algo": "cps"}
        assert c2.get("k2") == {"algo": "ring"}

    def test_load_missing_or_corrupt_is_empty(self, tmp_path):
        c = PlanCache(capacity=4)
        assert c.load(str(tmp_path / "nope.json")) == 0
        bad = tmp_path / "bad.json"
        bad.write_text("{not json")
        assert c.load(str(bad)) == 0
        assert len(c) == 0

    def test_no_path_configured_raises(self):
        with pytest.raises(ValueError):
            PlanCache().save()


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------
def _tree(perm: bool, names: str) -> TopoNode:
    """Two middle switches (3 + 2 servers); `perm` flips child order."""
    root = TopoNode(name=f"{names}root", level="root_sw")
    a = TopoNode(name=f"{names}a", uplink_bw=1e10, uplink_latency=1e-6,
                 level="middle_sw")
    a.children = [TopoNode(name=f"{names}a{i}", uplink_bw=1e9,
                           uplink_latency=5e-6) for i in range(3)]
    b = TopoNode(name=f"{names}b", uplink_bw=1e10, uplink_latency=1e-6,
                 level="middle_sw")
    b.children = [TopoNode(name=f"{names}b{i}", uplink_bw=1e9,
                           uplink_latency=5e-6) for i in range(2)]
    root.children = [b, a] if perm else [a, b]
    return root.finalize()


class TestFingerprint:
    def test_isomorphic_trees_share_fingerprint(self):
        # Different names AND different child order: same canonical form.
        assert fingerprint_topo(_tree(False, "x")) == \
            fingerprint_topo(_tree(True, "zzz"))

    def test_structure_changes_fingerprint(self):
        t1 = _tree(False, "x")
        t2 = _tree(False, "x")
        t2.children[0].children[0].uplink_bw *= 2       # one faster NIC
        assert fingerprint_topo(t1) != fingerprint_topo(t2)
        t3 = single_switch(5)
        assert fingerprint_topo(t1) != fingerprint_topo(t3)

    def test_params_fingerprint(self):
        assert fingerprint_params(cm.PAPER_TABLE5) == \
            fingerprint_params(dict(cm.PAPER_TABLE5))
        assert fingerprint_params(cm.PAPER_TABLE5) != \
            fingerprint_params(cm.TPU_V5E)
        assert fingerprint_params(None) == fingerprint_params({})

    def test_plan_key_sensitivity(self):
        t = single_switch(4)
        k = plan_key(t, cm.PAPER_TABLE5, 4096)
        assert k == plan_key(t, cm.PAPER_TABLE5, 4096)
        assert k != plan_key(t, cm.PAPER_TABLE5, 8192)
        assert k != plan_key(t, cm.TPU_V5E, 4096)
        assert k != plan_key(t, cm.PAPER_TABLE5, 4096, dtype="bfloat16")

    def test_axis_key_sensitivity(self):
        k = axis_key([("data", 8)], cm.PAPER_TABLE5, 4096)
        assert k == axis_key([("data", 8)], cm.PAPER_TABLE5, 4096)
        assert k != axis_key([("data", 16)], cm.PAPER_TABLE5, 4096)
        assert k != axis_key([("pod", 8)], cm.PAPER_TABLE5, 4096)


# ---------------------------------------------------------------------------
# Calibration round-trip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["closed_form", "simulator"])
def test_calibration_recovers_injected_params(backend):
    cfg = CalibrationConfig(backend=backend)
    res = calibrate_levels(cm.PAPER_TABLE5, cfg)
    assert res.backend == backend
    assert set(res.params) == set(cfg.levels)
    for level in cfg.levels:
        src = cm.PAPER_TABLE5[level]
        fit = res.params[level]
        for f in ("alpha", "delta", "epsilon"):
            true = getattr(src, f)
            got = getattr(fit, f)
            assert got == pytest.approx(true, rel=0.05, abs=1e-14), \
                f"{level}.{f}: {got} vs {true}"
        assert fit.w_t == src.w_t
        # Only 2β+γ is identifiable from the CPS curve; the Fig.-4 bench
        # pins γ, so the combination must round-trip even if the split
        # differs slightly.
        assert 2 * fit.beta + fit.gamma == pytest.approx(
            2 * src.beta + src.gamma, rel=0.05, abs=1e-14)
        samples = res.samples[level]
        assert len(samples.times) == len(cfg.ns) * len(cfg.sizes)
        assert samples.as_dict()["level"] == level


def test_service_calibrate_swaps_pricing_basis():
    svc = PlannerService()
    assert svc.stats()["calibrated"] is False
    res = svc.calibrate(cfg=CalibrationConfig(backend="closed_form"))
    assert svc.stats()["calibrated"] is True
    assert svc.params == res.params
    # New params → new fingerprints: a lookup after calibration is a miss,
    # not a stale hit priced under the old params.
    topo = single_switch(4)
    svc.get_plan(topo, 1 << 16)
    assert svc.get_plan(topo, 1 << 16).source == "memory"
    before = svc.cache.stats.misses
    svc.calibrate(cm.TPU_V5E, cfg=CalibrationConfig(backend="closed_form"))
    svc.get_plan(topo, 1 << 16)
    assert svc.cache.stats.misses == before + 1


# ---------------------------------------------------------------------------
# Skew-aware selection
# ---------------------------------------------------------------------------
class TestSkewValidation:
    def test_unknown_dist_fails_at_construction(self):
        # eager: never deep inside the pricing draw loop
        with pytest.raises(ValueError, match="unknown skew dist"):
            SkewModel(dist="zipf")

    def test_empirical_without_offsets_fails_at_construction(self):
        with pytest.raises(ValueError, match="empirical"):
            SkewModel(dist="empirical")

    def test_from_offsets_normalizes_and_gates_scale(self):
        m = SkewModel.from_offsets([2.0, 2.1, 2.5])
        assert m.dist == "empirical"
        assert min(m.offsets) == 0.0                 # earliest → 0
        assert m.scale == pytest.approx(0.5)         # worst offset
        assert m.key() != SkewModel(scale=0.5).key()  # offsets in the key
        assert m.key() != SkewModel.from_offsets([2.0, 2.1, 2.6]).key()

    def test_empirical_draws_come_from_measured_pool(self):
        import numpy as np
        m = SkewModel.from_offsets([0.0, 0.25, 0.5], draws=6, seed=1)
        offs = draw_offsets(m, 8)
        assert offs.shape == (6, 8)
        assert set(np.unique(offs)) <= {0.0, 0.25, 0.5}
        # deterministic under the fixed seed
        assert (offs == draw_offsets(m, 8)).all()

    def test_empirical_skew_changes_the_winner(self):
        # mirror of test_high_imbalance_changes_the_winner below, with the
        # offsets *measured* instead of drawn: under synchronized starts
        # ring's cheap rounds beat CPS's double incast on the paper ToR;
        # under a measured heavy-tail arrival pattern the incast fades and
        # CPS's few rounds win — empirical mode must re-rank exactly like
        # the synthetic distributions do.
        n, s = 15, 1.8e8
        params = {"middle_sw": cm.PAPER_TABLE5["middle_sw"],
                  "server": cm.PAPER_TABLE5["server"]}
        topo = single_switch(n)
        cands = [("ring", plans_mod.ring(n, s)), ("cps", plans_mod.cps(n, s))]
        sync_winner, _, _ = pick_plan_under_skew(
            cands, topo, SkewModel(scale=0.0), params)
        measured = SkewModel.from_offsets(
            [0.0] * 10 + [0.05, 0.1, 0.1, 0.2, 0.3], draws=8, seed=0)
        emp_winner, _, cost = pick_plan_under_skew(
            cands, topo, measured, params)
        assert sync_winner == "ring"
        assert emp_winner == "cps"
        assert cost > 0


class TestSkew:
    def test_offsets_deterministic_and_gated_on_scale(self):
        m = SkewModel(scale=0.1, draws=4, seed=3)
        a = draw_offsets(m, 8)
        b = draw_offsets(m, 8)
        assert (a == b).all() and a.shape == (4, 8) and (a >= 0).all()
        z = draw_offsets(SkewModel(scale=0.0), 8)
        assert z.shape == (1, 8) and not z.any()

    def test_zero_skew_matches_synchronized_pricing(self):
        topo = single_switch(8)
        plan = plans_mod.cps(8, 1e6)
        m = SkewModel(scale=0.0)
        assert expected_time(plan, topo, m) == pytest.approx(
            arrival_gated_time(plan, topo, offsets=None))

    def test_late_arrival_lower_bounds_completion(self):
        topo = single_switch(8)
        plan = plans_mod.ring(8, 1e6)
        base = arrival_gated_time(plan, topo, offsets=[0.0] * 8)
        late = arrival_gated_time(plan, topo, offsets=[0.0] * 7 + [0.5])
        assert late >= base + 0.5 * 0.99  # straggler's data gates the result

    def test_high_imbalance_changes_the_winner(self):
        # n=15 on the paper's ToR: CPS pays full incast twice when starts
        # are synchronized (w = n > w_t in both all-to-all steps), so
        # ring's 2(n-1) cheap rounds win. Under heavy arrival skew the
        # scatter-step incast fades (flows no longer land together) while
        # ring still pays all 28 α rounds — the winner flips to CPS.
        n, s = 15, 1.8e8
        params = {"middle_sw": cm.PAPER_TABLE5["middle_sw"],
                  "server": cm.PAPER_TABLE5["server"]}
        topo = single_switch(n)
        cands = [("ring", plans_mod.ring(n, s)), ("cps", plans_mod.cps(n, s))]
        sync_winner, _, _ = pick_plan_under_skew(
            cands, topo, SkewModel(scale=0.0), params)
        skew_winner, _, cost = pick_plan_under_skew(
            cands, topo, SkewModel(scale=0.1, draws=8, seed=0), params)
        assert sync_winner == "ring"
        assert skew_winner == "cps"
        assert cost > 0

    def test_service_reranks_under_skew(self):
        topo = single_switch(15)
        svc = PlannerService(skew=SkewModel(scale=0.1, draws=4, seed=0))
        r = svc.get_plan(topo, 1 << 22)
        assert r.expected_skewed_time is not None
        assert r.algo in ("gentree", "cps", "ring", "rhd")
        # skew config is part of the cache key
        r2 = svc.get_plan(topo, 1 << 22)
        assert r2.source == "memory" and r2.algo == r.algo
        svc_nosk = PlannerService(cache=svc.cache)
        r3 = svc_nosk.get_plan(topo, 1 << 22)
        assert r3.source == "cold" and r3.expected_skewed_time is None


# ---------------------------------------------------------------------------
# PlannerService facade
# ---------------------------------------------------------------------------
class TestService:
    def test_cold_then_memory_hit(self):
        svc = PlannerService()
        topo = symmetric_tree(2, 4)
        r1 = svc.get_plan(topo, 1 << 20)
        r2 = svc.get_plan(topo, 1 << 20)
        assert r1.source == "cold" and r2.source == "memory"
        assert r2.plan is r1.plan                 # no re-parse on warm hit
        assert r1.predicted_time > 0 and r1.algo == "gentree"
        assert r1.decisions                       # per-switch decisions kept

    def test_same_bucket_shares_entry(self):
        svc = PlannerService()
        topo = symmetric_tree(2, 4)
        r1 = svc.get_plan(topo, 1 << 20)
        r2 = svc.get_plan(topo, (1 << 20) - 1000)  # same geometric bucket
        assert r2.source == "memory"
        assert r2.nbytes_bucket == r1.nbytes_bucket

    def test_isomorphic_topologies_share_entry(self):
        svc = PlannerService()
        svc.get_plan(_tree(False, "x"), 1 << 18)
        r = svc.get_plan(_tree(True, "renamed"), 1 << 18)
        assert r.source == "memory"

    def test_disk_warm_restart(self, tmp_path):
        path = str(tmp_path / "plans.json")
        topo = symmetric_tree(2, 4)
        svc = PlannerService(cache_path=path)
        svc.get_plan(topo, 1 << 20)
        svc.save()

        svc2 = PlannerService(cache_path=path)   # "restarted" process
        r = svc2.get_plan(topo, 1 << 20)
        assert r.source == "disk"                # deserialized, not re-planned
        assert svc2.get_plan(topo, 1 << 20).source == "memory"

    def test_get_axis_plans_cached_and_correct(self):
        svc = PlannerService()
        axes = [("data", 8), ("pod", 2)]
        p1 = svc.get_axis_plans(axes, 1e6)
        p2 = svc.get_axis_plans(axes, 1e6)
        assert p1 == p2
        assert svc.cache.stats.hits >= 1
        # service result matches the uncached gentree-per-axis planner at
        # the bucketed size
        bucket = svc.cache.bucket(1e6 * 4)
        direct = plan_axes_gentree(axes, bucket / 4.0, None)
        assert p1 == direct

    def test_stats_shape(self):
        svc = PlannerService()
        svc.get_plan(single_switch(4), 4096)
        st = svc.stats()
        assert {"hits", "misses", "hit_rate"} <= set(st["cache"])
        assert st["entries"] == 1


# ---------------------------------------------------------------------------
# Executable plans (get_executable) + axis-plan config threading
# ---------------------------------------------------------------------------
class TestExecutable:
    def test_get_executable_caches_schedule_on_entry(self):
        svc = PlannerService()
        topo = symmetric_tree(2, 4)
        r1 = svc.get_executable(topo, 1 << 20)
        r2 = svc.get_executable(topo, 1 << 20)
        assert r1.schedule is not None
        assert r2.schedule is r1.schedule      # lowered once per entry
        assert r2.source == "memory"

    def test_get_executable_disk_warm_relowers(self, tmp_path):
        import numpy as np
        path = str(tmp_path / "plans.json")
        topo = symmetric_tree(2, 4)
        svc = PlannerService(cache_path=path)
        svc.get_executable(topo, 1 << 20)
        svc.save()
        svc2 = PlannerService(cache_path=path)
        r = svc2.get_executable(topo, 1 << 20)
        assert r.source == "disk"              # plan came from disk...
        assert r.schedule is not None          # ...schedule re-lowered
        X = np.random.default_rng(0).normal(size=(8, 24))
        assert np.allclose(r.schedule.run_numpy(X),
                           np.tile(X.sum(0), (8, 1)))

    def test_get_axis_executable_identity_placement(self):
        import numpy as np
        svc = PlannerService()
        r = svc.get_axis_executable("data", 6, 1e5)
        assert r.schedule.n == 6
        X = np.random.default_rng(1).normal(size=(6, 17))
        assert np.allclose(r.schedule.run_numpy(X),
                           np.tile(X.sum(0), (6, 1)))

    def test_axis_plans_honour_gentree_kwargs(self):
        """Satellite fix: a candidate-restricted service must not fall
        back to default candidates for cold axis pricing."""
        svc = PlannerService(gentree_kwargs={"candidates": ("ring",)})
        out = svc.get_axis_plans([("data", 8)], 1e6)
        assert [p.strategy for p in out] == ["ring"]
        # warm hit returns the same restricted answer
        assert [p.strategy for p in svc.get_axis_plans(
            [("data", 8)], 1e6)] == ["ring"]

    def test_axis_plans_engine_threads_and_keys_separate(self):
        """engine="reference"/"fast" reach plan_axes_gentree (gentree-based
        axis pricing) and differently-configured services never share an
        axis cache entry."""
        shared = PlanCache(capacity=16)
        s_default = PlannerService(cache=shared)
        s_ring = PlannerService(cache=shared,
                                gentree_kwargs={"candidates": ("ring",)})
        s_ref = PlannerService(cache=shared, engine="reference")
        s_fast = PlannerService(cache=shared, engine="fast")
        d = s_default.get_axis_plans([("data", 8)], 1e6)
        r = s_ring.get_axis_plans([("data", 8)], 1e6)
        assert [p.strategy for p in r] == ["ring"]
        assert [p.strategy for p in d] != ["ring"]   # no key collision
        # both engines run the real gentree search and agree on the winner
        assert (s_ref.get_axis_plans([("data", 8)], 1e6)
                == s_fast.get_axis_plans([("data", 8)], 1e6))

    def test_plan_axes_gentree_explicit_kwargs(self):
        out = plan_axes_gentree([("data", 12)], 1e6,
                                gentree_kwargs={"candidates": ("cps",)})
        assert [p.strategy for p in out] == ["cps"]

    def test_annotated_plan_survives_json_round_trip(self):
        from repro.core import plans as plans_mod2
        from repro.core.lower import lower_plan
        p = plans_mod2.ring(4, 16.0)
        q = plan_from_json(plan_to_json(p))
        assert q.num_blocks == p.num_blocks
        assert q.steps[0].transfers[0].blocks == \
            p.steps[0].transfers[0].blocks
        lower_plan(q)          # still executable after the round-trip

    def test_legacy_json_rows_load_unannotated(self):
        d = {"name": "old", "n": 2, "size": 2.0, "servers": None,
             "steps": [{"transfers": [[0, 1, 1.0]],
                        "reduces": [[1, 2, 1.0]]}]}
        q = plan_from_json(d)
        assert q.num_blocks is None
        assert q.steps[0].transfers[0].blocks is None

    def test_axis_executable_level_and_params_reach_pricing(self):
        """strategy="plan" pricing must see the axis's Table-5 level class
        and any SyncConfig.params override — not a fixed default switch."""
        from repro.core.cost_model import PAPER_TABLE5
        svc = PlannerService()
        r_ici = svc.get_axis_executable("pod", 2, 1e6, level="root_sw")
        r_dci = svc.get_axis_executable("pod", 2, 1e6, level="cross_dc")
        assert r_dci.key != r_ici.key
        assert r_dci.predicted_time != r_ici.predicted_time
        r_ovr = svc.get_axis_executable("pod", 2, 1e6, level="root_sw",
                                        params=PAPER_TABLE5)
        assert r_ovr.key != r_ici.key
        assert r_ovr.schedule is not None

    def test_axis_plans_carry_predicted_cost(self):
        svc = PlannerService()
        plans = svc.get_axis_plans([("data", 8), ("pod", 2)], 1e6)
        assert all(p.predicted is not None and p.predicted > 0
                   for p in plans)

    def test_legacy_axis_plan_rows_load_without_predicted(self):
        svc = PlannerService()
        axes = [("data", 8)]
        svc.get_axis_plans(axes, 1e6)
        # simulate a pre-telemetry snapshot: 3-element rows, no _obj
        for entry in svc.cache._entries.values():
            if "axis_plans" in entry:
                entry["axis_plans"] = [row[:3]
                                       for row in entry["axis_plans"]]
                entry.pop("_obj", None)
        plans = svc.get_axis_plans(axes, 1e6)
        assert plans and plans[0].predicted is None

    def test_plan_strategy_levels_match_gentree_indexing(self):
        """resolve_axis_plans(strategy="plan") must price each axis at the
        same Table-5 level as plan_axes_gentree: size-1 axes are skipped
        but still occupy their mesh level position."""
        from repro.core.sync import SyncConfig, resolve_axis_plans
        from repro.planner.service import (PlannerService,
                                           set_default_service)
        svc = PlannerService()
        set_default_service(svc)
        try:
            pl = resolve_axis_plans([("data", 1), ("pod", 4)],
                                    SyncConfig(strategy="plan"), 1e6)
            assert [p.axis for p in pl] == ["pod"]
            assert pl[0].schedule is not None and pl[0].schedule.n == 4
            # the entry resolve created is the CROSS_DC-priced one
            # (original axis index 1), so the same request warm-hits...
            r = svc.get_axis_executable("pod", 4, 1e6, level="cross_dc")
            assert r.source == "memory"
            # resolve wraps the executed schedule in the launch guard
            # (DESIGN.md §12); the UNDERLYING schedule must be the same
            # cached object the service hands out
            from repro.core.lower import GuardedSchedule
            assert isinstance(pl[0].schedule, GuardedSchedule)
            assert r.schedule is pl[0].schedule.inner
            # ...while root_sw pricing would be a different (cold) entry
            r2 = svc.get_axis_executable("pod", 4, 1e6, level="root_sw")
            assert r2.key != r.key
        finally:
            set_default_service(None)


# ---------------------------------------------------------------------------
# Measurement providers (offline + online behind ONE interface)
# ---------------------------------------------------------------------------
class TestMeasurementProviders:
    def test_provider_for_maps_backends(self):
        from repro.planner.calibrate import (ClosedFormProvider,
                                             LaxProvider, SimulatorProvider,
                                             provider_for)
        assert isinstance(provider_for(CalibrationConfig(
            backend="simulator")), SimulatorProvider)
        assert isinstance(provider_for(CalibrationConfig(
            backend="closed_form")), ClosedFormProvider)
        assert isinstance(provider_for(CalibrationConfig(
            backend="lax")), LaxProvider)
        with pytest.raises(ValueError, match="unknown backend"):
            provider_for(CalibrationConfig(backend="nope"))

    def test_custom_provider_reaches_the_same_fit(self):
        """calibrate_levels(provider=...) must flow through the identical
        least-squares path the backend lookup does."""
        from repro.planner.calibrate import ClosedFormProvider
        cfg = CalibrationConfig(backend="closed_form")
        via_backend = calibrate_levels(cm.PAPER_TABLE5, cfg)
        via_provider = calibrate_levels(cm.PAPER_TABLE5, cfg,
                                        provider=ClosedFormProvider())
        assert via_provider.params == via_backend.params
        assert via_provider.backend == "closed_form"

    def test_telemetry_provider_needs_samples(self):
        from repro.planner.calibrate import TelemetryProvider
        from repro.runtime.telemetry import Telemetry
        prov = TelemetryProvider(Telemetry(), min_samples=4)
        with pytest.raises(ValueError, match="telemetry has 0 samples"):
            prov.cps_curve("root_sw", cm.PAPER_TABLE5["root_sw"],
                           CalibrationConfig())

    def test_telemetry_provider_replays_samples_and_pins_w_t(self):
        from repro.planner.calibrate import TelemetryProvider
        from repro.runtime.telemetry import LevelSample, Telemetry
        tele = Telemetry()
        src = cm.PAPER_TABLE5["root_sw"]
        for n in (4, 8):
            for s in (1e6, 4e6):
                tele.record_sample("root_sw", LevelSample(
                    n, s, cm.cost_cps(n, s, src), cm.cost_cps(n, s, src)))
        prov = TelemetryProvider(tele, min_samples=4)
        ns, sizes, times = prov.cps_curve("root_sw", src,
                                          CalibrationConfig())
        assert len(ns) == 4 and times[0] == pytest.approx(
            cm.cost_cps(4, 1e6, src))
        assert prov.pin_w_t("root_sw", src) == src.w_t

    def test_online_refit_through_same_path_recovers_params(self):
        """CPS-equivalent telemetry of the TRUE closed form, fit online
        with the current (wrong) params as carry-over source: 2β+γ and α
        must recover to the truth through the shared fitting path."""
        import dataclasses as _dc

        from repro.planner.calibrate import TelemetryProvider
        from repro.runtime.telemetry import LevelSample, Telemetry
        true = cm.PAPER_TABLE5["root_sw"]
        wrong = _dc.replace(true, beta=true.beta * 5, alpha=true.alpha * 2)
        tele = Telemetry()
        for n in (4, 8, 12):
            for s in (1e6, 4e6, 1.6e7):
                t = cm.cost_cps(n, s, true)
                tele.record_sample("root_sw", LevelSample(n, s, t, t))
        res = calibrate_levels(
            {"root_sw": wrong, "server": cm.PAPER_TABLE5["server"]},
            CalibrationConfig(levels=("root_sw",)),
            provider=TelemetryProvider(tele, min_samples=4))
        fit = res.params["root_sw"]
        assert res.backend == "telemetry"
        assert fit.alpha == pytest.approx(true.alpha, rel=0.05)
        assert 2 * fit.beta + fit.gamma == pytest.approx(
            2 * true.beta + true.gamma, rel=0.05)
        assert fit.w_t == wrong.w_t          # pinned, not grid-searched


# ---------------------------------------------------------------------------
# Cache stats persistence (lifetime hit rates survive restarts)
# ---------------------------------------------------------------------------
class TestStatsPersistence:
    def test_stats_block_round_trips(self, tmp_path):
        path = str(tmp_path / "plans.json")
        c = PlanCache(capacity=8)
        c.put("k1", {"algo": "cps"})
        c.get("k1")
        c.get("missing")
        c.save(path)

        c2 = PlanCache(capacity=8, path=path)
        # persisted lifetime counters restored, THEN this load's disk
        # hits accumulate on top
        assert c2.stats.hits == 1 and c2.stats.misses == 1
        assert c2.stats.puts == 1
        assert c2.stats.disk_loads == 1
        c2.get("k1")
        assert c2.stats.hits == 2            # true lifetime hit count

    def test_stats_accumulate_across_generations(self, tmp_path):
        path = str(tmp_path / "plans.json")
        c = PlanCache(capacity=8)
        c.put("a", {"v": 1})
        c.get("a")
        c.save(path)
        c2 = PlanCache(capacity=8, path=path)
        c2.put("b", {"v": 2})
        c2.get("b")
        c2.save(path)
        c3 = PlanCache(capacity=8, path=path)
        assert c3.stats.puts == 2
        assert c3.stats.hits == 2
        # generation 2 loaded 1 entry from disk, generation 3 loaded 2
        assert c3.stats.disk_loads == 3

    def test_legacy_snapshot_without_stats_loads_clean(self, tmp_path):
        import json
        path = tmp_path / "old.json"
        path.write_text(json.dumps(
            {"version": 1, "entries": {"k": {"algo": "ring"}}}))
        c = PlanCache(capacity=4)
        assert c.load(str(path)) == 1
        assert c.stats.disk_loads == 1 and c.stats.hits == 0


# ---------------------------------------------------------------------------
# The observe half of the closed loop (service-level unit tests; the
# end-to-end refit→invalidate→replan scenario lives in test_substrate.py)
# ---------------------------------------------------------------------------
class TestObserve:
    def test_observe_records_residuals_and_samples(self):
        from repro.planner.service import RefitPolicy
        svc = PlannerService(refit_policy=RefitPolicy(enabled=False))
        r = svc.get_axis_executable("data", 8, 1e6)
        out = svc.observe("root_sw", 8, 1e6, r.predicted_time * 1.5,
                          predicted=r.predicted_time, key=r.key)
        assert out["rel_residual"] == pytest.approx(0.5)
        assert out["samples"] == 1 and out["refit"] is False
        assert svc.telemetry.residuals("level/root_sw").count == 1
        assert svc.telemetry.residuals(f"plan/{r.key}").count == 1

    def test_observe_default_predicted_prices_the_axis(self):
        svc = PlannerService()
        # bucket-aligned size: the executable's cache-bucketed price and
        # observe's exact-size price coincide
        size = float(1 << 20)
        r = svc.get_axis_executable("data", 8, size)
        out = svc.observe("root_sw", 8, size, r.predicted_time)
        # service's own price at the exact size ≈ the executable's price
        assert out["predicted"] == pytest.approx(r.predicted_time,
                                                 rel=0.05)
        assert abs(out["rel_residual"]) < 0.05

    def test_params_override_is_excluded_from_refit_feed(self):
        from repro.planner.service import RefitPolicy
        svc = PlannerService(refit_policy=RefitPolicy(
            min_samples=1, drift_threshold=0.01))
        out = svc.observe("root_sw", 8, 1e6, 10.0, predicted=1.0,
                          params=cm.TPU_V5E)
        assert out["refit"] is False and out["samples"] == 0
        assert svc.telemetry.samples("root_sw") == []
        # override residuals stay OUT of the level tracker that steers
        # the refit trigger — they land in a monitoring-only key
        assert svc.telemetry.residuals("level/root_sw").count == 0
        assert svc.telemetry.residuals("level/root_sw@override").count == 1
        assert svc.telemetry.ring("observe/root_sw").count == 1

    def test_policy_disabled_never_refits(self):
        from repro.planner.service import RefitPolicy
        svc = PlannerService(refit_policy=RefitPolicy(
            enabled=False, min_samples=1, drift_threshold=0.01))
        for _ in range(6):
            out = svc.observe("root_sw", 8, 1e6, 10.0, predicted=1.0)
        assert out["refit"] is False and len(svc.refits) == 0

    def test_adopt_empirical_skew_swaps_model_and_keys(self):
        svc = PlannerService()
        assert svc.adopt_empirical_skew() is None   # no offsets yet
        topo = single_switch(8)
        r_before = svc.get_plan(topo, 1 << 20)
        for _ in range(3):
            svc.observe_arrivals([0.0, 0.01, 0.05, 0.0, 0.0, 0.2,
                                  0.0, 0.02])
        model = svc.adopt_empirical_skew()
        assert model is not None and model.dist == "empirical"
        assert svc.skew is model
        # skew key is part of the fingerprint: old entry unreachable
        r_after = svc.get_plan(topo, 1 << 20)
        assert r_after.key != r_before.key
        assert r_after.source == "cold"
        assert r_after.expected_skewed_time is not None
