"""Quantized wire kernels (DESIGN.md §13): per-block fp8/int8
quantize/dequantize with per-tile f32 scales, and the fused compressed
N-ary reduce — interpret-mode Pallas vs the pure-jnp oracle, round-trip
error against the Precision error budgets, and the shared lane-padding
helper the kernels inherit from fused_reduce."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, strategies as st

from repro.core.cost_model import PRECISIONS
from repro.kernels import ref
from repro.kernels.fused_reduce import fused_reduce, pad_lanes
from repro.kernels.quant import (QUANT_TILE, WIRE_QMAX, dequantize,
                                 quant_reduce, quant_reduce_requant,
                                 quantize, wire_dtype)

WIRES = ["float8_e4m3fn", "int8"]
# wire → the Precision whose error_budget governs it
BUDGET = {"float8_e4m3fn": PRECISIONS["fp8"].error_budget,
          "int8": PRECISIONS["int8"].error_budget}


def _rt_relerr(x, wire, tile=QUANT_TILE):
    q, s = quantize(x, wire, tile=tile, interpret=True)
    back = dequantize(q, s, tile=tile, out_len=x.shape[-1], interpret=True)
    x = np.asarray(x)
    denom = max(float(np.max(np.abs(x))), 1e-30)
    return float(np.max(np.abs(np.asarray(back) - x))) / denom


# ---------------------------------------------------------------------------
# round-trip error bounds per wire dtype
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("wire", WIRES)
@pytest.mark.parametrize("W,L", [(1, 128), (4, 4096), (8, 1000), (3, 257)])
def test_roundtrip_within_budget(wire, W, L):
    x = jax.random.normal(jax.random.PRNGKey(W * L), (W, L), jnp.float32)
    assert _rt_relerr(x, wire) < BUDGET[wire]


@pytest.mark.parametrize("wire", WIRES)
def test_roundtrip_scale_outliers(wire):
    """Per-tile scales localize outliers: a 1e4 spike in one tile must
    not wreck the quantization of the others."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 512), jnp.float32)
    x = x.at[0, 5].set(1e4)
    q, s = quantize(x, wire, interpret=True)
    back = np.asarray(dequantize(q, s, out_len=512, interpret=True))
    ref_x = np.asarray(x)
    other = np.abs(back[:, 128:] - ref_x[:, 128:])
    scale = np.max(np.abs(ref_x[:, 128:]))
    assert np.max(other) / scale < BUDGET[wire]


@pytest.mark.parametrize("wire", WIRES)
def test_zero_input_exact(wire):
    """amax == 0 tiles carry scale 0 (not NaN/Inf) and decode to 0."""
    x = jnp.zeros((3, 256), jnp.float32)
    q, s = quantize(x, wire, interpret=True)
    assert np.all(np.asarray(s) == 0.0)
    back = dequantize(q, s, out_len=256, interpret=True)
    assert np.all(np.asarray(back) == 0.0)


# ---------------------------------------------------------------------------
# interpret-mode Pallas ≡ pure-jnp oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("wire", WIRES)
@pytest.mark.parametrize("W,L,tile", [(2, 256, 128), (5, 1000, 128),
                                      (8, 384, 64), (3, 130, 128)])
def test_quantize_matches_ref(wire, W, L, tile):
    x = jax.random.normal(jax.random.PRNGKey(L), (W, L), jnp.float32)
    q, s = quantize(x, wire, tile=tile, interpret=True)
    qr, sr = ref.quantize_ref(x, wire, tile)
    np.testing.assert_array_equal(np.asarray(q, np.float32),
                                  np.asarray(qr, np.float32))
    # payloads are bit-exact; the scale division may fold to a
    # reciprocal multiply under interpret-mode jit (±1 ulp)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=2e-7)


@pytest.mark.parametrize("wire", WIRES)
@pytest.mark.parametrize("own", [False, True])
def test_quant_reduce_matches_ref(wire, own):
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 700), jnp.float32)
    q, s = quantize(x, wire, interpret=True)
    o = (jax.random.normal(jax.random.PRNGKey(4), (700,), jnp.float32)
         if own else None)
    got = quant_reduce(q, s, o, out_len=700, interpret=True)
    want = ref.quant_reduce_ref(q, s, o, out_len=700)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# compressed fused reduce vs f32 reference, within the wire budget
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("wire", WIRES)
@pytest.mark.parametrize("x,L", [(2, 128), (8, 4096), (16, 257), (5, 1000)])
def test_quant_reduce_vs_f32_reference(wire, x, L):
    parts = jax.random.normal(jax.random.PRNGKey(x + L), (x, L),
                              jnp.float32)
    q, s = quantize(parts, wire, interpret=True)
    got = np.asarray(quant_reduce(q, s, out_len=L, interpret=True))
    want = np.asarray(fused_reduce(parts, interpret=True))
    denom = max(float(np.max(np.abs(want))), 1e-30)
    # the reduce accumulates in f32, so per-element error stays at the
    # round-trip level; x quantized operands compound by at most ~x·ulp,
    # still far inside the per-wire budget for these fan-ins
    assert float(np.max(np.abs(got - want))) / denom < BUDGET[wire]


@pytest.mark.parametrize("wire", WIRES)
def test_quant_reduce_requant_roundtrip(wire):
    """Reduce-and-requantize (the RS hop output stays on the wire):
    decode of the requantized sum ≈ the f32 fused sum."""
    parts = jax.random.normal(jax.random.PRNGKey(11), (4, 500),
                              jnp.float32)
    q, s = quantize(parts, wire, interpret=True)
    qo, so = quant_reduce_requant(q, s, wire, interpret=True)
    assert qo.dtype == wire_dtype(wire)
    back = np.asarray(dequantize(qo[None], so[None], out_len=500,
                                 interpret=True))[0]
    want = np.asarray(parts).sum(0)
    denom = max(float(np.max(np.abs(want))), 1e-30)
    assert float(np.max(np.abs(back - want))) / denom < 2 * BUDGET[wire]


# ---------------------------------------------------------------------------
# hypothesis sweep over (x, L, tile) including non-aligned L
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(x=st.integers(2, 8), L=st.integers(1, 700),
       tile=st.sampled_from([64, 128]), wire=st.sampled_from(WIRES))
def test_quant_property(x, L, tile, wire):
    parts = jax.random.normal(jax.random.PRNGKey(x * 701 + L), (x, L),
                              jnp.float32)
    q, s = quantize(parts, wire, tile=tile, interpret=True)
    # padded lanes are whole tiles; scales cover the padded width
    assert q.shape[1] % tile == 0
    assert s.shape == (x, q.shape[1] // tile)
    got = np.asarray(quant_reduce(q, s, tile=tile, out_len=L,
                                  interpret=True))
    assert got.shape == (L,)
    want = np.asarray(parts).sum(0)
    denom = max(float(np.max(np.abs(want))), 1e-30)
    assert float(np.max(np.abs(got - want))) / denom < BUDGET[wire]
    # and the oracle agrees bit-for-bit
    np.testing.assert_array_equal(
        got, np.asarray(ref.quant_reduce_ref(q, s, None, tile, L)))


# ---------------------------------------------------------------------------
# shared pad helper (the fused_reduce recursive-pad fix rides this PR)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("L,mult", [(1, 128), (127, 128), (128, 128),
                                    (129, 128), (1000, 128), (60, 64)])
def test_pad_lanes(L, mult):
    x = jnp.arange(2 * L, dtype=jnp.float32).reshape(2, L)
    out = pad_lanes(x, mult)
    assert out.shape[-1] % mult == 0 and out.shape[-1] >= L
    np.testing.assert_array_equal(np.asarray(out[:, :L]), np.asarray(x))
    assert np.all(np.asarray(out[:, L:]) == 0.0)


def test_fused_reduce_nonaligned_single_pad():
    """Regression for the recursive pad path: a non-tile-multiple L pads
    once and slices the output — same values as the aligned oracle."""
    parts = jax.random.normal(jax.random.PRNGKey(5), (7, 333), jnp.float32)
    got = fused_reduce(parts, interpret=True)
    assert got.shape == (333,)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(parts).sum(0),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ops.py dispatch + wire validation
# ---------------------------------------------------------------------------
def test_ops_dispatch_ref_cpu():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(6), (3, 300), jnp.float32)
    q, s = ops.quantize(x, "int8")
    qr, sr = ref.quantize_ref(x, "int8")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    got = ops.quant_reduce(q, s, out_len=300)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.quant_reduce_ref(
                                      q, s, None, 128, 300)))


def test_unknown_wire_rejected():
    with pytest.raises((KeyError, ValueError)):
        wire_dtype("float16")
    assert set(WIRE_QMAX) == set(WIRES)
    assert QUANT_TILE == PRECISIONS["fp8"].scale_block
