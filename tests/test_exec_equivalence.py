"""Executed-schedule ≡ lax.psum equivalence on a real multi-device mesh.

The acceptance bar for the executable Plan IR (DESIGN.md §8): every
compiled schedule — lowered flat builders AND lowered GenTree plans for
Table-6-style multi-level topologies — must produce results equal to
`lax.psum` within dtype tolerance when executed under shard_map on 8 host
CPU devices, across sizes, dtypes and axis sizes (including
non-powers-of-two); and `SyncConfig(strategy="plan")` must train a model
through launch.train on the executed plans, tracking the psum-sync loss
exactly.

Like test_collectives.py, one subprocess (XLA_FLAGS device-count=8) runs
every case; when hypothesis is installed the subprocess additionally runs
a randomized sweep (sizes × dtypes × axis sizes × topologies) and reports
any counterexample.
"""
import json
import os
import subprocess
import sys

import pytest

_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map
from repro.core import plans, topology
from repro.core.gentree import gentree
from repro.core.lower import lower_plan

results = {}


def run_sched(cs, n, size, dtype, seed=0):
    mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, size),
                          jnp.float32).astype(dtype)
    f = shard_map(lambda v: cs.allreduce(v[0], "x")[None],
                  mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    p = shard_map(lambda v: jax.lax.psum(v[0], "x")[None],
                  mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    got = np.asarray(jax.jit(f)(x)).astype(np.float64)
    want = np.asarray(jax.jit(p)(x)).astype(np.float64)
    scale = np.abs(want).max() + 1e-30
    return float(np.abs(got - want).max() / scale)


# ---- acceptance case: two-level Table-6-style topology, float32 @ 1e-6 ----
topo = topology.symmetric_tree(2, 4)     # 2 middle switches x 4 servers
r = gentree(topo, 1e6)
cs = lower_plan(r.plan)
results["table6_two_level_err"] = run_sched(cs, 8, 1000, jnp.float32)
results["table6_two_level"] = results["table6_two_level_err"] < 1e-6

# ---- lowered plans x sizes x dtypes ---------------------------------------
CASES = {
    "gentree_ss8": gentree(topology.single_switch(8), 1e6).plan,
    "gentree_cdc8": gentree(topology.cross_dc(
        dc0_middle=2, dc0_servers=2, dc1_middle=2, dc1_servers=2),
        1e6).plan,
    "ring": plans.ring(8, 80.0),
    "cps": plans.cps(8, 80.0),
    "rhd": plans.rhd(8, 80.0),
    "hcps4x2": plans.hcps([4, 2], 80.0),
    "reduce_broadcast": plans.reduce_broadcast(8, 80.0),
}
for name, plan in CASES.items():
    cs = lower_plan(plan)
    errs = []
    for size in (1, 8, 41, 1000):
        errs.append(run_sched(cs, 8, size, jnp.float32, seed=size))
    results[f"{name}_f32"] = max(errs) < 1e-6
    results[f"{name}_bf16"] = run_sched(cs, 8, 128, jnp.bfloat16) < 0.05

# ---- non-power-of-two axis sizes ------------------------------------------
for n in (3, 5, 6, 7):
    plan = gentree(topology.single_switch(n), 1e5).plan
    cs = lower_plan(plan)
    results[f"gentree_n{n}"] = run_sched(cs, n, 37, jnp.float32) < 1e-6
    cs_rhd = lower_plan(plans.rhd(n, float(n * 8)))
    results[f"rhd_n{n}"] = run_sched(cs_rhd, n, 37, jnp.float32) < 1e-6

# ---- RS/AG halves compose to the psum result ------------------------------
mesh = jax.make_mesh((8,), ("x",))
x = jax.random.normal(jax.random.PRNGKey(3), (8, 41))
cs = lower_plan(gentree(topology.symmetric_tree(2, 4), 1e6).plan)
g = shard_map(lambda v: cs.reduce_scatter(v[0], "x")[None],
              mesh=mesh, in_specs=P("x"), out_specs=P("x"))
shards = np.asarray(jax.jit(g)(x))
pad = (-41) % 8
want = np.concatenate([np.asarray(x.sum(0)), np.zeros(pad, np.float32)])
results["rs_half"] = bool(np.allclose(shards.reshape(-1), want, atol=1e-5))
h = shard_map(lambda v: cs.all_gather(v[0], "x")[None],
              mesh=mesh, in_specs=P("x"), out_specs=P("x"))
full = np.asarray(jax.jit(h)(jnp.asarray(shards)))
results["ag_half"] = bool(np.allclose(full, np.tile(want, (8, 1)),
                                      atol=1e-5))

# ---- sync_gradients + allreduce_planned execute plans ---------------------
from repro.core.sync import SyncConfig, sync_gradients
from repro.core import collectives as C
grads = {"a": jnp.ones((8, 100)), "b": jnp.full((8, 7), 2.0)}
f = shard_map(
    lambda g: {k: v[None] for k, v in sync_gradients(
        {k: v[0] for k, v in g.items()}, [("x", 8)],
        SyncConfig(strategy="plan")).items()},
    mesh=mesh, in_specs=P("x"), out_specs=P("x"))
out = f(grads)
results["sync_plan"] = bool(
    np.allclose(np.asarray(out["a"])[0], 8.0)
    and np.allclose(np.asarray(out["b"])[0], 16.0))
f = shard_map(lambda v: C.allreduce_planned(v[0], "x")[None],
              mesh=mesh, in_specs=P("x"), out_specs=P("x"))
xa = jnp.arange(8 * 33, dtype=jnp.float32).reshape(8, 33)
results["allreduce_planned"] = bool(np.allclose(
    np.asarray(f(xa)), np.tile(np.asarray(xa.sum(0)), (8, 1)), rtol=1e-5))

# ---- multi-axis (pod x data) strategy="plan" ------------------------------
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
z = jnp.arange(8 * 24, dtype=jnp.float32).reshape(2, 4, 24)
f = shard_map(
    lambda v: {"g": sync_gradients({"g": v[0, 0]}, [("data", 4), ("pod", 2)],
                                   SyncConfig(strategy="plan"))["g"][
        None, None]},
    mesh=mesh2, in_specs=P("pod", "data"), out_specs=P("pod", "data"))
out2 = np.asarray(f(z)["g"]).reshape(8, 24)
results["sync_plan_two_axis"] = bool(np.allclose(
    out2, np.tile(z.reshape(8, 24).sum(0), (8, 1)), rtol=1e-5))

# ---- training through launch.train with sync="plan" -----------------------
from repro.launch.train import TrainConfig, run_training
logs = []
out_plan = run_training(TrainConfig(
    arch="stablelm-12b", steps=2, engine="manual", sync="plan",
    seq_len=16, global_batch=8, log_every=10), smoke=True,
    on_log=logs.append)
out_psum = run_training(TrainConfig(
    arch="stablelm-12b", steps=2, engine="manual", sync="psum",
    seq_len=16, global_batch=8, log_every=10), smoke=True,
    on_log=logs.append)
dl = max(abs(a - b) for a, b in zip(out_plan["losses"],
                                    out_psum["losses"]))
results["train_plan_finite"] = bool(
    np.isfinite(out_plan["losses"]).all())
results["train_plan_matches_psum"] = bool(dl < 1e-3)
results["train_plan_loss_delta"] = float(dl)

# ---- hypothesis sweep (CI; skipped when hypothesis is absent) -------------
try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False
results["hypothesis_ran"] = HAVE_HYP
if HAVE_HYP:
    import math

    @settings(max_examples=12, deadline=None)
    @given(n=hst.integers(2, 8), size=hst.integers(1, 300),
           dtype=hst.sampled_from(["float32", "bfloat16"]),
           kind=hst.sampled_from(["gentree", "ring", "cps", "rhd"]),
           seed=hst.integers(0, 10**6))
    def sweep(n, size, dtype, kind, seed):
        if kind == "gentree":
            plan = gentree(topology.single_switch(n), 1e5).plan
        else:
            plan = getattr(plans, kind)(n, float(8 * n))
        cs = lower_plan(plan)
        tol = 1e-6 if dtype == "float32" else 0.05
        err = run_sched(cs, n, size, jnp.dtype(dtype), seed=seed)
        assert err < tol, (n, size, dtype, kind, err)

    try:
        sweep()
        results["hypothesis_sweep"] = True
    except Exception as e:
        results["hypothesis_sweep"] = False
        results["hypothesis_error"] = repr(e)[:500]

print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


@pytest.mark.parametrize("key", [
    "table6_two_level",
    "gentree_ss8_f32", "gentree_ss8_bf16",
    "gentree_cdc8_f32", "gentree_cdc8_bf16",
    "ring_f32", "ring_bf16", "cps_f32", "cps_bf16",
    "rhd_f32", "rhd_bf16", "hcps4x2_f32", "hcps4x2_bf16",
    "reduce_broadcast_f32", "reduce_broadcast_bf16",
    "gentree_n3", "gentree_n5", "gentree_n6", "gentree_n7",
    "rhd_n3", "rhd_n5", "rhd_n6", "rhd_n7",
    "rs_half", "ag_half",
    "sync_plan", "allreduce_planned", "sync_plan_two_axis",
    "train_plan_finite", "train_plan_matches_psum"])
def test_executed_schedule(results, key):
    assert results[key] is True, (key, results)


def test_hypothesis_sweep_when_available(results):
    if not results["hypothesis_ran"]:
        pytest.skip("hypothesis not installed")
    assert results["hypothesis_sweep"] is True, results.get(
        "hypothesis_error")
