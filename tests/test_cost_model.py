"""GenModel closed forms vs the generic plan-IR evaluator + paper anchors."""
import math

import pytest
from _hypothesis_stub import given, settings, strategies as st

from repro.core import cost_model as cm, plans
from repro.core.cost_model import GenModelParams


P = GenModelParams()


@pytest.mark.parametrize("n", [2, 3, 4, 8, 12, 15, 16, 24, 32])
@pytest.mark.parametrize("name,builder", [
    ("ring", plans.ring), ("cps", plans.cps),
    ("reduce_broadcast", plans.reduce_broadcast)])
def test_closed_form_matches_ir(n, name, builder):
    s = 1e7
    ir = cm.evaluate_plan(builder(n, s), P)
    cf = cm.CLOSED_FORMS[name](n, s, P)
    assert ir == pytest.approx(cf, rel=1e-6), (name, n)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32])
def test_rhd_closed_form_pow2(n):
    s = 1e7
    ir = cm.evaluate_plan(plans.rhd(n, s), P)
    cf = cm.cost_rhd(n, s, P)
    assert ir == pytest.approx(cf, rel=1e-6)


@pytest.mark.parametrize("factors", [[2, 2], [6, 2], [4, 2], [8, 4],
                                     [2, 2, 2], [5, 3]])
def test_hcps_closed_form(factors):
    s = 1e7
    ir = cm.evaluate_plan(plans.hcps(factors, s), P)
    cf = cm.cost_hcps(factors, s, P)
    assert ir == pytest.approx(cf, rel=1e-6)


def test_table2_coefficient_structure():
    """β and γ coefficients keep the paper's 2:1 ratio for all
    bandwidth-optimal plans; δ matches Table 2 exactly."""
    n, s = 12, 1e8
    only_beta = GenModelParams(alpha=0, beta=1, gamma=0, delta=0, epsilon=0)
    only_gamma = GenModelParams(alpha=0, beta=0, gamma=1, delta=0, epsilon=0)
    only_delta = GenModelParams(alpha=0, beta=0, gamma=0, delta=1, epsilon=0)
    for cf in (cm.cost_ring, cm.cost_cps):
        assert cf(n, s, only_beta) == pytest.approx(2 * (n - 1) * s / n)
        assert cf(n, s, only_gamma) == pytest.approx((n - 1) * s / n)
    assert cm.cost_ring(n, s, only_delta) == pytest.approx(3 * (n - 1) * s / n)
    assert cm.cost_cps(n, s, only_delta) == pytest.approx((n + 1) * s / n)


def test_incast_term_thresholded():
    """No ε cost below w_t; linear growth above (paper Eq. 7)."""
    from dataclasses import replace
    s = 1e8
    no_eps = replace(P, epsilon=0.0)
    below = cm.cost_cps(P.w_t - 1, s, P)
    assert below == pytest.approx(cm.cost_cps(P.w_t - 1, s, no_eps))
    n = P.w_t + 5
    extra = 2 * (n - 1) * s / n * (n - P.w_t) * P.epsilon
    assert cm.cost_cps(n, s, P) - cm.cost_cps(n, s, no_eps) == \
        pytest.approx(extra)


def test_paper_prediction_12_processors():
    """Paper §5.1/Fig. 8: at N=12 the best plan is 6×2 HCPS (w_t=9)."""
    s = 1e8
    name, fac, cost = cm.best_flat_plan(12, s, P)
    assert (name, fac) == ("hcps", [6, 2])
    # and the (α,β,γ) model would NOT pick it (it can't see δ/ε):
    legacy = P.legacy()
    c_cps = cm.cost_cps(12, s, legacy)
    c_hcps = cm.cost_hcps([6, 2], s, legacy)
    assert c_cps < c_hcps     # legacy model prefers plain CPS


def test_paper_prediction_15_processors():
    """Paper §5.2: for 15 servers GenTree chooses 5×3 HCPS."""
    s = 1e8
    name, fac, _ = cm.best_flat_plan(15, s, P)
    assert name == "hcps" and fac in ([5, 3], [3, 5])


def test_paper_prediction_8_processors_cps():
    """Paper §5.2: for 8 servers (≤ w_t) GenTree chooses plain CPS."""
    s = 1e8
    name, _, _ = cm.best_flat_plan(8, s, P)
    assert name == "cps"


@settings(max_examples=50, deadline=None)
@given(n=st.integers(2, 64), s=st.floats(1e3, 1e9))
def test_chi(n, s):
    assert cm.chi(n) == (0 if (n & (n - 1)) == 0 else 1)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 40))
def test_hcps_beats_neither_extreme_universally(n):
    """Theorem 2 consequence: when N > w_t the best plan has fan-in
    strictly between 2 and N (trade-off), priced by GenModel."""
    s = 1e8
    name, fac, cost = cm.best_flat_plan(n, s, P)
    assert cost <= cm.cost_cps(n, s, P) + 1e-12
    assert cost <= cm.cost_ring(n, s, P) + 1e-12
