"""End-to-end training: auto engine loss decrease, manual (ZeRO-3 +
plan-selected collectives) engine equivalence, checkpoint/restart replay.

Multi-device cases run in a subprocess with 8 fake devices."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import TrainConfig, run_training


def test_auto_engine_loss_decreases(tmp_path):
    out = run_training(TrainConfig(
        arch="stablelm-12b", steps=30, seq_len=64, global_batch=4,
        lr=3e-3, log_every=1000))
    losses = out["losses"]
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_ckpt_restart_replays_exactly(tmp_path):
    tc = dict(arch="stablelm-12b", steps=20, seq_len=32, global_batch=2,
              lr=1e-3, ckpt_every=10, log_every=1000)
    full = run_training(TrainConfig(**tc, ckpt_dir=str(tmp_path / "full")))
    # interrupted run: first do 10 steps, then resume to 20 from disk
    part = run_training(TrainConfig(**{**tc, "steps": 10},
                                    ckpt_dir=str(tmp_path / "part")))
    resumed = run_training(TrainConfig(**tc,
                                       ckpt_dir=str(tmp_path / "part")))
    assert resumed["losses"][-1] == pytest.approx(full["losses"][-1],
                                                  rel=1e-5)


_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.train import TrainConfig, run_training

results = {}
mesh = jax.make_mesh((8, 1), ("data", "model"))
kw = dict(arch="rwkv6-1.6b", steps=8, seq_len=32, global_batch=8,
          lr=1e-3, log_every=1000)
auto = run_training(TrainConfig(**kw, engine="auto"), mesh=mesh)
for sync in ("psum", "ring", "hcps", "gentree"):
    tc = TrainConfig(**kw, engine="manual", sync=sync)
    if sync == "hcps":
        tc = TrainConfig(**kw, engine="manual", sync=sync)
    man = run_training(tc, mesh=mesh)
    diff = abs(man["losses"][-1] - auto["losses"][-1])
    results[f"manual_{sync}_diff"] = diff
    results[f"manual_{sync}_ok"] = bool(diff < 5e-2)
results["auto_final"] = auto["losses"][-1]
results["auto_decreased"] = bool(auto["losses"][-1] < auto["losses"][0])

# TP mesh: auto engine with model axis > 1
mesh_tp = jax.make_mesh((2, 4), ("data", "model"))
tp = run_training(TrainConfig(arch="stablelm-12b", steps=6, seq_len=32,
                              global_batch=4, lr=1e-3, log_every=1000),
                  mesh=mesh_tp)
results["tp_finite"] = bool(np.isfinite(tp["losses"][-1]))
print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def multi():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def test_manual_engines_match_auto(multi):
    for sync in ("psum", "ring", "hcps", "gentree"):
        assert multi[f"manual_{sync}_ok"], multi


def test_auto_multi_device_decreases(multi):
    assert multi["auto_decreased"]


def test_tp_mesh_trains(multi):
    assert multi["tp_finite"]


def test_hcps_factors_plumb_through():
    """SyncConfig with explicit factors must not crash plan building."""
    from repro.core.sync import SyncConfig, plan_axes_gentree
    plans = plan_axes_gentree([("data", 16), ("pod", 2)], 1e8)
    assert all(p.strategy in ("psum", "ring", "rhd", "cps", "hcps")
               for p in plans)
    assert len(plans) == 2
