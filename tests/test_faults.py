"""Chaos hardening (DESIGN.md §12): deterministic fault injection,
guarded schedule execution, degraded-mode replanning, refit guardrails,
and corruption-tolerant cache/checkpoint loading.

The unit tests run single-process and jax-light; the chaos soak runs an
8-device training differential in a subprocess: a run under an armed
FaultPlan (device loss, link sag, checkpoint corruption) must land on
the same final parameters as the fault-free run.
"""
import json
import os
import subprocess
import sys
import types

import numpy as np
import pytest
from _hypothesis_stub import given, settings, strategies as st

from repro.runtime.faults import (ENV_VAR, FaultEvent, FaultInjector,
                                  FaultPlan, InjectedFault, active_injector)


@pytest.fixture
def quiet_faults(monkeypatch):
    """Deterministic fault environment: mask any ambient injector (the
    CI chaos job arms $REPRO_FAULT_PLAN for the whole suite) with an
    empty scoped plan, so guard/ladder assertions see exactly the events
    each test arms itself."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    with FaultInjector(FaultPlan()) as inj:
        yield inj


# ---------------------------------------------------------------------------
# FaultPlan: determinism + parsing
# ---------------------------------------------------------------------------
def test_generate_is_deterministic():
    kw = dict(device_loss=0.05, link_degrade=0.05, delay=0.1,
              payload_corrupt=0.1, file_corrupt=0.05)
    a = FaultPlan.generate(7, 200, **kw)
    b = FaultPlan.generate(7, 200, **kw)
    assert a.events == b.events
    assert a.key() == b.key()
    assert a.key() != FaultPlan.generate(8, 200, **kw).key()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_plan_key_stable_across_regeneration(seed):
    kw = dict(steps=64, device_loss=0.05, link_degrade=0.1, delay=0.1,
              payload_corrupt=0.1)
    assert FaultPlan.generate(seed, **kw).key() == \
        FaultPlan.generate(seed, **kw).key()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 500), st.integers(1, 64))
def test_step_events_fire_once_per_injector(seed, steps):
    plan = FaultPlan.generate(seed, steps, delay=0.3, link_degrade=0.2)
    inj = FaultInjector(plan)
    first = [ev for s in range(steps) for ev in inj.step_events(s)]
    again = [ev for s in range(steps) for ev in inj.step_events(s)]
    assert sorted(e.ident for e in first) == \
        sorted(e.ident for e in plan.events if e.kind in
               ("delay", "link_degrade", "link_restore"))
    assert again == []                    # replay after restore: no re-fire


def test_parse_spec_and_bare_seed():
    p = FaultPlan.parse("seed=7,steps=64,delay=0.5,payload_corrupt=0")
    assert p.seed == 7 and p.count("delay") > 0
    assert p.count("payload_corrupt") == 0
    assert p.events == FaultPlan.parse(" seed=7, steps=64, delay=0.5,"
                                       "payload_corrupt=0 ").events
    bare = FaultPlan.parse("41")
    assert bare.seed == 41
    assert bare.count("device_loss") == 0     # survivable defaults
    with pytest.raises(ValueError):
        FaultPlan.parse("seed=1,bogus=2")


def test_link_degrade_pairs_with_restore():
    plan = FaultPlan.generate(3, 200, link_degrade=0.2)
    degrades = [e for e in plan.events if e.kind == "link_degrade"]
    restores = {(e.target, e.at) for e in plan.events
                if e.kind == "link_restore"}
    assert degrades
    for d in degrades:
        assert 0.25 <= d.magnitude <= 0.75
        # bounded window: a matching restore exists unless it would land
        # past the end of the run
        assert any(t == d.target and d.at < at <= d.at + 8
                   for t, at in restores) or d.at + 8 >= 200


# ---------------------------------------------------------------------------
# FaultInjector: scoping, launch ordinals, file corruption
# ---------------------------------------------------------------------------
def test_injector_scoping_is_lifo(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)
    assert active_injector() is None
    outer, inner = FaultInjector(FaultPlan()), FaultInjector(FaultPlan())
    with outer:
        assert active_injector() is outer
        with inner:
            assert active_injector() is inner
        assert active_injector() is outer
    assert active_injector() is None


def test_env_var_arms_process_wide_injector(monkeypatch):
    monkeypatch.setenv(ENV_VAR, "seed=9,steps=16,delay=0.5")
    inj = active_injector()
    assert inj is not None
    assert inj.plan.key() == FaultPlan.parse("seed=9,steps=16,delay=0.5"
                                             ).key()
    # an explicitly-entered injector wins over the env one
    with FaultInjector(FaultPlan()) as scoped:
        assert active_injector() is scoped
    # a malformed spec never crashes the host process
    monkeypatch.setenv(ENV_VAR, "seed=9,not_a_fault=1")
    assert active_injector() is None


def test_check_launch_consumes_ordinals(quiet_faults):
    plan = FaultPlan(seed=1, events=(FaultEvent("payload_corrupt", 2),))
    with FaultInjector(plan) as inj:
        inj.check_launch("a")             # ordinal 0
        inj.check_launch("b")             # ordinal 1
        with pytest.raises(InjectedFault) as ei:
            inj.check_launch("c")         # ordinal 2: armed
        assert ei.value.event.kind == "payload_corrupt"
        inj.check_launch("d")             # fired once: ordinal 3 clean
        assert inj.stats()["launches"] == 4
        assert inj.stats()["fired"] == {"payload_corrupt": 1}


def test_corrupt_file_is_deterministic(tmp_path):
    payload = os.urandom(4096)
    p1, p2 = tmp_path / "blob.bin", tmp_path / "sub"
    p2.mkdir()
    p2 = p2 / "blob.bin"
    p1.write_bytes(payload)
    p2.write_bytes(payload)
    a = FaultInjector(FaultPlan(seed=5))
    b = FaultInjector(FaultPlan(seed=5))
    assert a.corrupt_file(str(p1)) and b.corrupt_file(str(p2))
    assert p1.read_bytes() == p2.read_bytes()     # seeded by (seed, name)
    assert p1.read_bytes() != payload[:len(p1.read_bytes())]
    assert p1.read_bytes().startswith(b"\x00CHAOS\x00")
    assert not a.corrupt_file(str(tmp_path / "missing.bin"))


# ---------------------------------------------------------------------------
# GuardedSchedule: retry -> fallback -> sticky demotion
# ---------------------------------------------------------------------------
def _stub_inner(fail_times: int = 0, value: int = 7):
    """Minimal CompiledSchedule stand-in for ladder-shape tests."""
    calls = {"n": 0}

    def run_numpy(X):
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise RuntimeError(f"boom {calls['n']}")
        return value

    stub = types.SimpleNamespace(plan_name="stub", n=4, num_blocks=4,
                                 run_numpy=run_numpy, calls=calls)
    return stub


def test_guard_retries_then_raises_without_fallback(quiet_faults):
    from repro.core.lower import GuardedSchedule, GuardPolicy
    gs = GuardedSchedule(_stub_inner(fail_times=99),
                         policy=GuardPolicy(max_retries=2, backoff=0.0))
    with pytest.raises(RuntimeError, match="boom"):
        gs.run_numpy(np.zeros((4, 4)))    # run_numpy has no flat rung
    assert gs.stats["launches"] == 1
    assert gs.stats["retries"] == 2
    assert gs.inner.calls["n"] == 3       # initial attempt + 2 retries
    assert not gs.demoted                 # no fallback taken -> no demotion


def test_guard_fallback_ladder_and_sticky_demotion(quiet_faults):
    from repro.core.lower import GuardedSchedule, GuardPolicy
    gs = GuardedSchedule(_stub_inner(),
                         policy=GuardPolicy(max_retries=1, backoff=0.0))
    attempts = {"n": 0}

    def attempt():
        attempts["n"] += 1
        raise RuntimeError("planned rung down")

    assert gs._guarded("allreduce", attempt, lambda: "flat") == "flat"
    assert attempts["n"] == 2             # retry bounded, then fallback
    assert gs.stats["fallbacks"] == 1 and gs.demoted
    # demotion is sticky: the next launch takes the flat rung directly
    assert gs._guarded("allreduce", attempt, lambda: "flat") == "flat"
    assert attempts["n"] == 2
    assert gs.stats["demoted_launches"] == 1
    gs.reset_guard()
    assert gs._guarded("allreduce", lambda: "planned",
                       lambda: "flat") == "planned"


def test_guard_timeout_is_posthoc_demotion(quiet_faults):
    from repro.core.lower import GuardedSchedule, GuardPolicy
    gs = GuardedSchedule(_stub_inner(),
                         policy=GuardPolicy(timeout=0.0, backoff=0.0))
    # the overrunning launch still returns its (valid) result...
    assert gs._guarded("allreduce", lambda: 42, lambda: "flat") == 42
    assert gs.stats["timeouts"] == 1 and gs.demoted
    # ...and subsequent launches are served by the flat rung
    assert gs._guarded("allreduce", lambda: 42, lambda: "flat") == "flat"


def test_injected_payload_fault_exercises_retry(monkeypatch):
    from repro.core.lower import GuardedSchedule, GuardPolicy
    monkeypatch.delenv(ENV_VAR, raising=False)
    plan = FaultPlan(seed=1, events=(FaultEvent("payload_corrupt", 0),))
    gs = GuardedSchedule(_stub_inner(),
                         policy=GuardPolicy(max_retries=1, backoff=0.0))
    with FaultInjector(plan):
        # launch ordinal 0 is armed: check_launch raises before the
        # planned attempt runs, the retry (ordinal 1) goes through
        assert gs._guarded("allreduce", lambda: "planned",
                           lambda: "flat") == "planned"
    assert gs.stats["retries"] == 1
    assert gs.stats["fallbacks"] == 0 and not gs.demoted


def test_guarded_run_numpy_matches_inner(quiet_faults):
    from repro.core.lower import GuardedSchedule, guard_schedule
    from repro.planner.service import PlannerService
    ex = PlannerService().get_axis_executable("data", 4, 4096.0)
    gs = guard_schedule(ex.schedule)
    assert isinstance(gs, GuardedSchedule)
    X = np.random.default_rng(0).normal(size=(4, 32))
    np.testing.assert_allclose(gs.run_numpy(X), ex.schedule.run_numpy(X))
    # wrapper is a drop-in: delegated attrs reach the inner schedule
    assert gs.n == ex.schedule.n
    assert gs.describe() == ex.schedule.describe()


def test_guard_schedule_is_memoized(quiet_faults):
    from repro.core.lower import guard_schedule
    from repro.planner.service import PlannerService
    sched = PlannerService().get_axis_executable("data", 4, 4096.0).schedule
    g1 = guard_schedule(sched)
    g2 = guard_schedule(sched)
    assert g1 is g2                       # sticky demotion survives re-wrap
    assert guard_schedule(g1) is g1       # idempotent
    assert guard_schedule(None) is None


def test_link_restore_reprobes_demoted_guard(quiet_faults):
    """ISSUE 9 satellite: sticky wire/plan demotion must clear when link
    health is restored — a transient fault may not pin the mesh to flat
    psum forever. `PlannerService.mark_degraded(level, 1.0)` (the
    runtime.ft link_restore path) re-probes every live demoted guard."""
    from repro.core.lower import GuardedSchedule, GuardPolicy
    from repro.planner.service import PlannerService
    gs = GuardedSchedule(_stub_inner(),
                         policy=GuardPolicy(max_retries=0, backoff=0.0))

    def boom():
        raise RuntimeError("link down")

    assert gs._guarded("allreduce", boom, lambda: "flat") == "flat"
    assert gs.demoted
    svc = PlannerService()
    svc.mark_degraded("root_sw", 0.5)     # degradation: demotion stays
    assert gs.demoted
    svc.mark_degraded("root_sw", 1.0)     # restoration: re-probe
    assert not gs.demoted
    assert gs.stats["reprobes"] == 1
    # the next launch tries the planned rung again
    assert gs._guarded("allreduce", lambda: "planned",
                       lambda: "flat") == "planned"


def test_fault_plan_link_restore_reprobes_through_ft(quiet_faults,
                                                     tmp_path):
    """End-to-end: a link_degrade → link_restore fault-plan event stream
    replayed through FaultTolerantLoop._apply_fault re-probes the guard
    (ft calls mark_degraded(target, 1.0) on restore)."""
    from repro.core.lower import GuardedSchedule, GuardPolicy
    from repro.planner.service import PlannerService
    from repro.checkpoint import CheckpointManager
    from repro.runtime.ft import FaultTolerantLoop

    gs = GuardedSchedule(_stub_inner(),
                         policy=GuardPolicy(max_retries=0, backoff=0.0))
    gs._guarded("allreduce", _raise_link_down, lambda: "flat")
    assert gs.demoted
    svc = PlannerService()
    loop = FaultTolerantLoop(lambda s, i: s, {"w": 0},
                             CheckpointManager(str(tmp_path)), planner=svc)
    events = []
    loop.on_event = lambda kind, info: events.append(kind)
    loop._apply_fault(FaultEvent("link_degrade", 0, magnitude=0.5,
                                 target="root_sw"), step=0)
    assert gs.demoted                      # degraded: replan, stay flat
    loop._apply_fault(FaultEvent("link_restore", 1, magnitude=1.0,
                                 target="root_sw"), step=1)
    assert not gs.demoted                  # restored: planned rung re-armed
    assert events == ["degrade", "restore"]


def _raise_link_down():
    raise RuntimeError("link down")


# ---------------------------------------------------------------------------
# PlanCache: corrupted persistence never crashes startup
# ---------------------------------------------------------------------------
def test_cache_load_corrupt_file_is_cold_start(tmp_path):
    from repro.planner.cache import PlanCache
    path = tmp_path / "plans.json"
    path.write_text("{ not json !!")
    cache = PlanCache(path=str(path))     # auto-loads at construction
    assert cache.stats.load_errors == 1
    assert len(cache) == 0
    assert cache.load() == 0              # explicit retry: still no crash


def test_cache_load_skips_bad_entries(tmp_path):
    from repro.planner.cache import PlanCache
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({"version": 1, "entries": {
        "good": {"axis_plans": [["data", 4, "cps"]]},
        "torn_plan": {"plan": {"truncated": True}, "algo": "cps",
                      "predicted_time": 1e-3},
        "not_a_dict": 5,
    }, "stats": {}}))
    cache = PlanCache(path=str(path))     # auto-loads at construction
    assert cache.stats.load_errors == 2   # only the intact entry survives
    assert len(cache) == 1
    assert cache.stats.disk_loads == 1


def test_cache_survives_injector_corruption(tmp_path, quiet_faults):
    from repro.planner.cache import PlanCache
    from repro.planner.service import PlannerService
    path = str(tmp_path / "plans.json")
    svc = PlannerService(cache=PlanCache(path=path))
    svc.get_axis_executable("data", 4, 4096.0)
    svc.cache.save()
    assert len(PlanCache(path=path)) >= 1        # intact round-trip
    assert FaultInjector(FaultPlan(seed=3)).corrupt_file(path)
    cold = PlanCache(path=path)
    assert len(cold) == 0                 # corrupt file -> cold, no raise
    assert cold.stats.load_errors == 1


# ---------------------------------------------------------------------------
# CheckpointManager: checksum manifest + fallback restore
# ---------------------------------------------------------------------------
def _ckpt_tree(v: float):
    return {"w": np.full((4,), v, np.float32), "step": np.int64(v)}


def test_checkpoint_checksums_written_and_verified(tmp_path):
    from repro.checkpoint.store import (CHECKSUM_FILE, CheckpointManager,
                                        verify_checksums)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _ckpt_tree(1.0))
    path = tmp_path / "step_00000001"
    assert (path / CHECKSUM_FILE).exists()
    assert verify_checksums(str(path)) and mgr.verify(1)
    (path / "arrays.npz").write_bytes(b"\x00flip")
    assert not verify_checksums(str(path)) and not mgr.verify(1)


def test_restore_falls_back_past_corrupt_checkpoint(tmp_path, quiet_faults):
    from repro.checkpoint.store import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(10, _ckpt_tree(10.0))
    mgr.save(20, _ckpt_tree(20.0))
    inj = FaultInjector(FaultPlan(seed=11))
    assert inj.corrupt_file(str(tmp_path / "step_00000020" / "arrays.npz"))
    tree, step = mgr.restore(_ckpt_tree(0.0))
    assert step == 10                     # newest intact wins
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.full((4,), 10.0, np.float32))
    # an explicit step is authoritative: corruption there raises
    with pytest.raises(Exception):
        mgr.restore(_ckpt_tree(0.0), step=20)


def test_restore_raises_when_everything_is_corrupt(tmp_path, quiet_faults):
    from repro.checkpoint.store import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, _ckpt_tree(5.0))
    inj = FaultInjector(FaultPlan(seed=2))
    assert inj.corrupt_file(str(tmp_path / "step_00000005" / "arrays.npz"))
    with pytest.raises(FileNotFoundError, match="no intact checkpoint"):
        mgr.restore(_ckpt_tree(0.0))


# ---------------------------------------------------------------------------
# Fault-tolerant loop: injected faults, bounded events, budget decay
# ---------------------------------------------------------------------------
def test_watchdog_event_log_is_bounded():
    from repro.runtime.ft import StragglerWatchdog
    wd = StragglerWatchdog(threshold=2.0, max_events=4)
    wd.observe(0, 0.01)                   # seeds the EWMA baseline
    for step in range(1, 40):
        wd.observe(step, 5.0)             # every step straggles
    assert len(wd.events) == 4
    assert wd.events[-1][0] == 39         # deque keeps the freshest


def test_loop_replays_injected_device_loss_and_forgives(tmp_path,
                                                        monkeypatch):
    from repro.checkpoint import CheckpointManager
    from repro.runtime.ft import FaultTolerantLoop
    monkeypatch.delenv(ENV_VAR, raising=False)
    plan = FaultPlan(seed=1, events=(FaultEvent("device_loss", 3),
                                     FaultEvent("delay", 1,
                                                magnitude=0.001)))
    events = []
    loop = FaultTolerantLoop(
        lambda state, step: {"x": state["x"] + 1.0},
        {"x": np.float64(0.0)},
        CheckpointManager(str(tmp_path), async_save=False),
        ckpt_every=2, injector=FaultInjector(plan), forgive_after=2,
        on_event=lambda kind, info: events.append(kind))
    out = loop.run(8)
    kinds = set(events)
    assert float(out["x"]) == 8.0         # restore-and-replay is exact
    assert "failure" in kinds
    # 2 successful post-failure steps reset the restart budget
    assert "budget_reset" in kinds
    assert loop.restarts == 0


def test_loop_link_fault_flows_into_planner_health(tmp_path, monkeypatch):
    from repro.checkpoint import CheckpointManager
    from repro.planner.service import PlannerService
    from repro.runtime.ft import FaultTolerantLoop
    monkeypatch.delenv(ENV_VAR, raising=False)
    svc = PlannerService()
    plan = FaultPlan(seed=1, events=(
        FaultEvent("link_degrade", 1, "root_sw", 0.5),
        FaultEvent("link_restore", 3, "root_sw")))
    seen = []
    mid_run_health = {}

    def step_fn(state, step):
        if step == 2:
            mid_run_health.update(svc.degraded())
        return {"x": state["x"] + 1.0}

    loop = FaultTolerantLoop(
        step_fn, {"x": np.float64(0.0)},
        CheckpointManager(str(tmp_path), async_save=False),
        ckpt_every=10, planner=svc, injector=FaultInjector(plan),
        on_event=lambda kind, info: seen.append((kind, dict(info))))
    loop.run(5)
    assert mid_run_health == {"root_sw": 0.5}     # degraded mid-run...
    assert svc.degraded() == {}                   # ...restored by the end
    kinds = [k for k, _ in seen]
    assert "degrade" in kinds and "restore" in kinds


# ---------------------------------------------------------------------------
# Refit guardrails: validate / clamp / quarantine
# ---------------------------------------------------------------------------
def test_validate_params_rejects_garbage():
    from repro.core.cost_model import GenModelParams, TPU_V5E
    from repro.planner.calibrate import validate_params
    ok = TPU_V5E["root_sw"]
    assert validate_params(ok) == []
    import dataclasses
    assert validate_params(dataclasses.replace(ok, alpha=float("nan")))
    assert validate_params(dataclasses.replace(ok, beta=-1e-12))
    assert validate_params(dataclasses.replace(ok, gamma=1.0))  # implausible
    assert validate_params(GenModelParams(w_t=0))
    assert validate_params(dataclasses.replace(ok, delta=float("inf")))


def test_clamp_params_bounds_per_refit_movement():
    import dataclasses
    from repro.core.cost_model import TPU_V5E
    from repro.planner.calibrate import DEFAULT_GUARD, clamp_params
    old = TPU_V5E["root_sw"]
    wild = dataclasses.replace(old, alpha=old.alpha * 100.0,
                               beta=old.beta / 100.0)
    new, clamped = clamp_params(old, wild)
    r = DEFAULT_GUARD.max_step_ratio
    assert new.alpha == pytest.approx(old.alpha * r)
    assert new.beta == pytest.approx(old.beta / r)
    assert set(clamped) == {"alpha", "beta"}
    same, untouched = clamp_params(old, old)
    assert untouched == [] and same == old


def test_quarantine_outliers_drops_fault_window_samples():
    from repro.planner.calibrate import quarantine_outliers

    def s(n, size, cps):
        return types.SimpleNamespace(n=n, size_floats=size,
                                     cps_equivalent=cps)

    group = [s(8, 1e6, 1.0), s(8, 1e6, 1.1), s(8, 1e6, 0.9),
             s(8, 1e6, 50.0)]            # retry-storm outlier
    tiny = [s(4, 1e5, 99.0)]             # group < 3: kept whole
    kept, quarantined = quarantine_outliers(group + tiny, k=4.0)
    assert [q.cps_equivalent for q in quarantined] == [50.0]
    assert len(kept) == 4


def test_refit_rejects_nan_fit_and_keeps_params(monkeypatch):
    import repro.planner.service as service_mod
    from repro.core.cost_model import GenModelParams
    from repro.planner.calibrate import CalibrationResult
    from repro.planner.service import PlannerService

    svc = PlannerService()
    poisoned = GenModelParams(alpha=float("nan"), beta=-1e-9)
    monkeypatch.setattr(
        service_mod, "calibrate_levels",
        lambda source, cfg, provider=None: CalibrationResult(
            params={"root_sw": poisoned}))
    res = svc._refit_level("root_sw", drift=1.0, observations=8)
    assert res["rejected"]                # violations reported
    assert svc.params is None             # pricing basis untouched
    ev = svc.refits[-1]
    assert ev["level"] == "root_sw" and ev["rejected"]
    assert svc.stats()["refits"][-1]["rejected"]


def test_refit_clamps_implausible_jump(monkeypatch):
    import dataclasses
    import repro.planner.service as service_mod
    from repro.core.cost_model import TPU_V5E
    from repro.planner.calibrate import CalibrationResult
    from repro.planner.service import PlannerService

    svc = PlannerService(params=TPU_V5E)
    old = svc._merged_level_params("root_sw", svc.params)
    jump = dataclasses.replace(old, beta=old.beta * 1000.0)
    monkeypatch.setattr(
        service_mod, "calibrate_levels",
        lambda source, cfg, provider=None: CalibrationResult(
            params={"root_sw": jump}))
    res = svc._refit_level("root_sw", drift=1.0, observations=8)
    assert "rejected" not in res
    assert svc.refits[-1]["clamped"] == ["beta"]
    got = svc.params["root_sw"].beta
    assert got == pytest.approx(old.beta * 8.0)   # max_step_ratio bound
    assert got < jump.beta


# ---------------------------------------------------------------------------
# Degraded-mode replanning: health -> fingerprint -> fresh plan
# ---------------------------------------------------------------------------
def test_topology_health_changes_canonical_form():
    from repro.core.topology import single_switch
    from repro.planner.fingerprint import fingerprint_topo, topo_canonical
    t = single_switch(4)
    base = fingerprint_topo(t)
    t.children[0].mark_degraded(0.5)
    assert topo_canonical(t) != topo_canonical(single_switch(4))
    assert fingerprint_topo(t) != base
    t.children[0].restore_health()
    assert fingerprint_topo(t) == base    # restore is exact
    assert t.children[0].uplink_bw == single_switch(4).children[0].uplink_bw


def test_prune_dead_drops_subtree():
    from repro.core.topology import single_switch
    t = single_switch(4)
    t.children[1].mark_dead()
    assert t.has_dead()
    pruned = t.prune_dead()
    assert not pruned.has_dead()
    assert len(pruned.server_ids()) == 3
    for c in t.children:
        c.mark_dead()
    with pytest.raises(ValueError):
        t.prune_dead()


def test_mark_degraded_replans_under_new_fingerprint(quiet_faults):
    from repro.planner.service import PlannerService
    svc = PlannerService()
    healthy = svc.get_axis_executable("data", 8, 65536.0)
    dropped = svc.mark_degraded("root_sw", 0.5)
    assert dropped >= 0
    assert svc.degraded() == {"root_sw": 0.5}
    assert svc.stats()["degraded"] == {"root_sw": 0.5}
    degraded = svc.get_axis_executable("data", 8, 65536.0)
    assert degraded.key != healthy.key    # replanned, not re-served
    # pricing reflects the sag: same plan shape costs more on half bw
    assert degraded.predicted_time > healthy.predicted_time
    svc.clear_degraded()
    assert svc.degraded() == {}
    assert svc.get_axis_executable("data", 8, 65536.0).key == healthy.key


def test_degrade_never_bakes_into_stored_params():
    from repro.core.cost_model import TPU_V5E
    from repro.planner.service import PlannerService
    svc = PlannerService(params=TPU_V5E)
    svc.mark_degraded("root_sw", 0.25)
    eff = svc._effective_axis_params()
    assert eff["root_sw"].beta == pytest.approx(
        TPU_V5E["root_sw"].beta / 0.25)
    # the stored basis is still nominal: a later restore is lossless
    assert svc.params["root_sw"].beta == TPU_V5E["root_sw"].beta
    svc.clear_degraded()
    assert svc._effective_axis_params()["root_sw"].beta == \
        TPU_V5E["root_sw"].beta


# ---------------------------------------------------------------------------
# 8-device chaos soak: faulted run == fault-free run
# ---------------------------------------------------------------------------
_SOAK_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.pop("REPRO_FAULT_PLAN", None)
import json
import tempfile
import jax
import numpy as np
from repro.launch.train import TrainConfig, run_training
from repro.planner.service import default_service
from repro.runtime.faults import FaultEvent, FaultInjector, FaultPlan
from repro.runtime.metrics import default_metrics

results = {}
mesh = jax.make_mesh((8, 1), ("data", "model"))
kw = dict(arch="rwkv6-1.6b", steps=24, seq_len=32, global_batch=8,
          lr=1e-3, log_every=1000, engine="manual", sync="plan",
          ckpt_every=6, observe_sync=False)

clean = run_training(TrainConfig(**kw, ckpt_dir=tempfile.mkdtemp()),
                     mesh=mesh)

# deterministic chaos: a device loss mid-run, a root-switch bandwidth sag
# with bounded restore, a corrupted newest checkpoint, and a second
# device loss that forces the restore to fall back past the corruption
plan = FaultPlan(seed=7, events=(
    FaultEvent("delay", 5, magnitude=0.02),
    FaultEvent("device_loss", 8),
    FaultEvent("link_degrade", 14, "root_sw", 0.5),
    FaultEvent("link_restore", 17, "root_sw"),
    FaultEvent("file_corrupt", 20, "checkpoint"),
    FaultEvent("device_loss", 21),
))
injector = FaultInjector(plan)
with injector:
    chaos = run_training(TrainConfig(**kw, ckpt_dir=tempfile.mkdtemp()),
                         mesh=mesh)

fired = injector.stats()["fired"]
results["fired"] = fired
results["loss_clean"] = clean["losses"][-1]
results["loss_chaos"] = chaos["losses"][-1]
cl = jax.tree.leaves(clean["state"]["params"])
ch = jax.tree.leaves(chaos["state"]["params"])
results["param_max_rel"] = max(
    float(np.max(np.abs(np.asarray(a, np.float64) -
                        np.asarray(b, np.float64))) /
          (np.max(np.abs(np.asarray(a, np.float64))) + 1e-30))
    for a, b in zip(cl, ch))

svc = default_service()
results["degraded_after"] = svc.degraded()
snap = default_metrics().snapshot()


def ctr(name):
    return snap.get(name, {}).get("value", 0)


results["degrade_events"] = ctr("planner_degrade_events_total")
results["ckpt_fallbacks"] = ctr("ckpt_restore_fallbacks_total")
results["restarts"] = ctr("ft_restarts_total")
results["files_corrupted"] = ctr("faults_files_corrupted_total")
results["guarded_launches"] = ctr("guarded_launches_total")

# the live service replans degraded levels under a fresh fingerprint
e1 = svc.get_axis_executable("data", 8, 65536.0)
svc.mark_degraded("root_sw", 0.5)
e2 = svc.get_axis_executable("data", 8, 65536.0)
svc.clear_degraded()
results["fingerprint_changed"] = bool(e1.key != e2.key)

# no refit ever committed NaN/negative params
from repro.planner.calibrate import validate_params
params = svc.params or {}
results["params_valid"] = all(not validate_params(p)
                              for p in params.values())
results["refits_rejected_kept_basis"] = all(
    not r.get("rejected") or "params" not in r
    for r in svc.stats()["refits"])
print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def soak():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop(ENV_VAR, None)
    out = subprocess.run([sys.executable, "-c", _SOAK_DRIVER], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def test_soak_fires_required_faults(soak):
    fired = soak["fired"]
    assert fired.get("device_loss", 0) >= 1
    assert fired.get("link_degrade", 0) >= 1
    assert soak["files_corrupted"] >= 1
    assert soak["restarts"] >= 2          # both device losses restarted
    assert soak["ckpt_fallbacks"] >= 1    # corrupt ckpt skipped on restore


def test_soak_matches_fault_free_run(soak):
    assert abs(soak["loss_chaos"] - soak["loss_clean"]) <= \
        1e-6 * max(1.0, abs(soak["loss_clean"])), soak
    assert soak["param_max_rel"] <= 1e-6, soak


def test_soak_planner_replans_and_heals(soak):
    assert soak["degrade_events"] >= 2    # degrade + restore transitions
    assert soak["degraded_after"] == {}   # health restored by run end
    assert soak["fingerprint_changed"]
    assert soak["guarded_launches"] >= 1


def test_soak_refits_never_commit_garbage(soak):
    assert soak["params_valid"]
    assert soak["refits_rejected_kept_basis"]
