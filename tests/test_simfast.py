"""Compiled plan-evaluation engine (core.simfast, DESIGN.md §7):

  * routing-index correctness against the reference `path_links` walk;
  * fast-vs-reference SimResult equivalence (total, per_step, comm,
    compute, latency, incast_extra) within 1e-9 across every plan builder
    and every Table-6 topology;
  * GenTree decision equivalence between the batched fast search and the
    pre-PR reference search, plus a regression pin of the per-switch
    algorithm choices so the fast path cannot silently change selection;
  * batched arrival-gated skew pricing against the per-draw reference;
  * Step aggregate caching semantics.
"""
import numpy as np
import pytest

from repro.core import plans as plans_mod, topology as topo_mod
from repro.core.cost_model import PAPER_TABLE5
from repro.core.gentree import baseline_plan, gentree
from repro.core.simfast import FastEngine
from repro.core.simulator import Simulator

TOL = 1e-9

# The paper's six evaluation topologies (Table 6) — SS24/SS32 in-rack,
# SYM/ASY three-level trees, CDC384 cross-DC — plus small extras.
TABLE6 = {
    "SS24": lambda: topo_mod.single_switch(24),
    "SS32": lambda: topo_mod.single_switch(32),
    "SYM384": lambda: topo_mod.symmetric_tree(16, 24),
    "SYM512": lambda: topo_mod.symmetric_tree(16, 32),
    "ASY384": lambda: topo_mod.asymmetric_tree(16, 32, 16),
    "CDC384": lambda: topo_mod.cross_dc(),
}
SMALL = {
    "SS15": lambda: topo_mod.single_switch(15),
    "SYM4x6": lambda: topo_mod.symmetric_tree(4, 6),
    "ASY-small": lambda: topo_mod.asymmetric_tree(4, 8, 4),
    "CDC-small": lambda: topo_mod.cross_dc(dc0_middle=2, dc0_servers=4,
                                           dc1_middle=2, dc1_servers=3),
    "TPU2x8": lambda: topo_mod.tpu_pod_tree(2, 8),
}


def _builder_plans(topo, size=1e6):
    """One plan per builder (ring/cps/rhd/hcps/reduce_broadcast), routed
    over the topology's real server ids."""
    ids = topo.server_ids()
    n = len(ids)
    out = [plans_mod.ring(n, size, servers=ids),
           plans_mod.cps(n, size, servers=ids),
           plans_mod.rhd(n, size, servers=ids),
           plans_mod.reduce_broadcast(n, size, servers=ids)]
    facs = plans_mod.factorizations(n, max_steps=3)
    if facs:
        out.append(plans_mod.hcps(facs[0], size, servers=ids))
    return out


def _assert_equivalent(ref, fast):
    assert fast.total == pytest.approx(ref.total, abs=TOL)
    assert fast.comm == pytest.approx(ref.comm, abs=TOL)
    assert fast.compute == pytest.approx(ref.compute, abs=TOL)
    assert fast.latency == pytest.approx(ref.latency, abs=TOL)
    assert fast.incast_extra == pytest.approx(ref.incast_extra, abs=TOL)
    assert len(fast.per_step) == len(ref.per_step)
    for a, b in zip(ref.per_step, fast.per_step):
        assert b == pytest.approx(a, abs=TOL)


# ---------------------------------------------------------------------------
# Routing index
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tname", list(SMALL) + ["SS24", "CDC384"])
def test_routing_index_matches_path_links(tname):
    topo = (SMALL.get(tname) or TABLE6[tname])()
    rx = topo.routing()
    srv = {s._sid: s for s in topo.servers()}
    idx = {id(n): i for i, n in enumerate(rx.nodes)}
    ids = sorted(srv)
    rng = np.random.default_rng(0)
    pairs = [(int(a), int(b)) for a, b in
             rng.integers(0, len(ids), size=(64, 2))]
    for a, b in pairs:
        ref = [idx[id(node)] * 2 + (0 if d == "up" else 1)
               for node, d in topo.path_links(srv[ids[a]], srv[ids[b]])]
        assert rx.path_link_ids(ids[a], ids[b]) == ref


def test_routing_index_rebuilt_on_finalize():
    topo = topo_mod.single_switch(4)
    rx1 = topo.routing()
    topo.children.append(topo_mod._server("extra", 1e9, 1e-6))
    topo.finalize()
    rx2 = topo.routing()
    assert rx2 is not rx1 and rx2.n_servers == 5


def test_routing_on_subtree_does_not_corrupt_enclosing_tree():
    """Simulating a subtree of a finalized tree (its server ids are a
    sparse subset of the global ids) must not re-finalize it: the parent
    pointer and the enclosing tree's ids stay intact, and fast ==
    reference on plans over the subtree's global server ids."""
    full = topo_mod.symmetric_tree(4, 6)
    sub = full.children[2]
    ids_before = full.server_ids()
    sub_ids = sub.server_ids()
    plan = plans_mod.cps(len(sub_ids), 1e6, servers=sub_ids)
    ref = Simulator(sub, PAPER_TABLE5, engine="reference").simulate(plan)
    fast = Simulator(sub, PAPER_TABLE5, engine="fast").simulate(plan)
    _assert_equivalent(ref, fast)
    assert sub.parent is full
    assert full.server_ids() == ids_before


def test_subtree_routing_index_refreshes_after_renumbering():
    """Editing the enclosing tree and re-finalizing renumbers sids
    DFS-wide; a subtree's cached index must be discarded, not reused."""
    full = topo_mod.symmetric_tree(3, 4)
    sub = full.children[1]
    stale = sub.routing()
    # grow an earlier sibling: every sid in `sub` shifts by one
    full.children[0].children.append(
        topo_mod._server("extra", 10 * topo_mod.GBPS, 5e-6))
    full.finalize()
    rx = sub.routing()
    assert rx is not stale
    assert rx.sids == tuple(s._sid for s in sub.servers())
    ids = sub.server_ids()
    plan = plans_mod.cps(len(ids), 1e6, servers=ids)
    _assert_equivalent(
        Simulator(sub, PAPER_TABLE5, engine="reference").simulate(plan),
        Simulator(sub, PAPER_TABLE5, engine="fast").simulate(plan))


# ---------------------------------------------------------------------------
# Engine equivalence: every builder × every Table-6 topology (+ extras)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tname", list(SMALL))
def test_fast_matches_reference_small(tname):
    topo = SMALL[tname]()
    ref_sim = Simulator(topo, PAPER_TABLE5, engine="reference")
    fast_sim = Simulator(topo, PAPER_TABLE5, engine="fast")
    for plan in _builder_plans(topo) + [gentree(topo, 1e6).plan]:
        _assert_equivalent(ref_sim.simulate(plan), fast_sim.simulate(plan))


@pytest.mark.slow
@pytest.mark.parametrize("tname", list(TABLE6))
def test_fast_matches_reference_table6(tname):
    topo = TABLE6[tname]()
    ref_sim = Simulator(topo, PAPER_TABLE5, engine="reference")
    fast_sim = Simulator(topo, PAPER_TABLE5, engine="fast")
    for plan in _builder_plans(topo):
        _assert_equivalent(ref_sim.simulate(plan), fast_sim.simulate(plan))


def test_engine_flag_and_env(monkeypatch):
    topo = topo_mod.single_switch(8)
    with pytest.raises(ValueError):
        Simulator(topo, engine="warp")
    monkeypatch.setenv("REPRO_SIM_ENGINE", "reference")
    assert Simulator(topo).engine == "reference"
    monkeypatch.delenv("REPRO_SIM_ENGINE")
    assert Simulator(topo).engine == "fast"


def test_unit_bytes_scaling_matches():
    topo = topo_mod.tpu_pod_tree(2, 8)
    plan = baseline_plan("cps", topo, 1e6)
    for unit in (1, 2, 8):
        ref = Simulator(topo, engine="reference",
                        unit_bytes=unit).simulate(plan)
        fast = Simulator(topo, engine="fast",
                         unit_bytes=unit).simulate(plan)
        _assert_equivalent(ref, fast)


# ---------------------------------------------------------------------------
# GenTree: batched fast search ≡ reference search, decisions pinned
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tname", list(SMALL))
def test_gentree_fast_search_matches_reference(tname):
    topo_f, topo_r = SMALL[tname](), SMALL[tname]()
    rf = gentree(topo_f, 1e7, engine="fast")
    rr = gentree(topo_r, 1e7, engine="reference")
    assert rf.predicted_time == pytest.approx(rr.predicted_time, abs=TOL)
    assert set(rf.decisions) == set(rr.decisions)
    for sw, dr in rr.decisions.items():
        df = rf.decisions[sw]
        assert (df.algo, df.factors, df.rearrange) == \
            (dr.algo, dr.factors, dr.rearrange), sw
        assert df.cost == pytest.approx(dr.cost, abs=TOL)


def _decision_summary(decisions):
    out = {}
    for name, d in sorted(decisions.items()):
        label = d.algo + ("x".join(map(str, d.factors)) if d.factors else "")
        if d.rearrange:
            label += "+rearr"
        key = ("root" if name in ("root", "wan_root")
               else "dc" if name in ("dc0", "dc1") else "middle")
        out.setdefault(key, set()).add(label)
    return {k: sorted(v) for k, v in out.items()}


# Regression pin: the per-switch algorithm choices on the Table-6
# topologies at S=1e8 (matches the pre-PR reference search output). A
# change here means the fast path silently altered plan selection.
PINNED_DECISIONS = {
    "SS24": {"root": ["hcps8x3"]},
    "SS32": {"root": ["hcps8x4"]},
    "SYM384": {"middle": ["hcps8x3"], "root": ["hcps2x2x4"]},
    "SYM512": {"middle": ["hcps8x2x2"], "root": ["hcps2x2x4"]},
    "ASY384": {"middle": ["hcps8x2", "hcps8x2x2"], "root": ["acps"]},
    "CDC384": {"dc": ["hcps2x2x2"], "middle": ["hcps8x2", "hcps8x2x2"],
               "root": ["acps+rearr"]},
}


@pytest.mark.parametrize("tname", list(TABLE6))
def test_gentree_decisions_pinned_table6(tname):
    r = gentree(TABLE6[tname](), 1e8, engine="fast")
    assert _decision_summary(r.decisions) == PINNED_DECISIONS[tname]


# ---------------------------------------------------------------------------
# Batched arrival-gated skew pricing ≡ per-draw reference
# ---------------------------------------------------------------------------
def test_gated_times_batch_matches_reference():
    from repro.planner.skew import (SkewModel, arrival_gated_time,
                                    draw_offsets, gated_times)
    for builder in (lambda: topo_mod.single_switch(12),
                    lambda: topo_mod.symmetric_tree(4, 6),
                    lambda: topo_mod.cross_dc(dc0_middle=2, dc0_servers=4,
                                              dc1_middle=2, dc1_servers=3)):
        topo = builder()
        n = topo.num_servers()
        offs = draw_offsets(SkewModel(scale=0.05, draws=5, seed=7), n)
        for plan in (baseline_plan("ring", topo, 1e6),
                     baseline_plan("cps", topo, 1e6),
                     gentree(topo, 1e6).plan):
            ref = [arrival_gated_time(plan, topo, None, o) for o in offs]
            bat = gated_times(plan, topo, None, offs)
            assert np.allclose(ref, bat, atol=TOL, rtol=0.0)
            z = gated_times(plan, topo)[0]
            assert z == pytest.approx(
                arrival_gated_time(plan, topo, None, None), abs=TOL)


def test_pick_plan_under_skew_engines_agree():
    from repro.planner.skew import SkewModel, pick_plan_under_skew
    topo = topo_mod.single_switch(12)
    cands = [(k, baseline_plan(k, topo, 1e7)) for k in ("ring", "cps")]
    for scale in (0.0, 0.02, 0.2):
        model = SkewModel(scale=scale, draws=6, seed=1)
        nf, _, cf = pick_plan_under_skew(cands, topo, model, engine="fast")
        nr, _, cr = pick_plan_under_skew(cands, topo, model,
                                         engine="reference")
        assert nf == nr
        assert cf == pytest.approx(cr, abs=TOL)


# ---------------------------------------------------------------------------
# Step aggregate caching (plans.Step)
# ---------------------------------------------------------------------------
def test_step_caches_and_invalidates_on_append():
    st = plans_mod.Step()
    st.transfers.append(plans_mod.Transfer(0, 1, 4.0))
    first = st.recv_bytes_by_dst()
    assert first == {1: 4.0}
    assert st.recv_bytes_by_dst() is first          # cached
    st.transfers.append(plans_mod.Transfer(2, 1, 2.0))
    assert st.recv_bytes_by_dst() == {1: 6.0}       # length change → rebuilt
    assert st.fan_in_by_dst() == {1: 2}
    st.invalidate_caches()
    assert st.recv_bytes_by_dst() == {1: 6.0}


def test_step_cache_survives_merge_pattern():
    """_merge_concurrent extends steps after they were priced; the length
    guard must invalidate."""
    a = plans_mod.Step(transfers=[plans_mod.Transfer(0, 1, 1.0)])
    _ = a.fan_in_by_dst()
    a.transfers.extend([plans_mod.Transfer(1, 0, 1.0)])
    assert a.fan_in_by_dst() == {1: 1, 0: 1}
