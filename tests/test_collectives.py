"""Collective-schedule correctness on a multi-device mesh.

The pytest process sees one CPU device; these tests re-exec a small driver
in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 so
the schedules run on a real 8-way mesh. One subprocess runs ALL cases
(startup dominates)."""
import json
import os
import subprocess
import sys

import pytest

_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.core import collectives as C
from repro.core.sync import SyncConfig, allreduce_int8_cps, sync_gradients

mesh = jax.make_mesh((8,), ("x",))
results = {}
x = jnp.arange(8 * 40, dtype=jnp.float32).reshape(8, 40) / 7.0
want = np.asarray(x.sum(0))

for strat, fac in [("psum", None), ("ring", None), ("rhd", None),
                   ("cps", None), ("hcps", (4, 2)), ("hcps", (2, 4)),
                   ("hcps", (2, 2, 2))]:
    f = shard_map(lambda v: C.allreduce(v[0], "x", strat, factors=fac)[None],
                  mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    out = np.asarray(f(x))
    results[f"allreduce_{strat}_{fac}"] = bool(
        np.allclose(out, np.tile(want, (8, 1)), rtol=1e-5))

# reduce_scatter: shard i must hold the i-th slice of the summed vector
for strat, fac in [("ring", None), ("rhd", None), ("cps", None),
                   ("hcps", (4, 2))]:
    f = shard_map(lambda v: C.reduce_scatter(v[0], "x", strat,
                                             factors=fac)[None],
                  mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    out = np.asarray(f(x)).reshape(-1)
    results[f"rs_{strat}_{fac}"] = bool(np.allclose(out, want, rtol=1e-5))

# reduce_scatter SHAPE CONTRACT: every strategy — psum included — returns
# the FLAT (chunk,) shard; the old psum path (tiled=False on the (n, chunk)
# reshape) handed back a (1, chunk) slab instead.
shape_ok, value_ok = {}, {}
for strat, fac in [("psum", None), ("ring", None), ("rhd", None),
                   ("cps", None), ("hcps", (4, 2))]:
    f = shard_map(lambda v: C.reduce_scatter(v[0], "x", strat,
                                             factors=fac)[None],
                  mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    out = np.asarray(f(x))
    shape_ok[strat] = out.shape == (8, x.shape[1] // 8)
    value_ok[strat] = bool(np.allclose(out.reshape(-1), want, rtol=1e-5))
results["rs_shape_contract"] = all(shape_ok.values())
results["rs_shape_detail"] = {k: bool(v) for k, v in shape_ok.items()}
results["rs_value_contract"] = all(value_ok.values())

# non-power-of-two axes: executable RHD via fold-in/fold-out (the plans.rhd
# patch) — allreduce must match the sum on 3/5/6/7-device sub-meshes
npo2 = {}
for n in (3, 5, 6, 7):
    sub = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("x",))
    y2 = jnp.arange(n * 19, dtype=jnp.float32).reshape(n, 19) / 3.0
    f = shard_map(lambda v: C.allreduce(v[0], "x", "rhd")[None],
                  mesh=sub, in_specs=P("x"), out_specs=P("x"))
    npo2[n] = bool(np.allclose(np.asarray(f(y2)),
                               np.tile(np.asarray(y2.sum(0)), (n, 1)),
                               rtol=1e-5))
results["rhd_non_pow2"] = all(npo2.values())
results["rhd_non_pow2_detail"] = npo2

# odd sizes exercise padding
y = jnp.arange(8 * 13, dtype=jnp.float32).reshape(8, 13)
wanty = np.asarray(y.sum(0))
f = shard_map(lambda v: C.allreduce(v[0], "x", "hcps", factors=(2, 4))[None],
              mesh=mesh, in_specs=P("x"), out_specs=P("x"))
results["allreduce_pad"] = bool(
    np.allclose(np.asarray(f(y)), np.tile(wanty, (8, 1)), rtol=1e-5))

# int8-compressed CPS allreduce: lossy — check correlation, not exactness
g = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))
f = shard_map(lambda v: allreduce_int8_cps(v[0], "x")[None],
              mesh=mesh, in_specs=P("x"), out_specs=P("x"))
out = np.asarray(f(g))[0]
ref = np.asarray(g.sum(0))
rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
results["int8_cps_rel"] = float(rel)
results["int8_cps_ok"] = bool(rel < 0.05)

# sync_gradients end-to-end over a pytree with gentree strategy
grads = {"a": jnp.ones((8, 100)), "b": jnp.full((8, 7), 2.0)}
def sync(g):
    loc = {k: v[0] for k, v in g.items()}
    out = sync_gradients(loc, [("x", 8)], SyncConfig(strategy="gentree"))
    return {k: v[None] for k, v in out.items()}
f = shard_map(sync, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
out = f(grads)
results["sync_gentree"] = bool(
    np.allclose(np.asarray(out["a"])[0], 8.0)
    and np.allclose(np.asarray(out["b"])[0], 16.0))

# multi-axis (pod × data) hierarchical sync
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
z = jnp.arange(8 * 24, dtype=jnp.float32).reshape(2, 4, 24)
def sync2(v):
    out = sync_gradients({"g": v[0, 0]}, [("data", 4), ("pod", 2)],
                         SyncConfig(strategy="hcps", factors=(2, 2)))
    return {"g": out["g"][None, None]}
f = shard_map(sync2, mesh=mesh2, in_specs=P("pod", "data"),
              out_specs=P("pod", "data"))
out = np.asarray(f(z)["g"]).reshape(8, 24)
results["sync_two_axis"] = bool(
    np.allclose(out, np.tile(z.reshape(8, 24).sum(0), (8, 1)), rtol=1e-5))

print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


@pytest.mark.parametrize("key", [
    "allreduce_psum_None", "allreduce_ring_None", "allreduce_rhd_None",
    "allreduce_cps_None", "allreduce_hcps_(4, 2)", "allreduce_hcps_(2, 4)",
    "allreduce_hcps_(2, 2, 2)", "rs_ring_None", "rs_rhd_None",
    "rs_cps_None", "rs_hcps_(4, 2)", "allreduce_pad", "int8_cps_ok",
    "sync_gentree", "sync_two_axis",
    "rs_shape_contract", "rs_value_contract", "rhd_non_pow2"])
def test_collective(results, key):
    assert results[key] is True, (key, results)


_TOPK_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.core.sync import allreduce_topk

mesh = jax.make_mesh((8,), ("x",))
# sparse gradients: top-k with k covering all nonzeros must be EXACT
g = jnp.zeros((8, 1000))
g = g.at[:, :5].set(jax.random.normal(jax.random.PRNGKey(0), (8, 5)))
f = shard_map(lambda v: allreduce_topk(v[0], "x", k_frac=0.01)[None],
              mesh=mesh, in_specs=P("x"), out_specs=P("x"))
out = np.asarray(f(g))[0]
ref = np.asarray(g.sum(0))
print("RESULTS " + json.dumps({
    "exact_on_sparse": bool(np.allclose(out, ref, rtol=1e-5, atol=1e-6))}))
"""


def test_topk_allreduce_exact_on_sparse():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _TOPK_DRIVER], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    assert json.loads(line[len("RESULTS "):])["exact_on_sparse"]
