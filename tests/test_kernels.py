"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes (as required for every kernel in kernels/)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_reduce import fused_reduce, grouped_reduce
from repro.kernels.rmsnorm import rmsnorm


# ---------------------------------------------------------------------------
# fused_reduce — the paper's δ-optimal N-ary add
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("x,L", [(2, 128), (3, 1000), (8, 4096), (16, 257),
                                 (64, 64), (5, 8192)])
def test_fused_reduce_sweep(x, L, dtype):
    parts = jax.random.normal(jax.random.PRNGKey(x * L), (x, L), jnp.float32)
    parts = parts.astype(dtype)
    got = fused_reduce(parts, interpret=True)
    want = ref.fused_reduce_ref(parts)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("fan_in", [2, 3, 4, 7])
@pytest.mark.parametrize("x,L", [(2, 256), (6, 512), (12, 1000)])
def test_grouped_reduce_sweep(x, L, fan_in):
    parts = jax.random.normal(jax.random.PRNGKey(1), (x, L), jnp.float32)
    got = grouped_reduce(parts, fan_in, interpret=True)
    want = ref.fused_reduce_ref(parts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_grouped_fanin2_matches_chained_oracle():
    parts = jax.random.normal(jax.random.PRNGKey(2), (9, 300), jnp.float32)
    got = grouped_reduce(parts, 2, interpret=True)
    want = ref.chained_reduce_ref(parts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(x=st.integers(2, 10), L=st.integers(1, 600))
def test_fused_reduce_property(x, L):
    parts = jax.random.normal(jax.random.PRNGKey(x + L), (x, L), jnp.float32)
    got = fused_reduce(parts, tile_l=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(parts).sum(0), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention — causal / window / softcap / GQA, shape+dtype sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,T,D", [
    (1, 2, 2, 128, 32), (2, 4, 2, 256, 64), (1, 8, 1, 128, 64),
    (2, 2, 2, 512, 16)])
def test_flash_causal_sweep(B, Hq, Hkv, T, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * T), 3)
    q = jax.random.normal(ks[0], (B, Hq, T, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, D), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128, 200])
def test_flash_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 32))
    k = jax.random.normal(ks[1], (1, 2, 256, 32))
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    got = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("softcap", [20.0, 50.0])
def test_flash_softcap(softcap):
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32)) * 4
    k = jax.random.normal(ks[1], (1, 2, 128, 32)) * 4
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    got = flash_attention(q, k, v, softcap=softcap, interpret=True)
    want = ref.attention_ref(q, k, v, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_cross_attention_lengths():
    """Tq != Tk (decode-style right-aligned queries)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 256, 32))
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,d", [(1, 64), (33, 128), (300, 256),
                                    (256, 512)])
def test_rmsnorm_sweep(rows, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(rows), 2)
    x = jax.random.normal(ks[0], (rows, d), jnp.float32).astype(dtype)
    w = jax.random.normal(ks[1], (d,), jnp.float32).astype(dtype)
    got = rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_3d():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 128))
    w = jnp.ones((128,))
    got = rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ops.py dispatch
# ---------------------------------------------------------------------------
def test_ops_ref_dispatch_cpu():
    from repro.kernels import ops
    parts = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
    np.testing.assert_allclose(np.asarray(ops.fused_reduce(parts)),
                               np.asarray(parts.sum(0)), rtol=1e-5)


# ---------------------------------------------------------------------------
# wkv — chunked RWKV6 recurrence (the SSM-family memory hotspot)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,T,K,chunk", [
    (1, 1, 8, 4, 4), (2, 3, 16, 8, 4), (1, 2, 32, 16, 8),
    (2, 2, 24, 8, 8), (1, 4, 64, 32, 16)])
def test_wkv_kernel_sweep(B, H, T, K, chunk):
    from repro.kernels.wkv import wkv
    ks = jax.random.split(jax.random.PRNGKey(B * T + K), 6)
    r = jax.random.normal(ks[0], (B, H, T, K))
    k = jax.random.normal(ks[1], (B, H, T, K))
    v = jax.random.normal(ks[2], (B, H, T, K))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, K)))
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, K, K)) * 0.1
    got, s_got = wkv(r, k, v, lw, u, s0, chunk=chunk, interpret=True)
    want, s_want = ref.wkv_ref(r, k, v, lw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=2e-5, atol=2e-5)


def test_wkv_kernel_state_handoff():
    """Running two half-sequences with carried state == one full run."""
    from repro.kernels.wkv import wkv
    B, H, T, K = 1, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    r = jax.random.normal(ks[0], (B, H, T, K))
    k = jax.random.normal(ks[1], (B, H, T, K))
    v = jax.random.normal(ks[2], (B, H, T, K))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, K)))
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    s0 = jnp.zeros((B, H, K, K))
    full, s_full = wkv(r, k, v, lw, u, s0, chunk=8, interpret=True)
    h1, s1 = wkv(r[:, :, :8], k[:, :, :8], v[:, :, :8], lw[:, :, :8],
                 u, s0, chunk=8, interpret=True)
    h2, s2 = wkv(r[:, :, 8:], k[:, :, 8:], v[:, :, 8:], lw[:, :, 8:],
                 u, s1, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, :, 8:]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm_scan — selective-SSM chunked scan (hymba's mamba branch)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,Di,N,chunk,bd", [
    (1, 8, 4, 2, 4, 4), (2, 16, 12, 4, 4, 6), (1, 32, 16, 8, 8, 16),
    (2, 24, 10, 4, 8, 5)])
def test_ssm_scan_kernel_sweep(B, T, Di, N, chunk, bd):
    from repro.kernels.ssm_scan import ssm_scan
    ks = jax.random.split(jax.random.PRNGKey(B * T + Di), 6)
    u = jax.random.normal(ks[0], (B, T, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Di)))
    b = jax.random.normal(ks[2], (B, T, N))
    c = jax.random.normal(ks[3], (B, T, N))
    la = -jnp.exp(jax.random.normal(ks[4], (Di, N)) * 0.5)
    s0 = jax.random.normal(ks[5], (B, Di, N)) * 0.1
    got, sg = ssm_scan(u, dt, b, c, la, s0, chunk=chunk, block_d=bd,
                       interpret=True)
    want, sw = ref.ssm_scan_ref(u, dt, b, c, la, s0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(sg), np.asarray(sw),
                               rtol=2e-5, atol=2e-5)


def test_ssm_scan_state_handoff():
    from repro.kernels.ssm_scan import ssm_scan
    B, T, Di, N = 1, 16, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    u = jax.random.normal(ks[0], (B, T, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, Di)))
    b = jax.random.normal(ks[2], (B, T, N))
    c = jax.random.normal(ks[3], (B, T, N))
    la = -jnp.exp(jax.random.normal(ks[4], (Di, N)) * 0.5)
    s0 = jnp.zeros((B, Di, N))
    full, s_full = ssm_scan(u, dt, b, c, la, s0, chunk=4, interpret=True)
    h1, s1 = ssm_scan(u[:, :8], dt[:, :8], b[:, :8], c[:, :8], la, s0,
                      chunk=4, interpret=True)
    h2, s2 = ssm_scan(u[:, 8:], dt[:, 8:], b[:, 8:], c[:, 8:], la, s1,
                      chunk=4, interpret=True)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, 8:]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-5, atol=2e-5)


def test_ssm_scan_matches_model_recurrence():
    """The kernel recurrence must equal models/recurrence.mamba_ssm's
    inner scan (same inputs derived from a real mamba layer)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.config import smoke_config
    from repro.models.recurrence import init_mamba, mamba_ssm
    from repro.kernels.ssm_scan import ssm_scan
    cfg = smoke_config(get_config("hymba_1_5b"))
    p = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_model, s_model = mamba_ssm(p, x, cfg, chunk=8)
    # recompute the scan inputs exactly as mamba_ssm does
    di, n = p["log_a"].shape
    xb = (x @ p["in_x"]).astype(jnp.float32)
    z = jax.nn.silu((x @ p["in_z"]).astype(jnp.float32))
    dt = jax.nn.softplus(xb @ p["w_dt"] + p["dt_bias"][None, None])
    b_t = xb @ p["w_b"].astype(jnp.float32) / di ** 0.5
    c_t = xb @ p["w_c"].astype(jnp.float32) / di ** 0.5
    u = jax.nn.silu(xb)
    s0 = jnp.zeros((2, di, n), jnp.float32)
    ys, s_fin = ssm_scan(u, dt, b_t, c_t, p["log_a"], s0, chunk=8,
                         block_d=di, interpret=True)
    y = (ys + u * p["d_skip"][None, None]) * z
    y = y.astype(x.dtype) @ p["out"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_model),
                               rtol=2e-5, atol=2e-5)
