"""HLO-text roofline analyzer: parsing + trip-count weighting unit tests
on synthetic HLO, plus an end-to-end check against a live-compiled jit
program with a known FLOP count."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha


SYNTH = """\
HloModule test, entry_computation_layout={()->f32[]}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[8,16]) tuple()
  %while.1 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[32,16]{1,0} all-gather(%init), dimensions={0}
  ROOT %out = f32[] constant(0)
}
"""


def test_shape_bytes():
    assert ha._shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert ha._shape_bytes("bf16[4]") == 8
    assert ha._shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert ha._shape_bytes("pred[]") == 1


def test_instr_parse_tuple_types():
    line = ("  %while.28 = (s32[], bf16[32,256]{1,0}, /*index=5*/f32[6]) "
            "while(%tuple.39), condition=%c, body=%b")
    name, rtype, op, rest = ha._parse_instr_line(line)
    assert name == "while.28" and op == "while"
    assert "index=5" in rtype


def test_synthetic_trip_weighting():
    stats = ha.analyze_hlo(SYNTH)
    # dot: 2 * (8*16) * 16 = 4096 flops, ×5 trips
    assert stats.flops == pytest.approx(5 * 2 * 8 * 16 * 16)
    # replica_groups={} → group size unknown → asymptotic wire factors:
    # all-reduce 2·M (512B payload) ×5, all-gather 1·M (2048B result) ×1
    assert stats.coll_by_kind["all-reduce"] == pytest.approx(5 * 2 * 512)
    assert stats.coll_by_kind["all-gather"] == pytest.approx(32 * 16 * 4)
    # raw payloads stay un-scaled in the payload ledger
    assert stats.coll_payload_by_kind["all-reduce"] == pytest.approx(5 * 512)
    assert stats.coll_payload_by_kind["all-gather"] == pytest.approx(2048)


MULTIFAM = """\
HloModule fam, entry_computation_layout={(f32[8,16])->f32[]}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,16]) -> f32[] {
  %x = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = f32[2,16]{1,0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  %ag = f32[32,16]{1,0} all-gather(%x), replica_groups=[2,4]<=[8], dimensions={0}
  %a2a = f32[8,16]{1,0} all-to-all(%x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = f32[8,16]{1,0} collective-permute(%x), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[] constant(0)
}
"""


def test_per_family_wire_bytes():
    """Regression pin for the wire-byte convention (ISSUE 9): the mix
    handed to the whole-step planner prices actual wire traffic —
    AR 2(n-1)/n·M, RS/AG/A2A (n-1)/n·M, CP M — with n parsed from
    replica_groups (both explicit and iota forms)."""
    stats = ha.analyze_hlo(MULTIFAM)
    M = 8 * 16 * 4                      # 512B operand payload
    ag_M = 32 * 16 * 4                  # gathered result payload
    assert stats.coll_by_kind["all-reduce"] == pytest.approx(2 * 3 / 4 * M)
    assert stats.coll_by_kind["reduce-scatter"] == pytest.approx(3 / 4 * M)
    assert stats.coll_by_kind["all-gather"] == pytest.approx(3 / 4 * ag_M)
    assert stats.coll_by_kind["all-to-all"] == pytest.approx(7 / 8 * M)
    assert stats.coll_by_kind["collective-permute"] == pytest.approx(M)
    # payload ledger keeps the raw M per family
    assert stats.coll_payload_by_kind["all-reduce"] == pytest.approx(M)
    assert stats.coll_payload_by_kind["all-gather"] == pytest.approx(ag_M)
    # group-size parser: explicit + iota forms
    assert ha._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert ha._group_size("replica_groups=[2,4]<=[8]") == 4
    assert ha._group_size("replica_groups={}") == 0


def test_mix_from_stats():
    mix = ha.mix_from_stats(ha.analyze_hlo(MULTIFAM))
    assert set(mix) == {"allreduce", "reduce_scatter", "allgather",
                        "all_to_all", "p2p"}
    assert mix["allreduce"] == {"count": 1, "size_floats": 8 * 16}
    assert mix["allgather"]["size_floats"] == 32 * 16
    assert mix["p2p"] == {"count": 1, "size_floats": 8 * 16}


def test_live_compiled_flops():
    """A real jit matmul under scan: analyzer FLOPs == analytic."""
    L, M, K, N = 4, 8, 32, 16

    def f(ws, x):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jax.ShapeDtypeStruct((L, K, K), jnp.float32)
    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    txt = jax.jit(f).lower(ws, x).compile().as_text()
    stats = ha.analyze_hlo(txt)
    assert stats.flops == pytest.approx(L * 2 * M * K * K)


def test_roofline_terms():
    stats = ha.ModuleStats(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9)
    rl = ha.roofline_from_stats(stats, chips=4, model_flops=4 * 197e12 / 2)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.useful_ratio == pytest.approx(0.5)
    assert rl.roofline_fraction == pytest.approx(0.5)
