"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the real device
count (1 on this container); multi-device tests spawn their own meshes via
the xdist-safe `fake_devices` marker handled in test files that re-exec
with a device-count env (see test_collectives.py docstring)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
