"""GenTree plan generation: Algorithm 1/2 behaviour + paper's Table-6
selection pattern + simulator consistency."""
import math

import pytest

from repro.core import topology as topo_mod
from repro.core.cost_model import PAPER_TABLE5
from repro.core.gentree import baseline_plan, generate_basic_plan, gentree
from repro.core.simulator import Simulator


def test_basic_plan_placement_single_switch():
    topo = topo_mod.single_switch(6)
    place = {}
    generate_basic_plan(topo, 6, place)
    final = place["root"]
    owned = sorted(b for blocks in final.values() for b in blocks)
    assert owned == list(range(6))                 # every block exactly once
    assert all(len(b) == 1 for b in final.values())  # balanced


def test_basic_plan_placement_asymmetric():
    root = topo_mod.TopoNode(name="root", level="root_sw")
    a = topo_mod.single_switch(4, name="swa")
    b = topo_mod.single_switch(2, name="swb")
    a.uplink_bw = b.uplink_bw = 1e9
    root.children = [a, b]
    root.finalize()
    place = {}
    generate_basic_plan(root, 6, place)
    owned = sorted(b_ for blocks in place["root"].values() for b_ in blocks)
    assert owned == list(range(6))


@pytest.mark.parametrize("n,algo,factors", [
    (8, "cps", None),
    (12, "hcps", [6, 2]),
    (15, "hcps", [5, 3]),
])
def test_single_switch_choices_match_paper(n, algo, factors):
    """Paper §5.2 CPU-testbed choices: CPS@8, 6×2@12, 5×3@15."""
    r = gentree(topo_mod.single_switch(n), 1e8)
    dec = r.decisions["root"]
    assert dec.algo == algo
    if factors:
        assert dec.factors == factors


def test_gentree_beats_baselines_single_switch():
    for n in (12, 15, 24):
        topo = topo_mod.single_switch(n)
        sim = Simulator(topo, PAPER_TABLE5)
        t_gen = gentree(topo, 1e8).predicted_time
        for kind in ("ring", "cps"):
            t_base = sim.simulate(baseline_plan(kind, topo, 1e8)).total
            assert t_gen <= t_base * 1.001, (n, kind)


def test_gentree_symmetric_tree():
    """SYM384-like (smaller): plans complete and beat global baselines."""
    topo = topo_mod.symmetric_tree(4, 6)
    sim = Simulator(topo, PAPER_TABLE5)
    r = gentree(topo, 1e7)
    assert len(r.decisions) == 5                  # 4 middle + root
    merges = sum((x.fan_in - 1) * x.size
                 for st in r.plan.steps for x in st.reduces)
    assert merges == pytest.approx((24 - 1) * 1e7)
    for kind in ("ring", "cps"):
        t_base = sim.simulate(baseline_plan(kind, topo, 1e7)).total
        assert r.predicted_time <= t_base * 1.001, kind


def test_gentree_asymmetric_tree_uses_acps():
    """Unbalanced children → Asymmetric CPS at the root (paper Table 6)."""
    root = topo_mod.TopoNode(name="root", level="root_sw")
    for name, k in (("sw0", 6), ("sw1", 3)):
        sw = topo_mod.TopoNode(name=name, uplink_bw=100 * topo_mod.GBPS,
                               uplink_latency=5e-6, level="middle_sw")
        sw.children = [topo_mod._server(f"{name}_s{i}", 10 * topo_mod.GBPS,
                                        5e-6) for i in range(k)]
        root.children.append(sw)
    root.finalize()
    r = gentree(root, 1e7)
    assert r.decisions["root"].algo == "acps"


@pytest.mark.slow
def test_gentree_cross_dc_rearrangement_wins():
    """Paper §5.3 CDC384: data rearrangement pays on the WAN-linked
    topology once enough senders share the WAN link (sender count ≫ w_t).
    GenTree consolidates DC1's results onto one middle-switch subtree
    before crossing the WAN."""
    r_with = gentree(topo_mod.cross_dc(), 1e7, enable_rearrangement=True)
    r_without = gentree(topo_mod.cross_dc(), 1e7,
                        enable_rearrangement=False)
    assert any(d.rearrange for d in r_with.decisions.values())
    assert r_with.predicted_time < r_without.predicted_time


def test_gentree_merge_conservation_everywhere():
    for topo in (topo_mod.single_switch(9),
                 topo_mod.symmetric_tree(3, 4),
                 topo_mod.tpu_pod_tree(2, 8)):
        n = topo.num_servers()
        s = 1e6
        r = gentree(topo, s)
        merges = sum((x.fan_in - 1) * x.size
                     for st in r.plan.steps for x in st.reduces)
        assert merges == pytest.approx((n - 1) * s), topo.name


def test_simulator_monotone_in_size():
    topo = topo_mod.single_switch(8)
    sim = Simulator(topo, PAPER_TABLE5)
    t1 = sim.simulate(baseline_plan("cps", topo, 1e6)).total
    t2 = sim.simulate(baseline_plan("cps", topo, 1e8)).total
    assert t2 > t1


def test_simulator_incast_grows_with_fanin():
    """x-to-x full mesh beyond w_t shows extra overhead (paper Fig. 3)."""
    times = []
    for n in (4, 8, 12, 15):
        topo = topo_mod.single_switch(n)
        sim = Simulator(topo, PAPER_TABLE5)
        res = sim.simulate(baseline_plan("cps", topo, 1e7))
        times.append((n, res.incast_extra))
    assert times[0][1] == 0 and times[1][1] == 0      # below w_t = 9
    assert times[2][1] > 0 and times[3][1] > times[2][1]
