"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus prefill/decode consistency
against the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, supported_shapes
from repro.models.config import SHAPES, smoke_config
from repro.models.registry import build

B, T = 2, 64
KEY = jax.random.PRNGKey(0)


def _batch(cfg, key=KEY, t=T):
    batch = {"labels": jax.random.randint(key, (B, t), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(
            key, (B, t, cfg.d_model), jnp.bfloat16) * 0.1
        batch["mrope_positions"] = jnp.tile(
            jnp.arange(t, dtype=jnp.int32)[None, None], (3, B, 1))
    elif cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, 32, cfg.d_model), jnp.bfloat16) * 0.1
        batch["tokens"] = jax.random.randint(key, (B, t), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(key, (B, t), 0, cfg.vocab)
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = smoke_config(get_config(request.param))
    api = build(cfg)
    params = api.init_params(KEY)
    return request.param, cfg, api, params


def test_loss_finite(arch_setup):
    name, cfg, api, params = arch_setup
    loss = api.loss_fn(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name


def test_grad_finite(arch_setup):
    name, cfg, api, params = arch_setup
    g = jax.grad(lambda p: api.loss_fn(p, _batch(cfg)))(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        ok = bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
        assert ok, (name, jax.tree_util.keystr(path))


def test_decode_shapes_and_finite(arch_setup):
    name, cfg, api, params = arch_setup
    pre = _batch(cfg)
    pre.pop("labels")
    logits, cache = api.prefill(params, pre, cache_len=T + 8)
    assert logits.shape == (B, 1, cfg.vocab)
    step = ({"tokens": jnp.ones((B, 1), jnp.int32)}
            if cfg.family != "vlm" else
            {"embeds": jax.random.normal(KEY, (B, 1, cfg.d_model),
                                         jnp.bfloat16)})
    lg, cache2 = api.decode_step(params, cache, step)
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32)))), name


def test_prefill_decode_matches_forward(arch_setup):
    """Teacher-forcing consistency: prefill(t0..tk) then decode(t_{k+1})
    must produce the same last-token logits as forward(t0..t_{k+1}).
    Run in f32 to keep the comparison tight."""
    name, cfg, api, params = arch_setup
    if cfg.family in ("vlm",):
        pytest.skip("embeds-input decode uses embedding lookup differently")
    # capacity-based MoE dispatch drops tokens batch-dependently — use the
    # exact dense dispatch for the consistency check
    kw = {"moe_dispatch": "dense"} if cfg.n_experts else {}
    t_full = 24
    params32 = jax.tree.map(
        lambda x: x.astype(jnp.float32)
        if x.dtype == jnp.bfloat16 else x, params)
    key = jax.random.fold_in(KEY, 5)
    toks = jax.random.randint(key, (B, t_full), 0, cfg.vocab)
    fb = {"tokens": toks}
    if cfg.family == "audio":
        fb["frames"] = jax.random.normal(key, (B, 32, cfg.d_model),
                                         jnp.float32) * 0.1
    full_logits = api.forward(params32, fb, remat=False, **kw)
    if isinstance(full_logits, tuple):
        full_logits = full_logits[0]

    pre = dict(fb)
    pre["tokens"] = toks[:, : t_full - 1]
    _, cache = api.prefill(params32, pre, cache_len=t_full + 4, **kw)
    lg, _ = api.decode_step(params32, cache,
                            {"tokens": toks[:, t_full - 1:]}, **kw)
    got = np.asarray(lg[:, -1], np.float32)
    want = np.asarray(full_logits[:, -1], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_param_count_sane(arch_setup):
    """init_params leaf count roughly matches config.params_count()."""
    name, cfg, api, params = arch_setup
    actual = sum(x.size for x in jax.tree.leaves(params))
    predicted = cfg.params_count()
    assert actual == pytest.approx(predicted, rel=0.35), \
        (name, actual, predicted)


def test_supported_shapes_shape():
    total = 0
    for a in ARCHS:
        sup = supported_shapes(a)
        assert set(sup) <= set(SHAPES)
        assert "train_4k" in sup
        total += len(SHAPES)
    assert total == 40


def test_full_param_counts_match_public_specs():
    """Full configs land near their nameplate sizes."""
    expect = {"stablelm_12b": 12e9, "qwen3_32b": 32e9, "gemma2_27b": 27e9,
              "mixtral_8x22b": 140e9, "deepseek_moe_16b": 16e9,
              "rwkv6_1_6b": 1.6e9, "hymba_1_5b": 1.5e9}
    for name, n in expect.items():
        cfg = get_config(name)
        assert cfg.params_count() == pytest.approx(n, rel=0.45), \
            (name, cfg.params_count())
