"""Span tracing, metrics and the cost ledger (DESIGN.md §11).

Four layers:

* `Tracer` invariants — nesting/depth bookkeeping (hypothesis over random
  span trees), ring-buffer bounds, deterministic root sampling with
  subtree drop, thread-local stacks, the disabled fast path;
* Chrome-trace export round-trip — the file json-loads, every complete
  event has non-negative ts/dur in a stable pid, one tid lane per thread,
  and parent/child containment survives the µs conversion;
* metrics semantics — counter monotonicity, gauge set/inc/dec, cumulative
  (Prometheus) histogram buckets, registry get-or-create + kind-mismatch
  rejection, JSON snapshot and text exposition round-trips;
* the cost ledger — `evaluate_plan_terms` reproduces `evaluate_plan`
  exactly, `CostBreakdown.scaled_to` sums to the target within 1e-6,
  `attribute_term_drift` recovers known per-term multipliers, and the
  `PlannerService.observe` path files ledger entries whose shares sum to
  the quoted prediction within 1e-6 (the PR's acceptance criterion).

The traced-vs-untraced numerical equivalence of a `strategy="plan"` sync
step runs in an 8-host-device subprocess (the test_sync_pipeline.py
pattern).
"""
import json
import os
import subprocess
import sys

import pytest

from _hypothesis_stub import given, settings, strategies as st

from repro.runtime.metrics import (Counter, Gauge, Histogram,
                                   MetricsRegistry, default_metrics)
from repro.runtime.trace import (Span, Tracer, default_tracer,
                                 set_default_tracer)


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------
class TestTracer:
    def test_disabled_records_nothing(self):
        tr = Tracer(enabled=False)
        with tr.span("a"):
            with tr.span("b"):
                pass
        tr.instant("c")
        assert tr.spans == []

    def test_nesting_depth_and_order(self):
        tr = Tracer(enabled=True)
        with tr.span("root", k=1):
            with tr.span("child"):
                with tr.span("grandchild"):
                    pass
            with tr.span("sibling"):
                pass
        spans = tr.spans
        # finished in leaf-first order
        assert [s.name for s in spans] == \
            ["grandchild", "child", "sibling", "root"]
        assert [s.depth for s in spans] == [2, 1, 1, 0]
        by_name = {s.name: s for s in spans}
        root, child = by_name["root"], by_name["child"]
        gchild = by_name["grandchild"]
        # containment: children start no earlier and end no later
        assert root.t0 <= child.t0 <= gchild.t0
        assert gchild.t1 <= child.t1 <= root.t1
        assert root.args == {"k": 1} and child.args is None

    def test_instant_is_zero_duration_at_current_depth(self):
        tr = Tracer(enabled=True)
        with tr.span("outer"):
            tr.instant("marker", why="test")
        marker = [s for s in tr.spans if s.name == "marker"][0]
        assert marker.t0 == marker.t1 and marker.depth == 1
        assert marker.duration_s == 0.0
        assert marker.args == {"why": "test"}

    def test_ring_buffer_bounds_memory(self):
        tr = Tracer(capacity=8, enabled=True)
        for i in range(50):
            with tr.span(f"s{i}"):
                pass
        spans = tr.spans
        assert len(spans) == 8
        assert [s.name for s in spans] == [f"s{i}" for i in range(42, 50)]

    def test_sampling_keeps_every_kth_root_with_subtree(self):
        tr = Tracer(enabled=True, sample_every=2)
        for i in range(6):
            with tr.span(f"root{i}"):
                with tr.span(f"inner{i}"):
                    pass
        names = [s.name for s in tr.spans]
        # roots 0, 2, 4 kept with their children; 1, 3, 5 fully dropped
        assert names == ["inner0", "root0", "inner2", "root2",
                         "inner4", "root4"]
        assert tr.dropped == 6       # 3 roots + 3 children

    def test_clear_resets_sampling_phase(self):
        tr = Tracer(enabled=True, sample_every=2)
        with tr.span("a"):
            pass
        tr.clear()
        assert tr.spans == [] and tr.dropped == 0
        with tr.span("b"):
            pass
        assert [s.name for s in tr.spans] == ["b"]   # phase restarted

    def test_exception_inside_span_still_finishes_it(self):
        tr = Tracer(enabled=True)
        with pytest.raises(RuntimeError):
            with tr.span("outer"):
                with tr.span("inner"):
                    raise RuntimeError("boom")
        assert [s.name for s in tr.spans] == ["inner", "outer"]
        assert [s.depth for s in tr.spans] == [1, 0]

    def test_threads_get_independent_stacks_and_tid_lanes(self):
        import threading
        tr = Tracer(enabled=True)

        def work(tag):
            with tr.span(f"outer-{tag}"):
                with tr.span(f"inner-{tag}"):
                    pass

        ts = [threading.Thread(target=work, args=(i,)) for i in range(3)]
        with tr.span("main"):
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        spans = tr.spans
        assert len(spans) == 7
        # per-thread depths are correct even with interleaving
        for s in spans:
            want = 0 if s.name.startswith(("outer", "main")) else 1
            assert s.depth == want, s
        # the main thread's lane is its own (worker idents may be reused
        # by the OS after a join, so workers aren't guaranteed 3 lanes)
        main_tid = {s.tid for s in spans if s.name == "main"}
        worker_tids = {s.tid for s in spans if s.name != "main"}
        assert main_tid and not (main_tid & worker_tids)

    def test_bad_args_rejected(self):
        with pytest.raises(ValueError):
            Tracer(capacity=0)
        with pytest.raises(ValueError):
            Tracer(sample_every=0)

    def test_default_tracer_swap(self):
        fresh = Tracer(enabled=True)
        old = set_default_tracer(fresh)
        try:
            assert default_tracer() is fresh
        finally:
            set_default_tracer(old)


@settings(max_examples=50, deadline=None)
@given(tree=st.recursive(
    st.integers(0, 3),
    lambda kids: st.lists(kids, min_size=1, max_size=3),
    max_leaves=12))
def test_span_tree_invariants(tree):
    """Random nesting structures: every node becomes exactly one span,
    depth equals nesting level, children close before parents, and
    siblings do not overlap."""
    tr = Tracer(enabled=True)
    expected = []

    def walk(node, depth, path):
        name = "/".join(map(str, path)) or "root"
        expected.append((name, depth))
        with tr.span(name):
            if isinstance(node, list):
                for i, kid in enumerate(node):
                    walk(kid, depth + 1, path + [i])

    walk(tree, 0, [])
    spans = tr.spans
    assert len(spans) == len(expected)
    got = {(s.name, s.depth) for s in spans}
    assert got == set(expected)
    by_name = {s.name: s for s in spans}
    for s in spans:
        if s.name == "root":
            continue
        parent = by_name["/" in s.name and s.name.rsplit("/", 1)[0]
                         or "root"]
        # containment: a child's window sits inside its parent's
        assert parent.t0 <= s.t0 and s.t1 <= parent.t1
        assert s.depth == parent.depth + 1
    # monotone: the recorded order is finish order
    t1s = [s.t1 for s in spans]
    assert t1s == sorted(t1s)


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------
class TestChromeExport:
    def _traced(self):
        tr = Tracer(enabled=True)
        with tr.span("step", idx=0):
            with tr.span("rs"):
                pass
            with tr.span("ag"):
                pass
        tr.instant("swap")
        return tr

    def test_round_trip_loads_and_is_well_formed(self, tmp_path):
        tr = self._traced()
        path = tmp_path / "trace.json"
        n = tr.export_chrome(str(path))
        doc = json.loads(path.read_text())
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        events = doc["traceEvents"]
        assert len(events) == n
        xs = [e for e in events if e["ph"] == "X"]
        metas = [e for e in events if e["ph"] == "M"]
        assert len(xs) == 4
        assert {m["name"] for m in metas} == {"process_name",
                                              "thread_name"}
        for e in xs:
            assert e["pid"] == 1 and e["tid"] == 0
            assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        # ts is relative to the earliest span: the root starts at 0
        step = [e for e in xs if e["name"] == "step"][0]
        assert step["ts"] == 0.0
        assert step["args"] == {"idx": 0}

    def test_containment_survives_unit_conversion(self, tmp_path):
        tr = self._traced()
        events = [e for e in tr.to_chrome() if e["ph"] == "X"]
        by = {e["name"]: e for e in events}
        for kid in ("rs", "ag"):
            assert by["step"]["ts"] <= by[kid]["ts"]
            assert by[kid]["ts"] + by[kid]["dur"] <= \
                by["step"]["ts"] + by["step"]["dur"] + 1e-9

    def test_empty_tracer_exports_empty_list(self, tmp_path):
        tr = Tracer(enabled=True)
        path = tmp_path / "empty.json"
        assert tr.export_chrome(str(path)) == 0
        assert json.loads(path.read_text())["traceEvents"] == []

    def test_tid_lanes_stable_per_thread(self):
        import threading
        tr = Tracer(enabled=True)

        def work():
            with tr.span("w1"):
                pass
            with tr.span("w2"):
                pass

        t = threading.Thread(target=work)
        with tr.span("m1"):
            pass
        t.start()
        t.join()
        with tr.span("m2"):
            pass
        xs = [e for e in tr.to_chrome() if e["ph"] == "X"]
        lanes = {}
        for e in xs:
            lanes.setdefault(e["name"][0], set()).add(e["tid"])
        # both main spans share one lane, both worker spans another
        assert len(lanes["m"]) == 1 and len(lanes["w"]) == 1
        assert lanes["m"] != lanes["w"]


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_counter_monotone(self):
        c = Counter("hits_total")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_set_inc_dec(self):
        g = Gauge("occupancy")
        g.set(0.75)
        g.inc(0.05)
        g.dec(0.30)
        assert g.value == pytest.approx(0.5)

    def test_histogram_cumulative_semantics(self):
        h = Histogram("lat", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 0.5, 5.0, 50.0):
            h.observe(v)
        assert h.count == 5 and h.sum == pytest.approx(56.05)
        cum = h.cumulative()
        assert cum == [(0.1, 1), (1.0, 3), (10.0, 4), (float("inf"), 5)]
        # boundary lands in the bucket whose upper bound it equals
        h2 = Histogram("edge", buckets=(1.0,))
        h2.observe(1.0)
        assert h2.cumulative()[0] == (1.0, 1)

    def test_registry_get_or_create_and_kind_mismatch(self):
        reg = MetricsRegistry()
        c = reg.counter("a_total", "help")
        assert reg.counter("a_total") is c
        with pytest.raises(TypeError):
            reg.gauge("a_total")
        assert reg.histogram("h").bounds == \
            reg.histogram("h").bounds

    def test_snapshot_shape(self):
        reg = MetricsRegistry()
        reg.counter("c_total").inc(2)
        reg.gauge("g").set(1.5)
        reg.histogram("h", buckets=(1.0,)).observe(0.5)
        snap = reg.snapshot()
        assert snap["c_total"] == {"type": "counter", "value": 2.0}
        assert snap["g"] == {"type": "gauge", "value": 1.5}
        assert snap["h"]["count"] == 1
        assert snap["h"]["buckets"][-1] == ["+Inf", 1]
        json.dumps(snap)    # JSON-safe (no raw inf)

    def test_prometheus_text_format(self):
        reg = MetricsRegistry()
        reg.counter("hits_total", "cache hits").inc(3)
        reg.histogram("lat_seconds", buckets=(0.5,)).observe(0.1)
        text = reg.to_prometheus()
        assert "# HELP hits_total cache hits" in text
        assert "# TYPE hits_total counter" in text
        assert "hits_total 3" in text
        assert 'lat_seconds_bucket{le="0.5"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 1' in text
        assert "lat_seconds_sum 0.1" in text
        assert "lat_seconds_count 1" in text

    def test_export_writes_json_and_prom(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("c_total").inc()
        path = tmp_path / "m.json"
        snap = reg.export(str(path))
        assert json.loads(path.read_text()) == snap
        prom = (tmp_path / "m.prom").read_text()
        assert "c_total 1" in prom

    def test_default_registry_shared_by_instrumentation(self):
        from repro.planner.cache import PlanCache
        base = default_metrics().counter("plan_cache_misses_total").value
        PlanCache(capacity=4).get("nope")
        assert default_metrics().counter(
            "plan_cache_misses_total").value == base + 1


# ---------------------------------------------------------------------------
# Cost ledger: per-term decomposition + drift attribution
# ---------------------------------------------------------------------------
class TestCostBreakdown:
    def _plans(self):
        from repro.core.plans import cps, reduce_broadcast, rhd, ring
        return [f(8, 4e6) for f in (ring, rhd, cps, reduce_broadcast)]

    def test_terms_reproduce_evaluate_plan(self):
        from repro.core.cost_model import (PAPER_TABLE5, evaluate_plan,
                                           evaluate_plan_terms)
        p = PAPER_TABLE5["root_sw"]
        for plan in self._plans():
            bd = evaluate_plan_terms(plan, p)
            assert bd.total == pytest.approx(evaluate_plan(plan, p),
                                             rel=1e-12)
            assert all(getattr(bd, t) >= 0.0 for t in bd.TERMS)

    def test_scaled_to_sums_exactly(self):
        from repro.core.cost_model import (PAPER_TABLE5,
                                           evaluate_plan_terms)
        p = PAPER_TABLE5["root_sw"]
        for plan in self._plans():
            for target in (1.0, 3.7e-3, 12.5):
                sc = evaluate_plan_terms(plan, p).scaled_to(target)
                assert sum(sc.as_dict().values()) == \
                    pytest.approx(target, abs=1e-6)

    def test_zero_breakdown_books_alpha(self):
        from repro.core.cost_model import CostBreakdown
        sc = CostBreakdown().scaled_to(2.0)
        assert sc.alpha == 2.0 and sc.total == 2.0
        assert CostBreakdown().shares() == \
            {t: 0.0 for t in CostBreakdown.TERMS}

    def test_shares_are_fractions(self):
        from repro.core.cost_model import PAPER_TABLE5, evaluate_plan_terms
        bd = evaluate_plan_terms(self._plans()[0],
                                 PAPER_TABLE5["root_sw"])
        sh = bd.shares()
        assert sum(sh.values()) == pytest.approx(1.0)
        assert all(0.0 <= v <= 1.0 for v in sh.values())


class TestTermAttribution:
    def test_recovers_known_multipliers(self):
        from repro.core.fitting import attribute_term_drift
        shares = [
            {"alpha": 1.0, "beta": 2.0, "gamma": 0.0, "delta": 0.5,
             "incast": 0.0},
            {"alpha": 2.0, "beta": 1.0, "gamma": 0.0, "delta": 1.0,
             "incast": 0.0},
            {"alpha": 0.5, "beta": 4.0, "gamma": 0.0, "delta": 2.0,
             "incast": 0.0},
        ]
        # cluster truth: β costs 3x the model's price, α and δ on-model
        measured = [s["alpha"] + 3.0 * s["beta"] + s["delta"]
                    for s in shares]
        m = attribute_term_drift(shares, measured)
        assert m["alpha"] == pytest.approx(1.0, abs=1e-8)
        assert m["beta"] == pytest.approx(3.0, abs=1e-8)
        assert m["delta"] == pytest.approx(1.0, abs=1e-8)
        # terms with zero predicted share cannot be attributed
        assert m["gamma"] is None and m["incast"] is None

    def test_empty_and_mismatched_windows(self):
        from repro.core.fitting import TERM_NAMES, attribute_term_drift
        assert attribute_term_drift([], []) == \
            {t: None for t in TERM_NAMES}
        with pytest.raises(ValueError):
            attribute_term_drift([{"alpha": 1.0}], [])


class TestObserveLedger:
    def _service(self):
        from repro.planner.service import PlannerService, RefitPolicy
        return PlannerService(refit_policy=RefitPolicy(enabled=False))

    def test_shares_sum_to_predicted_within_1e6(self):
        svc = self._service()
        for n, size in [(8, 1e6), (8, 4e6), (4, 1e6), (16, 2e6)]:
            out = svc.observe("root_sw", n, size, measured=1e-3)
            e = svc.telemetry.ledger.entries("root_sw")[-1]
            assert sum(e.shares.values()) == \
                pytest.approx(e.predicted, abs=1e-6)
            assert e.predicted == pytest.approx(out["predicted"])
            assert set(e.shares) == {"alpha", "beta", "gamma", "delta",
                                     "incast"}

    def test_ledger_window_grows_and_override_excluded(self):
        from repro.core.cost_model import TPU_V5E
        svc = self._service()
        svc.observe("root_sw", 8, 1e6, 1e-3)
        svc.observe("root_sw", 8, 1e6, 1e-3)
        assert svc.telemetry.ledger.count("root_sw") == 2
        # per-request params overrides are monitoring-only
        svc.observe("root_sw", 8, 1e6, 1e-3, params=TPU_V5E)
        assert svc.telemetry.ledger.count("root_sw") == 2

    def test_refit_event_names_drifting_term(self):
        from repro.core.cost_model import PAPER_TABLE5
        from repro.core.simulator import Simulator
        from repro.core.sync import level_switch_topo
        from repro.planner.service import PlannerService, RefitPolicy
        import dataclasses as dc
        wrong = dict(PAPER_TABLE5)
        wrong["root_sw"] = dc.replace(
            PAPER_TABLE5["root_sw"],
            beta=PAPER_TABLE5["root_sw"].beta / 6)
        svc = PlannerService(params=wrong,
                             refit_policy=RefitPolicy(min_samples=6,
                                                      drift_threshold=0.15,
                                                      cooldown=6))
        sizes = [(8, 1e6), (8, 4e6), (4, 1e6), (8, 1.6e7), (4, 4e6),
                 (8, 2e6), (8, 8e6), (4, 2e6)]
        refit_events = []
        for n, size in sizes * 3:
            resp = svc.get_axis_executable("data", n, size,
                                           level="root_sw")
            topo = level_switch_topo(n, PAPER_TABLE5, "root_sw")
            meas = Simulator(topo, PAPER_TABLE5,
                             unit_bytes=4).simulate(resp.plan).total
            out = svc.observe("root_sw", n, size, meas,
                              predicted=resp.predicted_time,
                              key=resp.key)
            if out["refit"]:
                refit_events = [r for r in svc.refits
                                if r["level"] == "root_sw"]
                break
        assert refit_events, "mis-seeded β never triggered a refit"
        td = refit_events[-1]["term_drift"]
        assert td is not None
        from repro.core.fitting import TERM_NAMES
        assert set(td) == set(TERM_NAMES)
        # β is 6x under-priced. The size-proportional columns (β, γ, δ)
        # are collinear over single-switch plans, so least squares may
        # split the drift among them — but the diagnosis must show the
        # model under-pricing SOMEWHERE well above the stable terms.
        attributed = {k: v for k, v in td.items() if v is not None}
        assert attributed and max(attributed.values()) > 1.5
        # the same event rides the telemetry event log
        ev = [e for e in svc.telemetry.events if e.kind == "refit"][-1]
        assert ev.info["term_drift"] == td

    def test_term_attribution_can_be_disabled(self):
        from repro.planner.service import RefitPolicy
        pol = RefitPolicy(term_attribution=False)
        assert pol.term_attribution is False


# ---------------------------------------------------------------------------
# Traced == untraced: spans must never perturb the numerics
# ---------------------------------------------------------------------------
_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.core.sync import SyncConfig, sync_gradients
from repro.runtime.trace import Tracer, set_default_tracer

AXES = [("x", 8)]
CFG = SyncConfig(strategy="plan", bucket_bytes=4096)


def run_once():
    mesh = jax.make_mesh((8,), ("x",))
    key = jax.random.PRNGKey(7)
    grads = {}
    for i, size in enumerate((1024, 517, 33)):
        key, sub = jax.random.split(key)
        grads[f"l{i}"] = jax.random.normal(sub, (8, size), jnp.float32)
    f = shard_map(
        lambda g: jax.tree.map(
            lambda v: v[None],
            sync_gradients(jax.tree.map(lambda v: v[0], g), AXES, CFG)),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    out = jax.jit(f)(grads)
    return {k: np.asarray(v) for k, v in out.items()}


set_default_tracer(Tracer(enabled=False))
untraced = run_once()

traced_tracer = Tracer(enabled=True)
set_default_tracer(traced_tracer)
traced = run_once()

results = {}
worst = 0.0
for k in untraced:
    diff = np.abs(untraced[k].astype(np.float64)
                  - traced[k].astype(np.float64)).max()
    scale = np.abs(untraced[k]).max() + 1e-30
    worst = max(worst, float(diff / scale))
results["max_rel_diff"] = worst
results["equal_within_1e6"] = bool(worst < 1e-6)
names = {s.name for s in traced_tracer.spans}
results["traced_span_count"] = len(traced_tracer.spans)
results["has_sync_span"] = "sync/bucketed" in names
results["has_round_span"] = "exec/round" in names
print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def diff_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def test_traced_sync_equals_untraced(diff_results):
    assert diff_results["equal_within_1e6"], diff_results


def test_traced_sync_recorded_expected_spans(diff_results):
    assert diff_results["traced_span_count"] > 0
    assert diff_results["has_sync_span"]
    assert diff_results["has_round_span"]
