"""Bucketed, double-buffered plan execution — conformance blitz (DESIGN.md §9).

Three layers:

* pure model/partition tests (no devices): dtype-homogeneous size-bounded
  partitioning, the two-stage pipeline time model, and
  `PlannerService.get_bucket_plan` — the chosen bucket size must be the
  GenModel argmin of the sweep, the modeled pipelined time must beat both
  the serial and the per-leaf baselines, schedules must be cached (warm
  hits) and droppable (`invalidate_executables`);
* an 8-host-device subprocess (the test_collectives.py pattern) running
  the differential fuzz: random pytrees — mixed f32/bf16 leaves, scalars,
  odd sizes, empty leaves — synced with bucketed
  `sync_gradients(strategy="plan")` must equal `lax.psum` within dtype
  tolerance (f32 @ 1e-6), on a single axis AND a two-level Table-6-style
  (data × pod) mesh, with auto, pinned-small, unpipelined and disabled
  bucketing;
* `allreduce_planned` bucketing: chunked pipelined execution with stats,
  and the flat-label fallback — it must warn once, record its reason in
  the stats dict, note that the bucketing config was ignored, and still
  match psum.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from _hypothesis_stub import given, settings, strategies as st

from repro.core.bucketing import (BucketConfig, partition, pipelined_time,
                                  serial_time)
from repro.planner.service import PlannerService


# ---------------------------------------------------------------------------
# partition (pure)
# ---------------------------------------------------------------------------
def test_partition_basic_shapes():
    sizes = [5, 0, 3, 100, 1, 7]
    dtypes = ["f32", "f32", "bf16", "f32", "bf16", "f32"]
    bks = partition(sizes, dtypes, 10)
    # every nonzero leaf exactly once, empty leaves in no bucket
    seen = [i for bk in bks for i in bk.indices]
    assert sorted(seen) == [0, 2, 3, 4, 5]
    for bk in bks:
        assert len({str(dtypes[i]) for i in bk.indices}) == 1
        assert bk.size <= 10 or len(bk.indices) == 1   # oversized ride alone
    # deterministic: ordered by first member, order preserved within dtype
    assert bks == partition(sizes, dtypes, 10)
    firsts = [bk.indices[0] for bk in bks]
    assert firsts == sorted(firsts)


@settings(max_examples=50, deadline=None)
@given(leaves=st.lists(st.tuples(st.integers(0, 40),
                                 st.sampled_from(["float32", "bfloat16"])),
                       max_size=30),
       cap=st.integers(1, 64))
def test_partition_properties(leaves, cap):
    sizes = [s for s, _ in leaves]
    dtypes = [d for _, d in leaves]
    bks = partition(sizes, dtypes, cap)
    seen = sorted(i for bk in bks for i in bk.indices)
    assert seen == [i for i, s in enumerate(sizes) if s > 0]
    for bk in bks:
        assert len({str(dtypes[i]) for i in bk.indices}) == 1
        assert bk.size <= cap or len(bk.indices) == 1
        assert bk.sizes == tuple(sizes[i] for i in bk.indices)
        # order-preserving within the bucket
        assert list(bk.indices) == sorted(bk.indices)


def test_partition_byte_cap_spans_dtypes():
    """With itemsizes, ONE byte budget binds every dtype class: under an
    1100 B cap, two 256-element f32 leaves (1024 B each) must split while
    two 256-element bf16 leaves (512 B each) share a bucket — an
    element-only cap would treat them identically."""
    sizes = [256, 256, 256, 256]
    dtypes = ["float32", "float32", "bfloat16", "bfloat16"]
    bks = partition(sizes, dtypes, 1100, itemsizes=[4, 4, 2, 2])
    f32 = [bk.indices for bk in bks if bk.dtype == "float32"]
    bf16 = [bk.indices for bk in bks if bk.dtype == "bfloat16"]
    assert f32 == [(0,), (1,)]      # 2 x 1024 B exceeds the cap
    assert bf16 == [(2, 3)]         # 2 x 512 B fits
    # element-count mode unchanged: all four leaves are 256 elements
    bks_el = partition(sizes, dtypes, 512)
    assert [bk.indices for bk in bks_el] == [(0, 1), (2, 3)]


def test_pipeline_time_model():
    # overlap can never lose; K=1 degenerates to serial
    assert pipelined_time(3.0, 2.0, 1) == serial_time(3.0, 2.0, 1)
    for k in (2, 5, 17):
        assert pipelined_time(3.0, 2.0, k) < serial_time(3.0, 2.0, k)
        assert pipelined_time(3.0, 2.0, k) == 3.0 + (k - 1) * 3.0 + 2.0


# ---------------------------------------------------------------------------
# get_bucket_plan (model only — no devices)
# ---------------------------------------------------------------------------
class TestGetBucketPlan:
    AXES = [("data", 16), ("pod", 4)]
    LEAVES = [50000] * 180 + [1000] * 20

    def test_argmin_and_baselines(self):
        svc = PlannerService()
        bp = svc.get_bucket_plan(self.AXES, 1e7, leaf_sizes=self.LEAVES)
        # the honest rank (DESIGN.md §15): contended pipeline estimate,
        # sandwiched between the optimistic pipeline and serial models
        assert bp.bucket_floats == min(
            bp.sweep, key=lambda b: (bp.sweep[b]["contended"], b))
        assert bp.predicted_pipelined <= bp.predicted_contended
        assert bp.predicted_contended <= bp.predicted_serial + 1e-15
        assert bp.predicted_contended < bp.predicted_per_leaf
        # the sweep explored both directions around the argmin: the trade
        # (α + γ/δ floor vs serialization ceiling) has an interior optimum
        assert len(bp.sweep) > 2
        # one lowered schedule per live axis, sized to the axis
        assert [(p.axis, p.schedule.n) for p in bp.axis_plans] == \
            [("data", 16), ("pod", 4)]

    def test_warm_hit_and_schedule_reuse(self):
        svc = PlannerService()
        b1 = svc.get_bucket_plan(self.AXES, 1e7)
        b2 = svc.get_bucket_plan(self.AXES, 1e7)
        assert b1.source == "cold" and b2.source == "memory"
        # same CompiledSchedule object — cached on the plan entry,
        # never re-lowered per step
        assert b1.axis_plans[0].schedule is b2.axis_plans[0].schedule

    def test_pinned_bucket_bytes(self):
        svc = PlannerService()
        bp = svc.get_bucket_plan(self.AXES, 1e6,
                                 config=BucketConfig(bucket_bytes=1 << 20))
        assert bp.bucket_floats == (1 << 20) // 4
        assert list(bp.sweep) == [bp.bucket_floats]

    def test_n1_axes_skipped_but_keep_level(self):
        svc = PlannerService()
        bp = svc.get_bucket_plan([("data", 8), ("model", 1)], 1e5)
        assert [a for a, _ in bp.axes] == ["data"]
        bp2 = svc.get_bucket_plan([("model", 1), ("data", 1)], 1e5)
        assert bp2.axes == () and bp2.axis_plans == []

    def test_precision_sweep_and_tolerance_cache(self):
        """Joint (bucket × precision) argmin (DESIGN.md §13): a tolerance
        opens lossy wire candidates, the chosen precision rides the
        sweep rows, and a tolerance change is a cold cache miss — a
        compressed plan is never served to a caller whose error budget
        changed."""
        svc = PlannerService()
        b1 = svc.get_bucket_plan(self.AXES, 1e7,
                                 config=BucketConfig(tolerance=0.3))
        assert b1.source == "cold"
        assert all("precision" in row for row in b1.sweep.values())
        # compression shrinks β·S: on the default params the sweep must
        # pick a lossy wire, and it must price no worse than lossless
        assert b1.precision in ("bf16", "fp8", "int8")
        b_full = svc.get_bucket_plan(self.AXES, 1e7)
        assert b_full.source == "cold" and b_full.precision == "f32"
        assert b1.predicted_pipelined <= b_full.predicted_pipelined
        # warm hit preserves the choice and the wire-bound schedules
        b2 = svc.get_bucket_plan(self.AXES, 1e7,
                                 config=BucketConfig(tolerance=0.3))
        assert b2.source == "memory" and b2.precision == b1.precision
        assert b2.axis_plans[0].schedule is b1.axis_plans[0].schedule
        # tolerance below every lossy budget clamps to lossless — and is
        # its own cache entry (cold), not a stale compressed plan
        b3 = svc.get_bucket_plan(self.AXES, 1e7,
                                 config=BucketConfig(tolerance=0.001))
        assert b3.source == "cold" and b3.precision == "f32"

    def test_precision_pinned_and_clamped(self):
        svc = PlannerService()
        bp = svc.get_bucket_plan(
            self.AXES, 1e6,
            config=BucketConfig(precision="fp8", tolerance=0.3))
        assert bp.precision == "fp8"
        for pl in bp.axis_plans:
            assert pl.schedule.wire is not None
            assert pl.schedule.wire.name == "fp8"
        # a pinned precision whose budget exceeds the tolerance clamps
        # to full precision (resolve_precision), wire stripped
        clamped = svc.get_bucket_plan(
            self.AXES, 1e6,
            config=BucketConfig(precision="fp8", tolerance=0.001))
        assert clamped.precision == "f32"
        assert all(pl.schedule.wire is None for pl in clamped.axis_plans)
        # the wire variant is a distinct object from the f32 user's
        # schedule (guard demotion state must not cross wires)
        assert bp.axis_plans[0].schedule is not \
            clamped.axis_plans[0].schedule

    def test_invalidate_drops_schedules(self):
        svc = PlannerService()
        svc.get_bucket_plan(self.AXES, 1e6)
        assert svc.executable_count() > 0
        dropped = svc.invalidate_executables()
        assert dropped > 0 and svc.executable_count() == 0
        # rebuild is cold for the bucket plan but re-lowers fine
        bp = svc.get_bucket_plan(self.AXES, 1e6)
        assert bp.source == "cold"
        assert all(p.schedule is not None for p in bp.axis_plans)


# ---------------------------------------------------------------------------
# executed conformance on 8 host devices (subprocess)
# ---------------------------------------------------------------------------
_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, warnings
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.core import collectives as C
from repro.core.bucketing import BucketConfig
from repro.core.sync import SyncConfig, sync_gradients

results = {}
TOL = {"float32": 1e-6, "bfloat16": 0.05}


def sync_out(tree, axes, mesh_shape, cfg):
    '''The synced tree (and the psum reference) on the sharded mesh.'''
    mesh = jax.make_mesh(mesh_shape, tuple(a for a, _ in reversed(axes)))
    names = tuple(a for a, n in axes if n > 1)
    spec = P(*(a for a, _ in reversed(axes)))
    nlead = len(mesh_shape)

    def local(g):
        return jax.tree.map(lambda v: v[(0,) * nlead], g)

    def lift(g):
        return jax.tree.map(lambda v: v[(None,) * nlead], g)

    f = shard_map(lambda g: lift(sync_gradients(local(g), axes, cfg)),
                  mesh=mesh, in_specs=spec, out_specs=spec)
    p = shard_map(lambda g: lift(jax.tree.map(
        lambda v: jax.lax.psum(v, names), local(g))),
                  mesh=mesh, in_specs=spec, out_specs=spec)
    return jax.jit(f)(tree), jax.jit(p)(tree)


def run_case(tree, axes, mesh_shape, cfg, seed=0, wire_budget=0.0):
    '''Per-leaf max relative error of bucketed sync vs lax.psum,
    normalized to max(dtype tolerance, wire error budget).'''
    got, want = sync_out(tree, axes, mesh_shape, cfg)
    worst = 0.0
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        if w.size == 0:
            assert g.size == 0
            continue
        assert g.dtype == w.dtype    # wire compression must not leak out
        tol = max(TOL[str(w.dtype)], wire_budget)
        a = np.asarray(g, np.float64)
        b = np.asarray(w, np.float64)
        err = np.abs(a - b).max() / (np.abs(b).max() + 1e-30)
        worst = max(worst, err / tol)   # normalized to the tolerance
    return worst


def mixed_tree(key, specs):
    leaves = []
    for i, (size, dtype, ndim) in enumerate(specs):
        key, sub = jax.random.split(key)
        shape = () if ndim == 0 else (size,)
        x = jax.random.normal(sub, (8,) + shape, jnp.float32)
        leaves.append(x.astype(dtype))
    return {"leaf%02d" % i: v for i, v in enumerate(leaves)}


FIXED = [(15, jnp.float32, 1), (0, jnp.float32, 1), (1, jnp.float32, 0),
         (129, jnp.bfloat16, 1), (37, jnp.float32, 1),
         (17, jnp.bfloat16, 1), (257, jnp.float32, 1)]
tree = mixed_tree(jax.random.PRNGKey(0), FIXED)

CONFIGS = {
    "auto": SyncConfig(strategy="plan"),
    "small": SyncConfig(strategy="plan", bucket_bytes=256),
    "serial": SyncConfig(strategy="plan", bucket_bytes=256, pipeline=False),
    "off": SyncConfig(strategy="plan", bucket_bytes=0),
}
for name, cfg in CONFIGS.items():
    results[f"fixed_{name}"] = bool(
        run_case(tree, [("x", 8)], (8,), cfg) < 1.0)

# ---- two-level Table-6-style mesh (data x pod) ----------------------------
tree2 = jax.tree.map(lambda v: v.reshape((2, 4) + v.shape[1:]), tree)
for name in ("auto", "small"):
    results[f"table6_{name}"] = bool(run_case(
        tree2, [("data", 4), ("pod", 2)], (2, 4), CONFIGS[name]) < 1.0)

# ---- compressed wire (DESIGN.md §13): plan ≡ psum within the budget -------
from repro.core.cost_model import PRECISIONS
QCASES = {
    "fp8_pin": (SyncConfig(strategy="plan", precision="fp8",
                           tolerance=0.3),
                PRECISIONS["fp8"].error_budget),
    "int8_pin": (SyncConfig(strategy="plan", precision="int8",
                            tolerance=0.3),
                 PRECISIONS["int8"].error_budget),
    "tol_sweep": (SyncConfig(strategy="plan", tolerance=0.3),
                  PRECISIONS["fp8"].error_budget),
    "int8_leaf": (SyncConfig(strategy="plan", bucket_bytes=0,
                             precision="int8", tolerance=0.3),
                  PRECISIONS["int8"].error_budget),
}
for name, (qcfg, budget) in QCASES.items():
    results[f"quant_{name}"] = bool(run_case(
        tree, [("x", 8)], (8,), qcfg, wire_budget=budget) < 1.0)
results["quant_table6_fp8"] = bool(run_case(
    tree2, [("data", 4), ("pod", 2)], (2, 4), QCASES["fp8_pin"][0],
    wire_budget=QCASES["fp8_pin"][1]) < 1.0)

# pinning precision="f32" must be BIT-IDENTICAL to the default planned
# path — the wire machinery is stripped, not run at unit scale
g_plain, _ = sync_out(tree, [("x", 8)], (8,), CONFIGS["auto"])
g_f32, _ = sync_out(tree, [("x", 8)], (8,),
                    SyncConfig(strategy="plan", precision="f32",
                               tolerance=0.5))
results["quant_f32_exact"] = bool(all(
    np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_f32))))

# ---- allreduce_planned: chunked pipelined buckets + stats -----------------
mesh = jax.make_mesh((8,), ("x",))
xa = jnp.arange(8 * 133, dtype=jnp.float32).reshape(8, 133)
stats = {}
f = shard_map(lambda v: C.allreduce_planned(
        v[0], "x", bucketing=BucketConfig(bucket_bytes=128),
        stats=stats)[None],
    mesh=mesh, in_specs=P("x"), out_specs=P("x"))
out = np.asarray(jax.jit(f)(xa))
results["planned_bucketed"] = bool(
    np.allclose(out, np.tile(np.asarray(xa.sum(0)), (8, 1)), rtol=1e-5)
    and stats["mode"] == "bucketed" and stats["num_buckets"] > 1)

# ---- allreduce_planned fallback: warn once + stats record -----------------
from repro.planner.service import PlannerService
from repro.core.sync import level_switch_topo
from repro.core.cost_model import TPU_V5E
svc = PlannerService()
topo = level_switch_topo(8, TPU_V5E, "root_sw")
resp = svc.get_plan(topo, 133 * 4.0, params=TPU_V5E)
resp.plan.num_blocks = None          # legacy / unannotated cache entry
st1, st2 = {}, {}
C._planned_fallback_warned = False
with warnings.catch_warnings(record=True) as wlist:
    warnings.simplefilter("always")
    g = shard_map(lambda v: C.allreduce_planned(
            v[0], "x", service=svc,
            bucketing=BucketConfig(bucket_bytes=128), stats=st1)[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    out1 = np.asarray(jax.jit(g)(xa))
    g2 = shard_map(lambda v: C.allreduce_planned(
            v[0], "x", service=svc, stats=st2)[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    np.asarray(jax.jit(g2)(xa))
fallback_warns = [w for w in wlist
                  if "flat plan-type labels" in str(w.message)]
results["fallback_correct"] = bool(np.allclose(
    out1, np.tile(np.asarray(xa.sum(0)), (8, 1)), rtol=1e-5))
results["fallback_stats"] = bool(
    st1["mode"] == "flat-label" and "no block annotations" in
    st1["fallback_reason"] and st1["bucketing_ignored"] is True
    and st2["mode"] == "flat-label"
    and st2["bucketing_ignored"] is False)
results["fallback_warns_once"] = len(fallback_warns) == 1

# ---- hypothesis differential fuzz (runs when hypothesis is installed) -----
try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False
results["hypothesis_ran"] = HAVE_HYP
if HAVE_HYP:
    leaf_spec = hst.tuples(hst.integers(0, 64),
                           hst.sampled_from([jnp.float32, jnp.bfloat16]),
                           hst.integers(0, 1))

    @settings(max_examples=10, deadline=None)
    @given(specs=hst.lists(leaf_spec, min_size=1, max_size=8),
           bucket=hst.sampled_from([None, 128, 512]),
           pipeline=hst.booleans(),
           two_level=hst.booleans(),
           seed=hst.integers(0, 10 ** 6))
    def fuzz(specs, bucket, pipeline, two_level, seed):
        cfg = SyncConfig(strategy="plan", bucket_bytes=bucket,
                         pipeline=pipeline)
        t = mixed_tree(jax.random.PRNGKey(seed), specs)
        if two_level:
            t = jax.tree.map(
                lambda v: v.reshape((2, 4) + v.shape[1:]), t)
            worst = run_case(t, [("data", 4), ("pod", 2)], (2, 4), cfg)
        else:
            worst = run_case(t, [("x", 8)], (8,), cfg)
        assert worst < 1.0, (specs, bucket, pipeline, two_level, worst)

    try:
        fuzz()
        results["hypothesis_fuzz"] = True
    except Exception as e:
        results["hypothesis_fuzz"] = False
        results["hypothesis_error"] = repr(e)[:500]

print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


@pytest.mark.parametrize("key", [
    "fixed_auto", "fixed_small", "fixed_serial", "fixed_off",
    "table6_auto", "table6_small",
    "quant_fp8_pin", "quant_int8_pin", "quant_tol_sweep",
    "quant_int8_leaf", "quant_table6_fp8", "quant_f32_exact",
    "planned_bucketed",
    "fallback_correct", "fallback_stats", "fallback_warns_once"])
def test_bucketed_sync(results, key):
    assert results[key] is True, (key, results)


def test_hypothesis_fuzz_when_available(results):
    if not results["hypothesis_ran"]:
        pytest.skip("hypothesis not installed")
    assert results["hypothesis_fuzz"] is True, results.get(
        "hypothesis_error")
