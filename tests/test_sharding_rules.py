"""Property tests for the launcher's sharding rules: every generated
PartitionSpec must be valid for its shape on the production mesh (each
named axis divides the corresponding dim; no mesh axis used twice)."""
import jax
import numpy as np
import pytest
from _hypothesis_stub import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shr


class FakeMesh:
    """Shape/axis-name stand-in (leaf_spec only reads these)."""
    def __init__(self, shape, names):
        self.devices = np.empty(shape)
        self.axis_names = names


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))
SIZES = dict(zip(MESH.axis_names, MESH.devices.shape))


def _check_valid(spec: P, shape, sizes):
    used = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for nm in names:
            assert nm not in used, f"axis {nm} used twice in {spec}"
            used.append(nm)
            total *= sizes[nm]
        assert dim % total == 0, (spec, shape)


dims = st.integers(1, 9).map(lambda k: [1, 2, 3, 8, 16, 64, 100, 128,
                                        4096][k - 1])


@settings(max_examples=100, deadline=None)
@given(shape=st.lists(dims, min_size=1, max_size=4))
def test_leaf_spec_always_valid(shape):
    spec = shr.leaf_spec(tuple(shape), MESH)
    _check_valid(spec, shape, SIZES)


@settings(max_examples=60, deadline=None)
@given(shape=st.lists(dims, min_size=2, max_size=5))
def test_leaf_spec_never_shards_layer_axis(shape):
    spec = shr.leaf_spec(tuple(shape), MESH, skip_first=True)
    entries = tuple(spec)
    if entries:
        assert entries[0] is None


def test_known_param_layouts():
    # attention projection (L, D, H·hd): TP on the output, FSDP on D
    spec = shr.leaf_spec((40, 5120, 5120), MESH)
    assert "model" in tuple(spec) and "data" in tuple(spec)
    # small norm scale replicates (spec entries all None)
    assert all(e is None for e in tuple(shr.leaf_spec((5120,), MESH)))
    # embedding (V, D)
    spec = shr.leaf_spec((100352, 5120), MESH, skip_first=False)
    _check_valid(spec, (100352, 5120), SIZES)


def test_cache_specs_never_shard_sequence_and_heads_together():
    import jax.numpy as jnp
    cache = {
        "k": jax.ShapeDtypeStruct((40, 128, 8, 32768, 160), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((40, 128, 8, 32768, 160), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((128,), jnp.int32),
    }
    specs = shr.cache_specs(cache, MESH)
    for name in ("k", "v"):
        entries = tuple(specs[name])
        model_dims = [i for i, e in enumerate(entries) if e == "model"]
        assert len(model_dims) <= 1


@settings(max_examples=40, deadline=None)
@given(b=st.sampled_from([1, 2, 16, 32, 128, 256, 512]),
       t=st.sampled_from([1, 128, 4096]))
def test_batch_specs_divisibility(b, t):
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
    spec = shr.batch_specs(batch, MESH)["tokens"]
    _check_valid(spec, (b, t), SIZES)
    spec3 = shr.batch_specs(batch, MESH3)["tokens"]
    _check_valid(spec3, (b, t),
                 dict(zip(MESH3.axis_names, MESH3.devices.shape)))
