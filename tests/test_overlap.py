"""Cross-family overlap scheduling (DESIGN.md §15).

Three layers, mirroring the subsystem:

* Pricing — `FastEngine.contended_halves_total` (vectorized occupancy
  merge) must agree with the reference `cost_model.contended_pair_time`
  walk at 1e-9 on every topology class, sit inside the
  [max, adversarial] envelope, and the `contended_pipelined_time` /
  `overlap_certificate` algebra must clamp and sandwich correctly.
* Merging — `plan_merge` validates the cross-schedule contract,
  `MergedSchedule.run_numpy_pair` must be numerically identical to the
  sequential constituents under EVERY order-preserving interleaving
  (hypothesis sweep over shuffled token streams).
* Planning — `PlannerService.get_bucket_plan` may select merged
  issuance ONLY when the contended price beats sequential
  (planner-never-selects-a-losing-merge), and must still select it
  somewhere (both modes are live, not a constant fallback).

The 8-device differential (merged rs_ag ≡ sequential RS+AG ≡ lax
references at 1e-6 on the Table-6 two-level mesh, plus the int8
wire-compressed variant) runs in one subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8, like
test_exec_equivalence.py.
"""
import json
import os
import random
import subprocess
import sys

import numpy as np
import pytest

from _hypothesis_stub import given, settings, strategies as st

from repro.core import topology
from repro.core.bucketing import (BucketConfig, contended_pipelined_time,
                                  pipelined_time, serial_time)
from repro.core.cost_model import contended_pair_time
from repro.core.gentree import gentree
from repro.core.lower import LoweringError, lower_plan
from repro.core.optimality import (overlap_certificate,
                                   overlap_lower_bound,
                                   overlap_upper_bound)
from repro.core.overlap import (merge_schedules, occupancy_summary,
                                plan_merge, rounds_link_disjoint)
from repro.core.plans import family_halves
from repro.core.simfast import FastEngine

TOPOS = {
    "ss8": lambda: topology.single_switch(8),
    "tree8": lambda: topology.symmetric_tree(2, 4),
    "cdc16": lambda: topology.cross_dc(dc0_middle=2, dc0_servers=4,
                                       dc1_middle=2, dc1_servers=4),
}


# ---------------------------------------------------------------------------
# Pricing: engine agreement + envelope
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(TOPOS))
def test_fast_engine_matches_reference_contended(name):
    topo = TOPOS[name]()
    plan = gentree(topo, 1e6).plan
    rs_half, ag_half = family_halves(plan)
    fast = FastEngine(topo).contended_halves_total(rs_half, ag_half)
    ref = contended_pair_time(topo, rs_half, ag_half)
    assert abs(fast - ref) / max(1e-30, ref) <= 1e-9, (name, fast, ref)


@pytest.mark.parametrize("name", sorted(TOPOS))
def test_contended_pair_envelope(name):
    """A concurrent pair can never beat max(t_rs, t_ag) — the busiest
    link still carries the slower half's units — and the pipeline model
    clamps it to at most sequential issuance."""
    topo = TOPOS[name]()
    plan = gentree(topo, 1e6).plan
    rs_half, ag_half = family_halves(plan)
    eng = FastEngine(topo)
    t_rs, t_ag = eng.halves_totals(plan)
    t_joint = eng.contended_halves_total(rs_half, ag_half)
    assert t_joint >= max(t_rs, t_ag) - 1e-15, (name, t_joint, t_rs, t_ag)
    k = 8
    piped = contended_pipelined_time(t_rs, t_ag, k, t_joint)
    assert overlap_lower_bound(t_rs, t_ag, k) <= piped + 1e-15
    assert piped <= overlap_upper_bound(t_rs, t_ag, k) + 1e-15


def test_contended_pipelined_time_edges():
    assert contended_pipelined_time(1.0, 2.0, 0) == 0.0
    assert contended_pipelined_time(1.0, 2.0, -3) == 0.0
    # one bucket: no steady state, halves run back to back
    assert contended_pipelined_time(1.0, 2.0, 1, 99.0) == 3.0
    # default joint = optimistic max  ->  reduces to pipelined_time
    assert contended_pipelined_time(1.0, 2.0, 5) == \
        pipelined_time(1.0, 2.0, 5)
    # joint below max clamps UP to max (can't beat the slower half)
    assert contended_pipelined_time(1.0, 2.0, 5, 0.5) == \
        pipelined_time(1.0, 2.0, 5)
    # joint above sum clamps DOWN to sequential issuance
    assert contended_pipelined_time(1.0, 2.0, 5, 10.0) == \
        serial_time(1.0, 2.0, 5)
    # interior joint lands between the bounds
    mid = contended_pipelined_time(1.0, 2.0, 5, 2.5)
    assert pipelined_time(1.0, 2.0, 5) < mid < serial_time(1.0, 2.0, 5)


def test_overlap_certificate_sandwich():
    for tj in (2.0, 2.5, 3.0):
        quoted = contended_pipelined_time(1.0, 2.0, 4, tj)
        cert = overlap_certificate(1.0, 2.0, 4, quoted)
        assert cert["sandwiched"], cert
        assert cert["lower_bound"] <= cert["quoted"] <= cert["upper_bound"]
        assert 0.0 <= cert["gap_ratio"] <= 1.0 + 1e-12
    # a quote outside the envelope is rejected
    assert not overlap_certificate(1.0, 2.0, 4, 0.5)["sandwiched"]
    assert not overlap_certificate(
        1.0, 2.0, 4, serial_time(1.0, 2.0, 4) * 2)["sandwiched"]


def test_occupancy_summary_self_overlap():
    topo = TOPOS["tree8"]()
    plan = gentree(topo, 1e6).plan
    rs_half, ag_half = family_halves(plan)
    summ = occupancy_summary(topo, rs_half.steps[0], ag_half.steps[0])
    assert summ["links_rs"] > 0 and summ["links_ag"] > 0
    assert 0 <= summ["links_shared"] <= min(summ["links_rs"],
                                            summ["links_ag"])
    assert summ["busiest_link_units"] > 0.0


# ---------------------------------------------------------------------------
# Merging: contract + numpy differential + interleaving sweep
# ---------------------------------------------------------------------------
def _self_merge(n=8, size=1e5):
    cs = lower_plan(gentree(topology.single_switch(n), size).plan)
    return cs, merge_schedules(cs, cs)


def test_plan_merge_self_is_valid_but_fully_serialized():
    cs, ms = _self_merge()
    info = ms.info
    assert info.n == 8
    assert info.round_pairs > 0
    # a schedule merged with itself shares every link every round
    assert info.coalesced == 0
    assert info.serialized == info.round_pairs
    assert 0.0 <= info.coalesced_fraction <= 1.0


def test_merge_schedules_memoized():
    cs, ms = _self_merge()
    assert merge_schedules(cs, cs) is ms


def test_plan_merge_rejects_family_and_size_mismatch():
    plan = gentree(topology.single_switch(8), 1e5).plan
    rs_half, ag_half = family_halves(plan)
    rs_cs, ag_cs = lower_plan(rs_half), lower_plan(ag_half)
    # AG-family schedule on the RS side of the merge
    with pytest.raises(LoweringError):
        plan_merge(ag_cs, ag_cs)
    # RS-family schedule on the AG side
    with pytest.raises(LoweringError):
        plan_merge(rs_cs, rs_cs)
    # axis-size mismatch
    other = lower_plan(gentree(topology.single_switch(4), 1e5).plan)
    with pytest.raises(LoweringError):
        plan_merge(lower_plan(plan), other)
    # the valid direction works
    info = plan_merge(rs_cs, ag_cs)
    assert info.round_pairs >= 0


def test_rounds_link_disjoint():
    cs = lower_plan(gentree(topology.single_switch(8), 1e5).plan)
    rd = cs.rs[0].rounds[0]
    # a round shares every link with itself
    assert not rounds_link_disjoint(rd, rd)


def _numpy_pair_expected(ms, X, shards):
    """Closed-form references for run_numpy_pair on canonical layouts."""
    a, b = ms.rs_inner, ms.ag_inner
    n = ms.n
    tot = X.sum(axis=0)
    pad = (-X.shape[1]) % a.num_blocks
    tot = np.concatenate([tot, np.zeros(pad, X.dtype)])
    chunk = tot.size // a.num_blocks
    ka = a.blocks_per_shard
    rs_want = np.stack([
        tot.reshape(a.num_blocks, chunk)[d * ka:(d + 1) * ka].reshape(-1)
        for d in range(n)])
    ag_row = np.concatenate([shards[d] for d in range(n)])
    ag_want = np.tile(ag_row, (n, 1))
    return rs_want, ag_want


def test_run_numpy_pair_matches_closed_form():
    cs, ms = _self_merge(n=8, size=1e5)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(8, 173)).astype(np.float64)
    shards = rng.normal(
        size=(8, ms.ag_inner.blocks_per_shard * 5)).astype(np.float64)
    rs_out, ag_out = ms.run_numpy_pair(X, shards)
    rs_want, ag_want = _numpy_pair_expected(ms, X, shards)
    np.testing.assert_allclose(rs_out, rs_want, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(ag_out, ag_want, rtol=1e-12, atol=1e-12)


def test_run_numpy_pair_rejects_bad_order():
    cs, ms = _self_merge(n=4, size=1e4)
    X = np.ones((4, 32))
    shards = np.ones((4, ms.ag_inner.blocks_per_shard * 2))
    with pytest.raises(LoweringError):
        ms.run_numpy_pair(X, shards, order=["a"])  # token counts off
    with pytest.raises(LoweringError):
        ms.run_numpy_pair(X[:3], shards)           # wrong device count


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([4, 8]), size=st.integers(1, 200),
       chunks=st.integers(1, 4), seed=st.integers(0, 10**6))
def test_run_numpy_pair_interleaving_invariant(n, size, chunks, seed):
    """ANY interleaving that preserves each constituent's internal step
    order produces bit-identical outputs — the disjoint-buffer fact the
    merged executor leans on."""
    cs = lower_plan(gentree(topology.single_switch(n), 1e4).plan)
    ms = merge_schedules(cs, cs)
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, size)).astype(np.float64)
    shards = rng.normal(
        size=(n, ms.ag_inner.blocks_per_shard * chunks)).astype(np.float64)
    rs_ref, ag_ref = ms.run_numpy_pair(X, shards)

    from repro.core.overlap import _ag_steps, _rs_steps
    toks = (["a"] * len(_rs_steps(ms.rs_inner))
            + ["b"] * len(_ag_steps(ms.ag_inner)))
    shuf = random.Random(seed)
    for _ in range(3):
        shuf.shuffle(toks)
        rs_out, ag_out = ms.run_numpy_pair(X, shards, order=toks)
        assert np.array_equal(rs_out, rs_ref), (n, size, toks)
        assert np.array_equal(ag_out, ag_ref), (n, size, toks)


# ---------------------------------------------------------------------------
# Planning: the argmin may only pick a winning merge
# ---------------------------------------------------------------------------
def test_planner_never_selects_losing_merge():
    from repro.planner.service import PlannerService
    svc = PlannerService()
    modes = set()
    for n in (4, 8, 16):
        for bb in (1 << 18, 1 << 20, 1 << 22, 1 << 23):
            bp = svc.get_bucket_plan([("data", n)], 4_000_000.0,
                                     config=BucketConfig(bucket_bytes=bb))
            ov = bp.overlap
            assert ov["mode"] in ("merged", "sequential"), ov
            modes.add(ov["mode"])
            t_seq = ov["t_pair_sequential"]
            if ov["mode"] == "merged":
                # a selected merge must strictly beat sequential issuance
                assert bp.num_buckets > 1
                assert 0.0 < ov["t_joint"] < t_seq, (n, bb, ov)
                assert bp.merged_schedule is not None, (n, bb)
            else:
                # sequential ⇔ no strict win was available
                assert (bp.num_buckets <= 1
                        or not ov["t_joint"]
                        or ov["t_joint"] >= t_seq), (n, bb, ov)
                assert bp.merged_schedule is None, (n, bb)
            # either way the quoted contended time respects the sandwich
            assert bp.predicted_pipelined <= bp.predicted_contended + 1e-15
            assert bp.predicted_contended <= bp.predicted_serial + 1e-15
    # both decisions must be exercised by the scan — a planner that
    # always answers "sequential" (or always "merged") is broken
    assert modes == {"merged", "sequential"}, modes


def test_step_plan_quotes_contended_with_certificate():
    from repro.planner.service import PlannerService
    svc = PlannerService()
    sp = svc.get_step_plan(
        [("data", 8)],
        {"allreduce": {"count": 4, "size_floats": 1 << 20},
         "allgather": {"count": 2, "size_floats": 1 << 18}})
    certs = 0
    for fam, quote in sp.quotes.items():
        if quote.get("certificate") is None:
            continue
        certs += 1
        cert = quote["certificate"]
        assert cert["sandwiched"], (fam, cert)
        assert quote["pipelined"] <= quote["contended"] + 1e-15, (fam,
                                                                  quote)
    # the multi-call allreduce family must carry a §15 certificate
    assert certs >= 1, sp.quotes


# ---------------------------------------------------------------------------
# 8-device differential: merged ≡ sequential ≡ lax at 1e-6
# ---------------------------------------------------------------------------
_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map
from repro.core import topology
from repro.core.cost_model import PRECISIONS
from repro.core.gentree import gentree
from repro.core.lower import lower_plan
from repro.core.overlap import merge_schedules

results = {}
N, SIZE = 8, 173
mesh = Mesh(np.array(jax.devices()[:N]), ("x",))
rng = np.random.default_rng(7)


def launch(fn, *xs):
    f = shard_map(lambda *vs: [o[None] for o in fn(*[v[0] for v in vs])],
                  mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    return [np.asarray(o).astype(np.float64) for o in jax.jit(f)(*xs)]


def relerr(got, want):
    return float(np.abs(got - want).max() / (np.abs(want).max() + 1e-30))


# Table-6 two-level mesh: the acceptance topology
cs = lower_plan(gentree(topology.symmetric_tree(2, 4), 1e6).plan)
ms = merge_schedules(cs, cs)
kb = ms.ag_inner.blocks_per_shard
X = jnp.asarray(rng.normal(size=(N, SIZE)), jnp.float32)
S = jnp.asarray(rng.normal(size=(N, kb * 3)), jnp.float32)

# merged issuance
m_shard, m_full = launch(
    lambda x, s: ms.rs_ag(x, s, "x"), X, S)
results["merged_demoted_after"] = bool(ms.demoted)
# sequential issuance through the raw constituents
s_shard, s_full = launch(
    lambda x, s: (cs.reduce_scatter(x, "x"), cs.all_gather(s, "x")), X, S)
results["merged_vs_sequential_shard"] = relerr(m_shard, s_shard)
results["merged_vs_sequential_full"] = relerr(m_full, s_full)

# lax references: RS shard == slice of psum; AG full == all device shards
Xn = np.asarray(X, np.float64)
tot = Xn.sum(0)
pad = (-SIZE) % ms.rs_inner.num_blocks
tot = np.concatenate([tot, np.zeros(pad)])
chunk = tot.size // ms.rs_inner.num_blocks
ka = ms.rs_inner.blocks_per_shard
rs_want = np.stack([
    tot.reshape(-1, chunk)[d * ka:(d + 1) * ka].reshape(-1)
    for d in range(N)])
ag_want = np.tile(np.asarray(S, np.float64).reshape(-1), (N, 1))
results["merged_vs_lax_shard"] = relerr(m_shard, rs_want)
results["merged_vs_lax_full"] = relerr(m_full, ag_want)

# demoted wrapper serves the same values through the sequential rung
ms._demoted = True
d_shard, d_full = launch(lambda x, s: ms.rs_ag(x, s, "x"), X, S)
results["demoted_vs_merged_shard"] = relerr(d_shard, m_shard)
results["demoted_vs_merged_full"] = relerr(d_full, m_full)
ms.reset_guard()

# int8 wire-compressed constituents: merged interleaves at step
# granularity through the constituents' own wire machinery, so the
# merged and sequential compressed paths must agree bit-for-bit-close
cs8 = cs.with_wire(PRECISIONS["int8"])
ms8 = merge_schedules(cs8, cs8)
m8_shard, m8_full = launch(lambda x, s: ms8.rs_ag(x, s, "x"), X, S)
s8_shard, s8_full = launch(
    lambda x, s: (cs8.reduce_scatter(x, "x"), cs8.all_gather(s, "x")),
    X, S)
results["compressed_merged_vs_sequential_shard"] = relerr(m8_shard,
                                                          s8_shard)
results["compressed_merged_vs_sequential_full"] = relerr(m8_full, s8_full)
# quantized-vs-exact stays inside the int8 error budget
results["compressed_vs_lax_shard"] = relerr(m8_shard, rs_want)
results["compressed_budget"] = float(PRECISIONS["int8"].error_budget)

print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


@pytest.mark.parametrize("key", [
    "merged_vs_sequential_shard", "merged_vs_sequential_full",
    "merged_vs_lax_shard", "merged_vs_lax_full",
    "demoted_vs_merged_shard", "demoted_vs_merged_full",
    "compressed_merged_vs_sequential_shard",
    "compressed_merged_vs_sequential_full"])
def test_eight_device_differential(results, key):
    assert results[key] < 1e-6, (key, results)


def test_eight_device_merged_not_demoted(results):
    assert results["merged_demoted_after"] is False, results


def test_eight_device_compressed_within_budget(results):
    assert results["compressed_vs_lax_shard"] < \
        results["compressed_budget"], results
