"""Plan-IR invariants (property-based): correctness of the builders and the
paper's optimality results (Theorems 1 & 2)."""
import math

import pytest
from _hypothesis_stub import given, settings, strategies as st

from repro.core import optimality as opt, plans


def _factor_lists(draw):
    pass


factors_st = st.lists(st.integers(2, 6), min_size=2, max_size=3)


def blocks_reduced_correctly(plan: plans.Plan) -> bool:
    """Simulate block ownership: after the ReduceScatter phase each block
    must have absorbed exactly N contributions; after AllGather each server
    holds the result. We verify the conservation law via reduce counts:
    total (fan_in - 1) summed = (N - 1) per owned block."""
    total_merges = sum((r.fan_in - 1) * r.size
                       for st_ in plan.steps for r in st_.reduces)
    expect = (plan.n - 1) * plan.size
    return math.isclose(total_merges, expect, rel_tol=1e-9)


@pytest.mark.parametrize("builder,kw", [
    (plans.ring, {}), (plans.cps, {}), (plans.rhd, {}),
    (plans.reduce_broadcast, {})])
@pytest.mark.parametrize("n", [2, 3, 4, 7, 8, 12, 15, 16])
def test_merge_conservation(builder, kw, n):
    p = builder(n, float(n * 12))
    assert blocks_reduced_correctly(p)


@settings(max_examples=40, deadline=None)
@given(factors=factors_st)
def test_hcps_merge_conservation(factors):
    n = math.prod(factors)
    p = plans.hcps(factors, float(n * 8))
    assert blocks_reduced_correctly(p)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 32))
def test_bandwidth_optimality(n):
    """Ring / CPS traffic per server == the Patarasuk–Yuan lower bound
    2(N−1)S/N (paper Eq. 2); RHD matches iff N is a power of two."""
    s = float(n * 16)
    bound = 2 * (n - 1) * s / n
    for b in (plans.ring, plans.cps):
        traffic = b(n, s).total_traffic_per_server()
        assert all(math.isclose(v, bound, rel_tol=1e-9)
                   for v in traffic.values())
    if (n & (n - 1)) == 0:
        traffic = plans.rhd(n, s).total_traffic_per_server()
        assert all(math.isclose(v, bound, rel_tol=1e-9)
                   for v in traffic.values())


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 32))
def test_theorem1_delta_lower_bound(n):
    """No plan beats (N+1)S/N memory ops; CPS achieves it (δ-optimal),
    Ring costs 3(N−1)S/N."""
    s = float(n * 16)
    lb = opt.delta_lower_bound_mem_ops(n, s)
    cps = plans.cps(n, s)
    ring = plans.ring(n, s)
    rhd = plans.rhd(n, s)
    assert cps.max_mem_ops_per_server() == pytest.approx(lb)
    assert opt.is_delta_optimal(cps)
    for p in (ring, rhd):
        assert p.max_mem_ops_per_server() >= lb - 1e-9
    assert ring.max_mem_ops_per_server() == pytest.approx(
        3 * (n - 1) * s / n)


@settings(max_examples=40, deadline=None)
@given(factors=factors_st)
def test_theorem1_h_steps(factors):
    """Eq. 15: a reduction whose per-block DAG has h ops costs
    (N−1+2h)·S/N memory ops. For m-stage HCPS the DAG for one block has
    h = Σ_i ∏_{j>i} f_j ops (N/f_0 groups at stage 0, …, 1 at the last),
    and the per-server parallel cost matches because work is balanced.
    This also equals Table 2's (2·Σ_{i≥1}∏_{j≤i}f_j + N + 1)·S/N row."""
    n = math.prod(factors)
    s = float(n * 8)
    p = plans.hcps(factors, s)
    h = sum(math.prod(factors[i + 1:]) for i in range(len(factors)))
    assert p.max_mem_ops_per_server() == pytest.approx(
        opt.mem_ops_with_h_steps(n, s, h))
    # Table-2 row form; the paper's ∏_{j=1}^{i} f_j runs over the *last*
    # stages first (reverse of execution order)
    rev = factors[::-1]
    table2 = (2 * sum(math.prod(rev[:i + 1])
                      for i in range(len(rev) - 1)) + n + 1) * s / n
    assert p.max_mem_ops_per_server() == pytest.approx(table2)


@settings(max_examples=60, deadline=None)
@given(n=st.integers(2, 32), w_t=st.integers(2, 12))
def test_theorem2_impossibility(n, w_t):
    """No plan is both δ- and ε-optimal when N > w_t — checked on every
    builder we have."""
    s = float(n * 16)
    cand = [plans.ring(n, s), plans.cps(n, s), plans.rhd(n, s),
            plans.reduce_broadcast(n, s)]
    for f in plans.factorizations(n, max_steps=3)[:5]:
        cand.append(plans.hcps(f, s))
    for p in cand:
        assert opt.theorem2_holds(p, w_t), (p.name, n, w_t)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 24))
def test_ring_epsilon_optimal(n):
    """Ring has fan-in 2 everywhere — ε-optimal for any w_t ≥ 2."""
    p = plans.ring(n, float(n * 4))
    assert p.max_fan_in() <= 2


@settings(max_examples=40, deadline=None)
@given(n=st.integers(2, 30))
def test_factorizations_products(n):
    for f in plans.factorizations(n):
        assert math.prod(f) == n
        assert all(x >= 2 for x in f)
        assert 2 <= len(f) <= 3
