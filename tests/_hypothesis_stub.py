"""Guard for the optional `hypothesis` dependency.

`pytest.importorskip("hypothesis")` at module scope would skip entire test
modules — including their deterministic, non-property tests. This shim
applies the same skip at *test* granularity instead: when hypothesis is
missing, every `@given` test is marked skipped (with the importorskip
reason) while the rest of the module still runs.

Usage in test modules:

    from _hypothesis_stub import given, settings, strategies as st
"""
import pytest

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(
        reason="could not import 'hypothesis': optional dependency "
               "not installed")

    class _Strategy:
        """Inert placeholder so module-level strategy definitions like
        st.lists(st.integers(2, 6)).map(f) still evaluate."""

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

        def flatmap(self, fn):
            return self

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(*a, **kw):
            return _Strategy()

        @staticmethod
        def floats(*a, **kw):
            return _Strategy()

        @staticmethod
        def lists(*a, **kw):
            return _Strategy()

        @staticmethod
        def sampled_from(*a, **kw):
            return _Strategy()

        @staticmethod
        def booleans(*a, **kw):
            return _Strategy()

        @staticmethod
        def tuples(*a, **kw):
            return _Strategy()

        @staticmethod
        def recursive(*a, **kw):
            return _Strategy()

    def given(*a, **kw):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*a, **kw):
        def deco(fn):
            return fn
        return deco
