"""Runtime telemetry (repro.runtime.telemetry): rings + streaming stats,
residual tracking, arrival-offset estimation, re-measure windows, and the
shared watchdog/planner datapath (DESIGN.md §10)."""
import pytest

from repro.runtime.telemetry import (ArrivalEstimator, CostLedger,
                                     LedgerEntry, LevelSample,
                                     ResidualTracker, Telemetry, TimingRing)


# ---------------------------------------------------------------------------
# TimingRing
# ---------------------------------------------------------------------------
class TestTimingRing:
    def test_mean_and_count(self):
        r = TimingRing(capacity=8)
        for v in (1.0, 2.0, 3.0):
            r.add(v)
        assert r.count == 3 and r.total == 3
        assert r.mean() == pytest.approx(2.0)
        assert r.last == 3.0

    def test_wraparound_keeps_freshest_window(self):
        r = TimingRing(capacity=4)
        for v in range(10):
            r.add(float(v))
        assert r.count == 4 and r.total == 10
        assert r.window() == [6.0, 7.0, 8.0, 9.0]
        assert r.mean() == pytest.approx(7.5)

    def test_percentiles(self):
        r = TimingRing(capacity=16)
        for v in range(1, 11):           # 1..10
            r.add(float(v))
        assert r.percentile(0) == 1.0
        assert r.percentile(100) == 10.0
        assert r.percentile(50) == pytest.approx(5.5)

    def test_ewma_halflife_decay(self):
        r = TimingRing(capacity=8, halflife=1)
        r.add(0.0)                        # seeds the EWMA
        r.add(2.0)                        # k = 2^-1 = 0.5
        assert r.ewma == pytest.approx(1.0)

    def test_baseline_false_excluded_from_ewma_kept_in_window(self):
        r = TimingRing(capacity=8)
        r.add(1.0)
        r.add(100.0, baseline=False)      # straggler
        assert r.ewma == pytest.approx(1.0)
        assert r.count == 2 and 100.0 in r.window()

    def test_reset(self):
        r = TimingRing(capacity=4)
        r.add(1.0)
        r.reset()
        assert r.count == 0 and r.ewma is None and r.mean() == 0.0

    def test_bad_capacity(self):
        with pytest.raises(ValueError):
            TimingRing(capacity=0)


# ---------------------------------------------------------------------------
# ResidualTracker
# ---------------------------------------------------------------------------
class TestResidualTracker:
    def test_relative_residuals(self):
        t = ResidualTracker()
        rel = t.record(predicted=1.0, measured=1.5)
        assert rel == pytest.approx(0.5)
        assert t.record(1.0, 0.5) == pytest.approx(-0.5)

    def test_drift_is_median_absolute(self):
        t = ResidualTracker()
        for meas in (1.1, 0.9, 1.1, 2.0):       # rels .1, -.1, .1, 1.0
            t.record(1.0, meas)
        assert t.drift() == pytest.approx(0.1)  # outlier-robust

    def test_bias_keeps_sign(self):
        t = ResidualTracker()
        for meas in (1.2, 1.3, 1.25):
            t.record(1.0, meas)
        assert t.bias() > 0.2
        t2 = ResidualTracker()
        for meas in (0.8, 0.7, 0.75):
            t2.record(1.0, meas)
        assert t2.bias() < -0.2

    def test_zero_predicted_is_safe(self):
        t = ResidualTracker()
        assert t.record(0.0, 1.0) == 0.0


# ---------------------------------------------------------------------------
# Empty-window contract: no sample can masquerade as a measurement
# ---------------------------------------------------------------------------
class TestEmptyWindowContract:
    def test_empty_ring_percentile_is_none(self):
        r = TimingRing(capacity=4)
        assert r.percentile(50.0) is None
        assert r.percentile(0.0) is None
        r.add(1.0)
        assert r.percentile(50.0) == 1.0
        r.reset()
        assert r.percentile(95.0) is None

    def test_empty_ring_summary_identity_fields(self):
        s = TimingRing(capacity=4).summary()
        assert s["count"] == 0 and s["total"] == 0
        assert s["mean"] == 0.0
        assert s["ewma"] is None and s["last"] is None
        assert s["p50"] is None and s["p95"] is None

    def test_empty_tracker_drift_and_bias_are_none(self):
        t = ResidualTracker()
        assert t.drift() is None
        assert t.bias() is None
        t.record(1.0, 1.5)
        assert t.drift() == pytest.approx(0.5)
        t.reset()
        assert t.drift() is None and t.bias() is None


# ---------------------------------------------------------------------------
# ArrivalEstimator
# ---------------------------------------------------------------------------
class TestArrivalEstimator:
    def test_offsets_relative_to_earliest(self):
        est = ArrivalEstimator()
        est.record([10.0, 10.5, 10.1, 10.0])
        assert est.n_devices == 4
        offs = est.offsets()
        assert offs[0] == 0.0
        assert offs[1] == pytest.approx(0.5)

    def test_median_over_collectives(self):
        est = ArrivalEstimator()
        for late in (0.1, 0.2, 0.3):
            est.record([0.0, late])
        assert est.count == 3
        assert est.offsets()[1] == pytest.approx(0.2)

    def test_reset(self):
        est = ArrivalEstimator()
        est.record([0.0, 1.0])
        est.reset()
        assert est.n_devices == 0 and est.count == 0


# ---------------------------------------------------------------------------
# Telemetry facade
# ---------------------------------------------------------------------------
class TestTelemetry:
    def test_rings_create_on_demand_and_share(self):
        tele = Telemetry()
        tele.record("train/step", 0.1)
        assert tele.ring("train/step").count == 1
        assert tele.ring("train/step") is tele.ring("train/step")

    def test_residual_and_sample_recording(self):
        tele = Telemetry()
        tele.record_residual("level/root_sw", 1.0, 1.4)
        assert tele.residuals("level/root_sw").drift() == pytest.approx(0.4)
        tele.record_sample("root_sw", LevelSample(8, 1e6, 0.01, 0.011))
        assert len(tele.samples("root_sw")) == 1
        tele.clear_samples("root_sw")
        assert tele.samples("root_sw") == []

    def test_remeasure_window_clears_suspect_state_keeps_rings(self):
        tele = Telemetry()
        tele.record("train/step", 0.1)
        tele.record_residual("level/root_sw", 1.0, 2.0)
        tele.record_sample("root_sw", LevelSample(8, 1e6, 0.01, 0.011))
        tele.record_arrivals([0.0, 0.5])
        tele.remeasure("remesh", {"dropped": 3})
        # residuals, samples, arrivals describe the old cluster: gone
        assert tele.residuals("level/root_sw").count == 0
        assert tele.samples("root_sw") == []
        assert tele.arrivals.n_devices == 0
        # raw timing rings survive for trend display; event logged
        assert tele.ring("train/step").count == 1
        assert [e.kind for e in tele.events] == ["remesh"]

    def test_stats_shape(self):
        tele = Telemetry()
        tele.record("x", 1.0)
        tele.record_residual("level/a", 1.0, 1.1)
        st = tele.stats()
        assert "x" in st["rings"] and "level/a" in st["residuals"]
        assert st["rings"]["x"]["count"] == 1


# ---------------------------------------------------------------------------
# CostLedger (DESIGN.md §11): per-term predicted seconds next to measured
# ---------------------------------------------------------------------------
def _entry(level="root_sw", predicted=1.0, measured=1.1, **shares):
    base = {"alpha": 0.0, "beta": 0.0, "gamma": 0.0, "delta": 0.0,
            "incast": 0.0}
    base.update(shares)
    return LedgerEntry(level=level, n=8, size_floats=1e6,
                       predicted=predicted, measured=measured, shares=base)


class TestCostLedger:
    def test_record_and_per_level_isolation(self):
        led = CostLedger()
        led.record(_entry(level="root_sw", alpha=0.4, beta=0.6))
        led.record(_entry(level="cross_dc", alpha=1.0))
        assert led.count("root_sw") == 1 and led.count("cross_dc") == 1
        assert led.levels() == ["cross_dc", "root_sw"]
        assert led.entries("nope") == []

    def test_totals_sum_terms_over_window(self):
        led = CostLedger()
        led.record(_entry(alpha=0.4, beta=0.6))
        led.record(_entry(alpha=0.1, beta=0.9))
        tot = led.totals("root_sw")
        assert tot["alpha"] == pytest.approx(0.5)
        assert tot["beta"] == pytest.approx(1.5)

    def test_bounded_window(self):
        led = CostLedger(capacity=3)
        for i in range(10):
            led.record(_entry(alpha=float(i)))
        assert led.count("root_sw") == 3
        assert [e.shares["alpha"] for e in led.entries("root_sw")] == \
            [7.0, 8.0, 9.0]

    def test_clear_level_and_all(self):
        led = CostLedger()
        led.record(_entry(level="a"))
        led.record(_entry(level="b"))
        led.clear("a")
        assert led.count("a") == 0 and led.count("b") == 1
        led.clear()
        assert led.levels() == []

    def test_remeasure_clears_ledger(self):
        tele = Telemetry()
        tele.ledger.record(_entry(alpha=1.0))
        tele.remeasure("remesh", {})
        assert tele.ledger.levels() == []

    def test_stats_reports_ledger_counts(self):
        tele = Telemetry()
        tele.ledger.record(_entry(level="root_sw"))
        assert tele.stats()["ledger"] == {"root_sw": 1}


# ---------------------------------------------------------------------------
# The shared datapath: watchdog EWMA lives in the telemetry ring
# ---------------------------------------------------------------------------
class TestWatchdogDatapath:
    def test_watchdog_writes_through_shared_ring(self):
        from repro.runtime import StragglerWatchdog
        tele = Telemetry()
        wd = StragglerWatchdog(threshold=2.0, halflife=5, telemetry=tele)
        for s in range(10):
            assert not wd.observe(s, 1.0)
        # the same samples are visible through the hub — one datapath
        ring = tele.ring("train/step")
        assert ring.count == 10 and ring.ewma == pytest.approx(1.0)
        assert wd.observe(10, 5.0)            # straggler
        assert ring.count == 11               # kept in window...
        assert ring.ewma == pytest.approx(1.0)  # ...but not the baseline
        assert wd.events and wd.events[0][0] == 10

    def test_ft_loop_straggler_opens_remeasure_window(self, tmp_path):
        import jax.numpy as jnp

        from repro.checkpoint import CheckpointManager
        from repro.runtime import FaultTolerantLoop, StragglerWatchdog

        tele = Telemetry()
        tele.record_residual("level/root_sw", 1.0, 2.0)
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        wd = StragglerWatchdog(threshold=2.0, halflife=5, telemetry=tele)
        # warm the first JAX dispatch OUTSIDE the loop: a cold step 0
        # would seed the watchdog EWMA with compile/dispatch time and a
        # small injected sleep could stay under 2x that baseline
        jnp.float32(0) + 1

        def step_fn(state, step):
            import time
            if step == 8:
                time.sleep(0.3)           # injected straggler, >> 2x
            return {"acc": state["acc"] + step}  # baseline even when cold

        loop = FaultTolerantLoop(step_fn, {"acc": jnp.float32(0)}, mgr,
                                 ckpt_every=100, watchdog=wd)
        assert loop.telemetry is tele     # one hub end to end
        loop.run(10)
        kinds = [e.kind for e in tele.events]
        assert "straggler" in kinds
        # pre-event residual history was dropped with the window
        assert tele.residuals("level/root_sw").count == 0

    def test_elastic_remesh_opens_remeasure_window(self):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.planner.service import PlannerService
        from repro.runtime import elastic_remesh

        tele = Telemetry()
        svc = PlannerService(telemetry=tele)
        svc.get_bucket_plan([("data", 8)], 4096.0)
        tele.record_sample("root_sw", LevelSample(8, 1e3, 0.01, 0.01))
        mesh = jax.make_mesh((1,), ("data",))
        elastic_remesh({"w": jnp.ones((2,))},
                       {"w": NamedSharding(mesh, P())}, planner=svc)
        assert svc.executable_count() == 0
        assert [e.kind for e in tele.events] == ["remesh"]
        assert tele.samples("root_sw") == []
