"""Data pipeline, optimizer, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import DataConfig, SyntheticLM, make_pipeline
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.runtime import FaultTolerantLoop, StragglerWatchdog


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_data_deterministic_in_step():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    a = SyntheticLM(cfg).batch_at(7)
    b = SyntheticLM(cfg).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    # label t is token t+1 of the underlying stream:
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


def test_pipeline_prefetch_resume():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    it = make_pipeline(cfg, start_step=5, prefetch=2)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"],
                                  SyntheticLM(cfg).batch_at(5)["tokens"])


def test_data_learnable_structure():
    cfg = DataConfig(vocab=100, seq_len=64, global_batch=8)
    b = SyntheticLM(cfg).batch_at(0)
    follow = (b["tokens"] * 7 + 3) % 100
    frac = (b["labels"] == follow).mean()
    assert frac > 0.4          # markov_mix=0.65 minus collisions


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw_update(params, g, opt,
                               AdamWConfig(lr=0.0, grad_clip=1.0))
    assert float(gnorm) == pytest.approx(200.0)


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, peak=1.0, warmup=10, total=100))
    lr10 = float(warmup_cosine(10, peak=1.0, warmup=10, total=100))
    lr100 = float(warmup_cosine(100, peak=1.0, warmup=10, total=100))
    assert lr0 == 0.0 and lr10 == pytest.approx(1.0)
    assert lr100 == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_save_load_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5, dtype=jnp.bfloat16),
            "b": {"c": jnp.ones((2, 3))}, "step": jnp.int32(7)}
    save_pytree(tree, str(tmp_path / "ck"))
    out = load_pytree(str(tmp_path / "ck"), tree)
    assert out["step"] == 7
    assert out["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.ones((2, 3)))


def test_manager_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"x": jnp.zeros(3)}
    for s in (10, 20, 30):
        mgr.save(s, {"x": jnp.full(3, float(s))})
    assert mgr.latest_step() == 30
    restored, step = mgr.restore(tree)
    assert step == 30 and float(restored["x"][0]) == 30.0
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2       # gc keeps 2


def test_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, {"x": jnp.ones(4)})
    mgr.wait()
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_ft_loop_recovers_from_failures(tmp_path):
    """Inject a failure at step 7; the loop must restore step-5 state and
    produce the exact same final state as a failure-free run."""
    def make_loop(fail_once, path):
        mgr = CheckpointManager(path, keep=3, async_save=False)
        seen = {"failed": False}

        def step_fn(state, step):
            if fail_once and step == 7 and not seen["failed"]:
                seen["failed"] = True
                raise RuntimeError("injected device loss")
            return {"acc": state["acc"] + step}

        return FaultTolerantLoop(step_fn, {"acc": jnp.float32(0)}, mgr,
                                 ckpt_every=5)

    clean = make_loop(False, str(tmp_path / "a")).run(12)
    faulty_loop = make_loop(True, str(tmp_path / "b"))
    faulty = faulty_loop.run(12)
    assert float(clean["acc"]) == float(faulty["acc"])
    assert faulty_loop.restarts == 1


def test_ft_loop_resumes_from_disk(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(10, {"acc": jnp.float32(45.0)})   # sum of 0..9

    def step_fn(state, step):
        return {"acc": state["acc"] + step}

    loop = FaultTolerantLoop(step_fn, {"acc": jnp.float32(0)}, mgr,
                             ckpt_every=100)
    out = loop.run(12)
    assert float(out["acc"]) == sum(range(12))


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0, halflife=5)
    for s in range(20):
        assert not wd.observe(s, 1.0)
    assert wd.observe(20, 5.0)          # 5× the EWMA
    assert wd.events and wd.events[0][0] == 20
    # baseline not poisoned by the straggler
    assert not wd.observe(21, 1.2)


def test_elastic_remesh_identity():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.runtime import elastic_remesh
    mesh = jax.make_mesh((1,), ("data",))
    state = {"w": jnp.ones((4, 4))}
    sh = {"w": NamedSharding(mesh, P())}
    out = elastic_remesh(state, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4, 4)))


# ---------------------------------------------------------------------------
# replanning: bucket schedules must not survive an axis-size change
# ---------------------------------------------------------------------------
def test_elastic_remesh_invalidates_bucket_schedules():
    """An elastic remesh changes axis sizes; every lowered
    CompiledSchedule and bucket plan derived from the planner cache must
    be dropped, and the next lookup must rebuild against the new size."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.planner.service import PlannerService
    from repro.runtime import elastic_remesh

    svc = PlannerService()
    bp8 = svc.get_bucket_plan([("data", 8)], 4096.0)
    assert bp8.axis_plans[0].schedule.n == 8
    assert svc.executable_count() > 0

    mesh = jax.make_mesh((1,), ("data",))
    state = {"w": jnp.ones((4, 4))}
    out = elastic_remesh(state, {"w": NamedSharding(mesh, P())},
                         planner=svc)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4, 4)))
    assert svc.executable_count() == 0          # stale schedules gone

    bp4 = svc.get_bucket_plan([("data", 4)], 4096.0)
    assert bp4.source == "cold"
    assert bp4.axis_plans[0].schedule.n == 4
    assert bp4.axis_plans[0].schedule is not bp8.axis_plans[0].schedule


def test_precision_change_never_serves_stale_compressed_plan():
    """The PR 4 fingerprint-invalidation contract extended to the wire
    precision (DESIGN.md §13): precision and tolerance live in
    BucketConfig.key(), so changing either is a cold cache miss — a
    caller that revokes lossy consent can never be handed a cached
    compressed schedule, and vice versa."""
    from repro.core.bucketing import BucketConfig
    from repro.planner.service import PlannerService

    svc = PlannerService()
    lossy = svc.get_bucket_plan(
        [("data", 8)], 65536.0,
        config=BucketConfig(precision="fp8", tolerance=0.3))
    assert lossy.source == "cold" and lossy.precision == "fp8"
    assert lossy.axis_plans[0].schedule.wire.name == "fp8"

    # tolerance revoked: cold miss, full-precision plan, no wire
    strict = svc.get_bucket_plan(
        [("data", 8)], 65536.0,
        config=BucketConfig(precision="fp8", tolerance=None))
    assert strict.source == "cold" and strict.precision == "fp8"
    # precision=fp8 with tolerance=None is an explicit pin (trusted) —
    # but a *float* tolerance below the budget clamps
    clamped = svc.get_bucket_plan(
        [("data", 8)], 65536.0,
        config=BucketConfig(precision="fp8", tolerance=0.01))
    assert clamped.source == "cold" and clamped.precision == "f32"
    assert clamped.axis_plans[0].schedule.wire is None

    # default (no consent at all) is lossless and its own entry
    plain = svc.get_bucket_plan([("data", 8)], 65536.0)
    assert plain.source == "cold" and plain.precision == "f32"
    assert plain.axis_plans[0].schedule is not \
        lossy.axis_plans[0].schedule

    # warm hits for each key keep their own choice
    assert svc.get_bucket_plan(
        [("data", 8)], 65536.0,
        config=BucketConfig(precision="fp8",
                            tolerance=0.3)).precision == "fp8"
    assert svc.get_bucket_plan([("data", 8)], 65536.0).precision == "f32"

    # schedule invalidation rebuilds the wire binding, not just f32
    svc.invalidate_executables()
    re = svc.get_bucket_plan(
        [("data", 8)], 65536.0,
        config=BucketConfig(precision="fp8", tolerance=0.3))
    assert re.axis_plans[0].schedule.wire.name == "fp8"


def test_ft_resume_invalidates_and_rebuilds_bucket_schedules(tmp_path):
    """FaultTolerantLoop resume (restore from disk — possibly onto a
    different allocation) drops the derived schedules and reports it via
    the event hook; fresh lookups re-lower for the new mesh."""
    from repro.planner.service import PlannerService

    svc = PlannerService()
    svc.get_bucket_plan([("data", 8)], 8192.0)
    assert svc.executable_count() > 0

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(10, {"acc": jnp.float32(45.0)})   # sum of 0..9
    events = []
    loop = FaultTolerantLoop(
        lambda s, i: {"acc": s["acc"] + i}, {"acc": jnp.float32(0)}, mgr,
        ckpt_every=100, planner=svc,
        on_event=lambda kind, info: events.append((kind, info)))
    out = loop.run(12)
    assert float(out["acc"]) == sum(range(12))

    kinds = [k for k, _ in events]
    assert "resume" in kinds and "invalidate" in kinds
    inv = dict(events)["invalidate"]
    assert inv["dropped"] > 0
    assert svc.executable_count() == 0
    # replanning after the (conceptual) axis-size change
    bp = svc.get_bucket_plan([("data", 4)], 8192.0)
    assert bp.axis_plans[0].schedule.n == 4


def test_ft_failure_restart_invalidates_bucket_schedules(tmp_path):
    """The failure-restart path restores a checkpoint too — it must drop
    the derived schedules exactly like a cold resume."""
    from repro.planner.service import PlannerService

    svc = PlannerService()
    svc.get_bucket_plan([("data", 8)], 4096.0)
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    seen = {"failed": False}

    def step_fn(state, step):
        if step == 7 and not seen["failed"]:
            seen["failed"] = True
            raise RuntimeError("injected device loss")
        return {"acc": state["acc"] + step}

    loop = FaultTolerantLoop(step_fn, {"acc": jnp.float32(0)}, mgr,
                             ckpt_every=5, planner=svc)
    out = loop.run(12)
    assert float(out["acc"]) == sum(range(12))
    assert loop.restarts == 1
    assert svc.executable_count() == 0


def test_ft_restart_without_checkpoint_invalidates(tmp_path):
    """A failure before the first checkpoint restarts from step 0 with no
    restore — the stale schedules must still be dropped (the failure may
    mean a new allocation)."""
    from repro.planner.service import PlannerService

    svc = PlannerService()
    svc.get_bucket_plan([("data", 8)], 4096.0)
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    seen = {"failed": False}

    def step_fn(state, step):
        if step == 3 and not seen["failed"]:
            seen["failed"] = True
            raise RuntimeError("injected device loss")
        return {"acc": state["acc"] + step}

    loop = FaultTolerantLoop(step_fn, {"acc": jnp.float32(0)}, mgr,
                             ckpt_every=50, planner=svc)
    out = loop.run(6)
    # no checkpoint: in-memory state survives the restart (steps 0-2
    # already applied) and the loop replays 0..5 on top
    assert float(out["acc"]) == sum(range(3)) + sum(range(6))
    assert loop.restarts == 1
    assert svc.executable_count() == 0


def test_ft_resume_invalidation_opt_out(tmp_path):
    from repro.planner.service import PlannerService

    svc = PlannerService()
    svc.get_bucket_plan([("data", 8)], 4096.0)
    before = svc.executable_count()
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(10, {"acc": jnp.float32(45.0)})
    loop = FaultTolerantLoop(
        lambda s, i: {"acc": s["acc"] + i}, {"acc": jnp.float32(0)}, mgr,
        ckpt_every=100, planner=svc, invalidate_on_resume=False)
    loop.run(12)
    assert svc.executable_count() == before     # schedules kept


# ---------------------------------------------------------------------------
# the online loop: observe -> drift -> refit -> invalidate -> replan
# ---------------------------------------------------------------------------
def _drifted_cluster(true_params, svc, level="root_sw"):
    """Ground-truth measurement oracle: what the cluster ACTUALLY takes
    is the service's chosen plan simulated under the true params."""
    from repro.core.simulator import Simulator
    from repro.core.sync import level_switch_topo

    def measure(n, size):
        resp = svc.get_axis_executable("data", n, size, level=level)
        topo = level_switch_topo(n, true_params, level)
        meas = Simulator(topo, true_params,
                         unit_bytes=4).simulate(resp.plan).total
        return resp, meas

    return measure


def test_refit_fires_and_invalidates_stale_plans():
    """Satellite: mis-seed GenModelParams, feed synthetic measurements
    until the refit fires; (a) old fingerprints miss, (b) derived_count
    drops to zero, (c) the next sync step lowers fresh schedules."""
    import dataclasses

    from repro.core.cost_model import PAPER_TABLE5
    from repro.planner.service import PlannerService, RefitPolicy

    true = PAPER_TABLE5
    wrong = dict(true)
    wrong["root_sw"] = dataclasses.replace(
        true["root_sw"], alpha=true["root_sw"].alpha / 3,
        beta=true["root_sw"].beta / 6)
    svc = PlannerService(params=wrong, refit_policy=RefitPolicy(
        min_samples=6, drift_threshold=0.15, cooldown=6))
    measure = _drifted_cluster(true, svc)

    bp_old = svc.get_bucket_plan([("data", 8)], float(1 << 18))
    sched_old = bp_old.axis_plans[0].schedule
    assert svc.cache.derived_count() > 0
    misses_before_refit = None

    sizes = [(8, 1e6), (8, 4e6), (4, 1e6), (8, 1.6e7), (4, 4e6),
             (8, 2e6), (8, 8e6), (4, 2e6)]
    fired = False
    for n, size in sizes * 3:
        resp, meas = measure(n, size)
        out = svc.observe("root_sw", n, size, meas,
                          predicted=resp.predicted_time, key=resp.key)
        if out["refit"]:
            fired = True
            assert out["dropped"] > 0
            misses_before_refit = svc.cache.stats.misses
            break
    assert fired, "drift never triggered a refit"
    # (b) every derived executable artifact dropped at the swap
    assert svc.cache.derived_count() == 0
    assert svc.refits and svc.refits[0]["level"] == "root_sw"

    # (a) the refitted params flow through the fingerprints: the same
    # request resolves to a NEW key and the old entry is never hit
    bp_new = svc.get_bucket_plan([("data", 8)], float(1 << 18))
    assert bp_new.key != bp_old.key
    assert bp_new.source == "cold"
    assert svc.cache.stats.misses > misses_before_refit

    # (c) fresh schedules, lowered under the refitted model — the stale
    # CompiledSchedule is unreachable (identity assertion)
    sched_new = bp_new.axis_plans[0].schedule
    assert sched_new is not None and sched_new is not sched_old


def test_closed_loop_converges_and_never_executes_stale_schedules():
    """Acceptance: a training loop started with deliberately
    mis-calibrated GenModelParams observes measured costs, refits,
    replans and converges — post-refit predicted axis cost tracks
    measured within 10%, and no stale CompiledSchedule is ever executed
    after the swap (schedule identity)."""
    import dataclasses

    from repro.core.cost_model import PAPER_TABLE5
    from repro.planner.service import PlannerService, RefitPolicy

    true = PAPER_TABLE5
    wrong = dict(true)
    wrong["root_sw"] = dataclasses.replace(
        true["root_sw"], alpha=true["root_sw"].alpha / 3,
        beta=true["root_sw"].beta / 6)
    svc = PlannerService(params=wrong, refit_policy=RefitPolicy(
        min_samples=6, drift_threshold=0.15, cooldown=6))
    measure = _drifted_cluster(true, svc)

    sizes = [(8, 1e6), (8, 4e6), (4, 1e6), (8, 1.6e7), (4, 4e6),
             (8, 2e6), (8, 8e6), (4, 2e6)]
    executed = []          # (schedule, params fingerprint at execution)
    refit_at = None
    for step in range(4 * len(sizes)):
        n, size = sizes[step % len(sizes)]
        resp, meas = measure(n, size)
        # "execute" the schedule this step: record its identity
        executed.append(resp.schedule)
        out = svc.observe("root_sw", n, size, meas,
                          predicted=resp.predicted_time, key=resp.key)
        if out["refit"] and refit_at is None:
            refit_at = len(executed)
            stale = set(map(id, executed))
    assert refit_at is not None, "loop never refit"

    # no stale CompiledSchedule executed after the swap
    post_swap = executed[refit_at:]
    assert post_swap, "no steps ran after the refit"
    assert all(id(s) not in stale for s in post_swap)

    # converged: post-refit predictions track measurements within 10%
    for n, size in sizes:
        resp, meas = measure(n, size)
        assert abs(resp.predicted_time - meas) / meas < 0.10, \
            f"post-refit divergence at n={n} S={size}"
