"""Per-family executed schedules ≡ lax references on a real 8-device mesh.

The acceptance bar for the multi-family Plan IR (DESIGN.md §14): every
lowered family schedule — AllGather / ReduceScatter halves of the axis's
GenTree AllReduce plan, the flat AllToAll exchange, and the P2P shift —
must match its `lax` reference (`all_gather` / `psum_scatter` /
`all_to_all` / `ppermute`) within 1e-6 on 8 host CPU devices, including
the Table-6 two-level mesh; the strategy-dispatch round-trip
`collectives.all_gather(collectives.reduce_scatter(x, s), s)` must equal
psum for every strategy on non-power-of-two axes and non-aligned sizes
(the hcps shard-order bug this PR fixes); and the expert-parallel MoE
dispatch (`moe_dispatch="ep"`) must match the single-device sorted block
both over `lax.all_to_all` and over a planner-lowered AllToAll schedule,
with `deepseek_moe_16b` training end to end under `sync="plan"`.

Like test_exec_equivalence.py, one subprocess (XLA_FLAGS device-count=8)
runs every multi-device case and prints one RESULTS json line; the
hypothesis sweep rides in the same subprocess when installed. Plain
single-process tests at the bottom pin `get_step_plan`'s
pricing-consistency invariant (Σ family terms ≡ joint quote at 1e-9) and
the per-call-dominance ratio ≤ 1.
"""
import json
import os
import subprocess
import sys

import pytest

_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map
from repro.core import collectives, topology
from repro.core import sync as sync_mod
from repro.core.gentree import family_plan, gentree
from repro.core.lower import lower_plan
from repro.core.plans import family_halves
from repro.planner.service import PlannerService

results = {}


def mesh_of(n):
    return Mesh(np.array(jax.devices()[:n]), ("x",))


def rand(n, size, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, size),
                             jnp.float32).astype(dtype)


def relerr(got, want):
    got = np.asarray(got).astype(np.float64)
    want = np.asarray(want).astype(np.float64)
    return float(np.abs(got - want).max() / (np.abs(want).max() + 1e-30))


def run_pair(n, f_got, f_want, size, seed=0):
    x = rand(n, size, seed)
    m = mesh_of(n)
    g = shard_map(lambda v: f_got(v[0])[None], mesh=m,
                  in_specs=P("x"), out_specs=P("x"))
    w = shard_map(lambda v: f_want(v[0])[None], mesh=m,
                  in_specs=P("x"), out_specs=P("x"))
    return relerr(jax.jit(g)(x), jax.jit(w)(x))


# ---- planned family schedules vs lax references ---------------------------
# Schedules from both a flat single-switch mesh and the Table-6-style
# two-level tree (2 middle switches x 4 servers) — the lowered halves of
# a multi-level GenTree plan must keep the same device<->shard contract.
TOPOS = {"ss8": topology.single_switch(8),
         "table6": topology.symmetric_tree(2, 4)}
for tname, topo in TOPOS.items():
    size = 1024
    ag = lower_plan(family_plan("allgather", topo, float(size)))
    rs = lower_plan(family_plan("reduce_scatter", topo, float(size)))
    err = run_pair(
        8, lambda v: ag.all_gather(v, "x"),
        lambda v: jax.lax.all_gather(v, "x", axis=0, tiled=True), size // 8)
    results[f"ag_{tname}_err"] = err
    results[f"ag_{tname}"] = err < 1e-6
    err = run_pair(
        8, lambda v: rs.reduce_scatter(v, "x"),
        lambda v: jax.lax.psum_scatter(v, "x", scatter_dimension=0,
                                       tiled=True), size)
    results[f"rs_{tname}_err"] = err
    results[f"rs_{tname}"] = err < 1e-6

a2a = lower_plan(family_plan("all_to_all", TOPOS["ss8"], 4096.0))
err = run_pair(
    8, lambda v: a2a.all_to_all(v, "x"),
    lambda v: jax.lax.all_to_all(v.reshape((8, -1)), "x", split_axis=0,
                                 concat_axis=0).reshape(v.shape), 64)
results["a2a_err"] = err
results["a2a"] = err < 1e-6

p2p = lower_plan(family_plan("p2p", TOPOS["ss8"], 512.0))
err = run_pair(
    8, lambda v: p2p.p2p(v, "x"),
    lambda v: jax.lax.ppermute(v, "x",
                               [(i, (i + 1) % 8) for i in range(8)]), 64)
results["p2p_err"] = err
results["p2p"] = err < 1e-6


# ---- strategy round-trips: all_gather(reduce_scatter(x)) == psum ----------
# Non-power-of-two axes and non-aligned sizes exercise the zero-pad path;
# hcps exercises the digit-reversed shard-order un-reorder in the
# all_gather dispatch (calling all_gather_hcps directly on the reordered
# reduce_scatter shard block-permutes the vector — the bug this PR fixes).
def roundtrip(n, strategy, size, factors=None, seed=3):
    def got(v):
        flat = v.reshape(-1)
        pad = (-flat.size) % collectives._pad_multiple(n, strategy)
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        shard = collectives.reduce_scatter(flat, "x", strategy,
                                           factors=factors)
        full = collectives.all_gather(shard, "x", strategy, factors=factors)
        if pad:
            full = full[:-pad]
        return full.reshape(v.shape)
    return run_pair(n, got, lambda v: jax.lax.psum(v, "x"), size, seed=seed)

for n, size, label in [(8, 37, "n8_s37"), (6, 37, "n6_s37"), (6, 96, "n6")]:
    for strat in ("psum", "ring", "cps", "rhd"):
        err = roundtrip(n, strat, size)
        results[f"rt_{strat}_{label}_err"] = err
        results[f"rt_{strat}_{label}"] = err < 1e-6
err = roundtrip(8, "hcps", 37, factors=[2, 2, 2])
results["rt_hcps_n8_s37_err"] = err
results["rt_hcps_n8_s37"] = err < 1e-6
err = roundtrip(6, "hcps", 37, factors=[2, 3])
results["rt_hcps_n6_s37_err"] = err
results["rt_hcps_n6_s37"] = err < 1e-6

# the un-reorder is load-bearing: the raw hcps doubling phase on the
# natural-order shard must NOT reproduce psum (factors [2,2,2] digit
# reversal swaps shards 1<->4 and 3<->6)
def hcps_raw(v):
    shard = collectives.reduce_scatter(v.reshape(-1), "x", "hcps",
                                       factors=[2, 2, 2])
    return collectives.all_gather_hcps(shard, "x", [2, 2, 2]).reshape(v.shape)
results["hcps_raw_misorders"] = run_pair(
    8, hcps_raw, lambda v: jax.lax.psum(v, "x"), 64, seed=5) > 1e-3


# ---- expert-parallel MoE dispatch == sorted reference block ---------------
from repro.models import layers

def moe_case(sched):
    n, E, k, D, ntok = 8, 8, 2, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    p = {"wi": jax.random.normal(ks[0], (E, D, 24), jnp.float32) * 0.1,
         "wg": jax.random.normal(ks[1], (E, D, 24), jnp.float32) * 0.1,
         "wo": jax.random.normal(ks[2], (E, 24, D), jnp.float32) * 0.1}
    xt = jax.random.normal(ks[3], (n, ntok, D), jnp.float32)
    logits = jax.random.normal(ks[4], (n, ntok, E), jnp.float32)
    topv, topi = jax.lax.top_k(jax.nn.softmax(logits), k)
    topv = topv / topv.sum(-1, keepdims=True)
    m = mesh_of(n)
    ref = shard_map(
        lambda x, ti, tv: layers._moe_sorted_block(
            x[0], ti[0], tv[0], p, E, k, D, 1.25)[None],
        mesh=m, in_specs=(P("x"),) * 3, out_specs=P("x"))
    want = jax.jit(ref)(xt, topi, topv)
    with sync_mod.expert_parallel("x", n, sched):
        ep = shard_map(
            lambda x, ti, tv: layers._moe_ep(
                p, x[0], ti[0], tv[0], None, E, k, D, 1.25)[None],
            mesh=m, in_specs=(P("x"),) * 3, out_specs=P("x"))
        got = jax.jit(ep)(xt, topi, topv)
    return relerr(got, want)

err = moe_case(None)
results["moe_ep_lax_err"] = err
results["moe_ep_lax"] = err < 1e-6
svc = PlannerService()
sched = svc.get_family_executable("all_to_all", "x", 8, 4096.0).schedule
results["moe_ep_sched_lowered"] = sched is not None
err = moe_case(sched)
results["moe_ep_plan_err"] = err
results["moe_ep_plan"] = err < 1e-6


# ---- acceptance: deepseek_moe_16b trains under sync="plan" with EP --------
from repro.launch.train import run_training, TrainConfig

ep_calls = [0]
_orig_moe_ep = layers._moe_ep
def _counting_moe_ep(*a, **kw):
    ep_calls[0] += 1
    return _orig_moe_ep(*a, **kw)
layers._moe_ep = _counting_moe_ep
try:
    res_plan = run_training(TrainConfig(arch="deepseek_moe_16b", steps=2,
                                        engine="manual", sync="plan",
                                        seq_len=16, global_batch=8),
                            smoke=True)
finally:
    layers._moe_ep = _orig_moe_ep
res_psum = run_training(TrainConfig(arch="deepseek_moe_16b", steps=2,
                                    engine="manual", sync="psum",
                                    seq_len=16, global_batch=8), smoke=True)
lp = [float(x) for x in res_plan["losses"]]
ls = [float(x) for x in res_psum["losses"]]
results["train_moe_plan_finite"] = bool(np.all(np.isfinite(lp)))
results["train_moe_ep_dispatch_used"] = ep_calls[0] > 0
dl = max(abs(a - b) for a, b in zip(lp, ls))
results["train_moe_loss_delta"] = dl
results["train_moe_plan_matches_psum"] = bool(dl < 1e-3)


# ---- hypothesis sweep (CI; skipped when hypothesis is absent) -------------
try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False
results["hypothesis_ran"] = HAVE_HYP
if HAVE_HYP:
    @settings(max_examples=10, deadline=None)
    @given(family=hst.sampled_from(["allgather", "reduce_scatter",
                                    "all_to_all", "p2p"]),
           tname=hst.sampled_from(["ss8", "table6"]),
           chunk=hst.integers(1, 40), seed=hst.integers(0, 10**6))
    def fam_sweep(family, tname, chunk, seed):
        cs = lower_plan(family_plan(family, TOPOS[tname], float(8 * chunk)))
        if family == "allgather":
            err = run_pair(8, lambda v: cs.all_gather(v, "x"),
                           lambda v: jax.lax.all_gather(
                               v, "x", axis=0, tiled=True), chunk, seed=seed)
        elif family == "reduce_scatter":
            err = run_pair(8, lambda v: cs.reduce_scatter(v, "x"),
                           lambda v: jax.lax.psum_scatter(
                               v, "x", scatter_dimension=0, tiled=True),
                           8 * chunk, seed=seed)
        elif family == "all_to_all":
            err = run_pair(8, lambda v: cs.all_to_all(v, "x"),
                           lambda v: jax.lax.all_to_all(
                               v.reshape((8, -1)), "x", split_axis=0,
                               concat_axis=0).reshape(v.shape),
                           8 * chunk, seed=seed)
        else:
            err = run_pair(8, lambda v: cs.p2p(v, "x"),
                           lambda v: jax.lax.ppermute(
                               v, "x", [(i, (i + 1) % 8) for i in range(8)]),
                           chunk, seed=seed)
        assert err < 1e-6, (family, tname, chunk, err)

    @settings(max_examples=10, deadline=None)
    @given(n=hst.sampled_from([5, 6, 7, 8]),
           strat=hst.sampled_from(["psum", "ring", "cps", "rhd"]),
           size=hst.integers(1, 200), seed=hst.integers(0, 10**6))
    def rt_sweep(n, strat, size, seed):
        err = roundtrip(n, strat, size, seed=seed)
        assert err < 1e-6, (n, strat, size, err)

    try:
        fam_sweep()
        rt_sweep()
        results["hypothesis_sweep"] = True
    except Exception as e:
        results["hypothesis_sweep"] = False
        results["hypothesis_error"] = repr(e)[:500]

print("RESULTS " + json.dumps(results))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


@pytest.mark.parametrize("key", [
    "ag_ss8", "rs_ss8", "ag_table6", "rs_table6", "a2a", "p2p",
    "rt_psum_n8_s37", "rt_ring_n8_s37", "rt_cps_n8_s37", "rt_rhd_n8_s37",
    "rt_psum_n6_s37", "rt_ring_n6_s37", "rt_cps_n6_s37", "rt_rhd_n6_s37",
    "rt_psum_n6", "rt_ring_n6", "rt_cps_n6", "rt_rhd_n6",
    "rt_hcps_n8_s37", "rt_hcps_n6_s37", "hcps_raw_misorders",
    "moe_ep_lax", "moe_ep_sched_lowered", "moe_ep_plan",
    "train_moe_plan_finite", "train_moe_ep_dispatch_used",
    "train_moe_plan_matches_psum"])
def test_family_schedules(results, key):
    assert results[key] is True, (key, results)


def test_hypothesis_sweep_when_available(results):
    if not results["hypothesis_ran"]:
        pytest.skip("hypothesis not installed")
    assert results["hypothesis_sweep"] is True, results.get(
        "hypothesis_error")


# ---- single-process: whole-step pricing consistency -----------------------
MIX = {"allreduce": {"count": 4, "size_floats": 1 << 20},
       "reduce_scatter": {"count": 2, "size_floats": 1 << 18},
       "allgather": {"count": 2, "size_floats": 1 << 18},
       "all_to_all": {"count": 6, "size_floats": 1 << 16},
       "p2p": {"count": 1, "size_floats": 1 << 14}}


def _service():
    from repro.planner.service import PlannerService
    return PlannerService()


def test_step_plan_pricing_consistency():
    """Σ per-family joint terms must equal the joint total exactly (1e-9)
    — the StepPlan invariant DESIGN.md §14 documents."""
    svc = _service()
    sp = svc.get_step_plan([("data", 8)], MIX)
    total = 0.0
    for fam, q in sp.quotes.items():
        assert q["joint"], fam
        fam_total = sum(q["joint"].values())
        assert abs(fam_total - q["joint_total"]) <= \
            1e-9 * max(1.0, q["joint_total"]), (fam, fam_total, q)
        total += fam_total
    assert abs(total - sp.total_joint) <= 1e-9 * max(1.0, sp.total_joint)


def test_step_plan_ratio_bounded():
    """Joint planning may never lose to naïve per-call planning — the
    per-call regime is in the argmin, so ratio ≤ 1 by construction."""
    svc = _service()
    sp = svc.get_step_plan([("data", 8)], MIX)
    assert 0.0 < sp.ratio <= 1.0 + 1e-12, sp.ratio
    assert sp.total_best <= sp.total_per_call * (1 + 1e-12)
    for fam in MIX:
        assert fam in sp.schedules, fam


def test_step_plan_from_module_stats():
    """A ModuleStats census (the analyze_hlo output) prices through the
    same path as an explicit mix spec."""
    from repro.launch.hlo_analysis import ModuleStats
    stats = ModuleStats()
    stats.add_coll("all-reduce", 2.0 * 4096, payload=4096.0)
    stats.add_coll("all-to-all", 0.875 * 2048, payload=2048.0)
    svc = _service()
    sp = svc.get_step_plan([("data", 8)], stats)
    assert set(sp.quotes) == {"allreduce", "all_to_all"}
    assert sp.quotes["allreduce"]["count"] == 1
