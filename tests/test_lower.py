"""core.lower: Plan IR → executable schedule compilation.

Covers the block-annotation contract of every builder, the symbolic
structural validation (duplicate block reduce, fan mismatch, incomplete
gather — the LoweringError paths), the ReduceScatter/AllGather boundary +
canonical shard layout, and numerical equivalence of the compiled
schedule via the pure-numpy executor (`run_numpy`), including a
hypothesis sweep over random tree topologies and sizes. The jax
(shard_map) execution of the same schedules is exercised on a real
8-device mesh by tests/test_exec_equivalence.py.
"""
import math

import numpy as np
import pytest
from _hypothesis_stub import given, settings, strategies as st

from repro.core import plans
from repro.core.gentree import gentree, baseline_plan
from repro.core.lower import (CompiledSchedule, LoweringError, lower_plan)
from repro.core.plans import Plan, ReduceOp, Step, Transfer
from repro.core import topology as topo_mod


RNG = np.random.default_rng(7)


def _exec_ok(plan, placement=None, size=None, rtol=1e-9) -> CompiledSchedule:
    cs = lower_plan(plan, placement=placement)
    X = RNG.normal(size=(plan.n, size or 40))
    out = cs.run_numpy(X)
    assert np.allclose(out, np.tile(X.sum(0), (plan.n, 1)),
                       rtol=rtol, atol=1e-9), plan.name
    return cs


# ---------------------------------------------------------------------------
# Flat builders lower and execute
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 3, 4, 5, 6, 8, 12, 15])
@pytest.mark.parametrize("builder", [plans.ring, plans.cps, plans.rhd,
                                     plans.reduce_broadcast])
def test_flat_builders_execute(builder, n):
    _exec_ok(builder(n, float(4 * n * 8)))


@pytest.mark.parametrize("factors", [[4, 2], [2, 4], [2, 2, 2], [3, 2],
                                     [2, 3], [5, 3], [2, 2, 3]])
def test_hcps_executes(factors):
    n = math.prod(factors)
    _exec_ok(plans.hcps(factors, float(n * 8)))


def test_non_contiguous_server_ids_need_placement():
    p = plans.ring(4, 16.0, servers=[3, 11, 5, 7])
    cs = _exec_ok(p)            # default placement: sorted ids → 0..3
    assert cs.placement == (3, 5, 7, 11)
    # explicit placement map works too
    _exec_ok(p, placement={3: 2, 11: 0, 5: 1, 7: 3})
    with pytest.raises(LoweringError, match="placement"):
        lower_plan(p, placement={3: 0, 11: 0, 5: 1, 7: 2})


# ---------------------------------------------------------------------------
# GenTree plans (both engines) lower and execute; RS boundary is sane
# ---------------------------------------------------------------------------
TOPOS = {
    "ss8": lambda: topo_mod.single_switch(8),
    "ss15": lambda: topo_mod.single_switch(15),
    "sym2x4": lambda: topo_mod.symmetric_tree(2, 4),
    "sym4x6": lambda: topo_mod.symmetric_tree(4, 6),
    "asym": lambda: topo_mod.asymmetric_tree(2, 4, 2),
    "cdc": lambda: topo_mod.cross_dc(dc0_middle=2, dc0_servers=3,
                                     dc1_middle=2, dc1_servers=2),
}


@pytest.mark.parametrize("tname", list(TOPOS))
@pytest.mark.parametrize("engine", ["fast", "reference"])
def test_gentree_plans_execute(tname, engine):
    topo = TOPOS[tname]()
    r = gentree(topo, 1e6, engine=engine)
    cs = _exec_ok(r.plan)
    n = topo.num_servers()
    assert cs.num_blocks == n
    # post-RS every block has exactly one owner; n blocks over n devices
    # means the trainer halves are available
    assert sorted(cs.owner_of_block.tolist()) == sorted(
        set(cs.owner_of_block.tolist()))
    assert cs.blocks_per_shard == 1


@pytest.mark.parametrize("kind", ["ring", "cps", "rhd", "hcps:4x2"])
def test_baseline_plans_execute(kind):
    topo = topo_mod.symmetric_tree(2, 4)
    _exec_ok(baseline_plan(kind, topo, 1e5))


def test_reduce_scatter_boundary_matches_mirror():
    r = gentree(topo_mod.symmetric_tree(2, 4), 1e6)
    cs = lower_plan(r.plan)
    assert len(cs.rs) == len(cs.ag) == len(r.plan.steps) // 2


# ---------------------------------------------------------------------------
# Structural validation — malformed plans are rejected with real messages
# ---------------------------------------------------------------------------
def _unit_plan(n=4, steps=None) -> Plan:
    return Plan("bad", n, float(n), steps=steps or [], num_blocks=n)


def test_rejects_unannotated_plan():
    p = Plan("legacy", 4, 4.0, steps=[Step()])
    with pytest.raises(LoweringError, match="block annotations"):
        lower_plan(p)


def test_rejects_duplicate_block_reduce():
    # server 1's contribution to block 0 folds at 2 AND at 3; then 3's
    # partial (containing srv 1 twice after the second fold) merges
    st1 = Step()
    st1.transfers = [Transfer(1, 2, 1.0, blocks=(0,)),
                     Transfer(1, 3, 1.0, blocks=(0,))]
    st1.reduces = [ReduceOp(2, 2, 1.0, blocks=(0,)),
                   ReduceOp(3, 2, 1.0, blocks=(0,))]
    st2 = Step()
    st2.transfers = [Transfer(2, 3, 1.0, blocks=(0,))]
    st2.reduces = [ReduceOp(3, 2, 1.0, blocks=(0,))]
    with pytest.raises(LoweringError, match="duplicate block reduce"):
        lower_plan(_unit_plan(steps=[st1, st2]))


def test_rejects_fan_in_mismatch():
    st = Step()
    st.transfers = [Transfer(1, 0, 1.0, blocks=(0,))]
    st.reduces = [ReduceOp(0, 4, 1.0, blocks=(0,))]
    with pytest.raises(LoweringError, match="fan_in=4"):
        lower_plan(_unit_plan(steps=[st]))


def test_rejects_reduce_without_copies():
    st = Step()
    st.reduces = [ReduceOp(0, 2, 1.0, blocks=(1,))]
    with pytest.raises(LoweringError, match="no incoming copies"):
        lower_plan(_unit_plan(steps=[st]))


def test_rejects_incomplete_gather():
    # a valid reduce of block 0 at server 0, but nothing is ever gathered
    st = Step()
    st.transfers = [Transfer(i, 0, 1.0, blocks=(0,)) for i in range(1, 4)]
    st.reduces = [ReduceOp(0, 4, 1.0, blocks=(0,))]
    with pytest.raises(LoweringError,
                       match="never fully reduced|incomplete gather"):
        lower_plan(_unit_plan(steps=[st]))


def test_rejects_size_annotation_mismatch():
    st = Step()
    st.transfers = [Transfer(1, 0, 3.0, blocks=(0,))]   # 1 block != 3 units
    st.reduces = [ReduceOp(0, 2, 1.0, blocks=(0,))]
    with pytest.raises(LoweringError, match="inconsistent"):
        lower_plan(_unit_plan(steps=[st]))


def test_rejects_ambiguous_write():
    # two copies converge with no reduce declared
    st = Step()
    st.transfers = [Transfer(1, 0, 1.0, blocks=(0,)),
                    Transfer(2, 0, 1.0, blocks=(0,))]
    with pytest.raises(LoweringError, match="no reduce"):
        lower_plan(_unit_plan(steps=[st]))


def test_rejects_double_fold_same_step():
    st = Step()
    st.transfers = [Transfer(1, 0, 1.0, blocks=(0,))]
    st.reduces = [ReduceOp(0, 2, 1.0, blocks=(0,)),
                  ReduceOp(0, 2, 1.0, blocks=(0,))]
    with pytest.raises(LoweringError, match="duplicate reduce"):
        lower_plan(_unit_plan(steps=[st]))


# ---------------------------------------------------------------------------
# Schedule shape: ppermute rounds are valid partial permutations
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("builder,n", [(plans.cps, 8), (plans.ring, 6),
                                       (plans.rhd, 6)])
def test_rounds_are_partial_permutations(builder, n):
    cs = lower_plan(builder(n, float(8 * n)))
    for step in cs.rs + cs.ag:
        for rd in step.rounds:
            srcs = [s for s, _ in rd.perm]
            dsts = [d for _, d in rd.perm]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)
            for s, d in rd.perm:
                assert (rd.send_blks[s] >= 0).any()
                assert rd.recv_off[d] >= 0


def test_multiblock_transfers_coalesce_into_one_round():
    """RHD's half-vector exchange is ONE ppermute per step, not one per
    block: rounds track the algorithm's step structure."""
    cs = lower_plan(plans.rhd(8, 64.0))
    assert all(len(st.rounds) == 1 for st in cs.rs + cs.ag)
    # halving step 0 moves 4 blocks in a single payload
    assert cs.rs[0].rounds[0].send_blks.shape[1] == 4


def test_cps_is_one_nary_fold():
    """The δ-optimal structure survives lowering: CPS folds each device's
    block in ONE N-ary fold phase (fan n), not a chain of pairwise adds."""
    n = 8
    cs = lower_plan(plans.cps(n, float(8 * n)))
    folds = cs.rs[0].folds
    assert len(folds) == 1
    assert (folds[0].ops >= 0).sum(axis=1).max() == n - 1
    assert folds[0].include_self.all()


# ---------------------------------------------------------------------------
# Hypothesis: random topologies / sizes / placements all execute correctly
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(shape=st.lists(st.integers(2, 4), min_size=1, max_size=2),
       size=st.integers(1, 97), seed=st.integers(0, 10**6))
def test_random_gentree_plans_execute(shape, size, seed):
    if len(shape) == 1:
        topo = topo_mod.single_switch(shape[0])
    else:
        topo = topo_mod.symmetric_tree(shape[0], shape[1])
    n = topo.num_servers()
    r = gentree(topo, float(max(size, n)))
    cs = lower_plan(r.plan)
    X = np.random.default_rng(seed).normal(size=(n, size))
    assert np.allclose(cs.run_numpy(X), np.tile(X.sum(0), (n, 1)),
                       rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 12), size=st.integers(1, 64),
       builder=st.sampled_from(["ring", "cps", "rhd", "reduce_broadcast"]),
       seed=st.integers(0, 10**6))
def test_random_flat_plans_execute(n, size, builder, seed):
    p = getattr(plans, builder)(n, float(8 * n))
    cs = lower_plan(p)
    X = np.random.default_rng(seed).normal(size=(n, size))
    assert np.allclose(cs.run_numpy(X), np.tile(X.sum(0), (n, 1)),
                       rtol=1e-9, atol=1e-9)
