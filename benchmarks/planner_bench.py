"""Planner service — cold vs. warm `get_plan` latency and cache hit rate.

Acceptance gate: a warm (memory-cached) lookup for a 64-server, 3-level
tree must be >= 100x faster than cold GenTree generation. Also reports the
disk-warm path (restart with a persisted cache) and the hit rate over a
sweep of message sizes that exercises the geometric buckets.
"""
from __future__ import annotations

import os
import tempfile
import time

from repro.core.topology import symmetric_tree
from repro.planner.service import PlannerService

from .common import fmt_table

REQUIRED_SPEEDUP = 100.0


def _median_seconds(fn, repeats: int = 15) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def run() -> dict:
    # 3 levels: root_sw -> 8 middle_sw -> 8 servers each = 64 servers.
    topo = symmetric_tree(8, 8)
    nbytes = 64 << 20

    svc = PlannerService()
    t0 = time.perf_counter()
    cold = svc.get_plan(topo, nbytes)
    cold_s = time.perf_counter() - t0
    assert cold.source == "cold"

    warm_s = _median_seconds(lambda: svc.get_plan(topo, nbytes))
    speedup = cold_s / warm_s

    # Disk-warm: persist, "restart" into a fresh service, first lookup
    # deserializes from JSON instead of re-running GenTree.
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "plans.json")
        svc.save(path)
        svc2 = PlannerService(cache_path=path)
        t0 = time.perf_counter()
        disk = svc2.get_plan(topo, nbytes)
        disk_s = time.perf_counter() - t0
        assert disk.source == "disk"

    # Hit rate over a size sweep: 24 sizes across 3 decades land in a
    # handful of geometric buckets, so most lookups are warm.
    sweep = PlannerService()
    for i in range(24):
        sweep.get_plan(topo, int(1e6 * 1.35 ** i))
    hit_rate = sweep.cache.stats.hit_rate

    rows = [
        {"path": "cold (GenTree + simulate)", "seconds": f"{cold_s:.4f}"},
        {"path": "warm (memory LRU)", "seconds": f"{warm_s:.6f}"},
        {"path": "warm (disk restart)", "seconds": f"{disk_s:.6f}"},
    ]
    print(fmt_table(rows, ["path", "seconds"],
                    "planner: get_plan latency, 64-server 3-level tree"))
    print(f"speedup cold/warm: {speedup:.0f}x (required >= "
          f"{REQUIRED_SPEEDUP:.0f}x)")
    print(f"size-sweep hit rate: {hit_rate:.0%} "
          f"({sweep.cache.stats.hits} hits / "
          f"{sweep.cache.stats.misses} misses, "
          f"{len(sweep.cache)} entries)")

    assert speedup >= REQUIRED_SPEEDUP, (
        f"warm get_plan only {speedup:.0f}x faster than cold "
        f"(need >= {REQUIRED_SPEEDUP:.0f}x)")
    return {"ok": True, "speedups": f"{speedup:.0f}x",
            "cold_s": cold_s, "warm_s": warm_s, "disk_s": disk_s,
            "hit_rate": hit_rate}


if __name__ == "__main__":
    run()
