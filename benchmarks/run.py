"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,table7]
                                            [--json BENCH_planner.json]
                                            [--trace BENCH_trace.json]
                                            [--metrics BENCH_metrics.json]

Each module prints its own human-readable table; this driver finishes with
a machine-readable `name,seconds,derived` CSV summary (and, with --json, a
JSON file mapping name -> {seconds, derived}). `--trace` enables the
process-wide span tracer for the whole run and exports a Chrome-trace
JSON (chrome://tracing / ui.perfetto.dev) with one top-level span per
bench; `--metrics` exports the metrics registry (JSON + sibling .prom).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig4,table7")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the summary as JSON, e.g. "
                         "BENCH_planner.json")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the span tracer for the whole run and "
                         "export a Chrome-trace JSON")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="export the metrics registry (JSON + .prom)")
    args = ap.parse_args()

    from repro.runtime.trace import default_tracer
    tracer = default_tracer()
    if args.trace:
        tracer.enabled = True

    from . import (bucket_bench, exec_bench, faults_bench, fig3_incast,
                   fig4_delta_microbench, fig8_model_accuracy,
                   overlap_bench, planner_bench, quant_bench, roofline,
                   simfast_bench, step_bench, table3_cpu_testbed,
                   table4_gpu_testbed, table5_fitting,
                   table6_plan_selection, table7_large_scale,
                   telemetry_bench)
    all_benches = [
        ("fig3", fig3_incast.run),
        ("fig4", fig4_delta_microbench.run),
        ("fig8", fig8_model_accuracy.run),
        ("table3", table3_cpu_testbed.run),
        ("table4", table4_gpu_testbed.run),
        ("table5", table5_fitting.run),
        ("table6", table6_plan_selection.run),
        ("table7", table7_large_scale.run),
        ("roofline", roofline.run),
        ("planner", planner_bench.run),
        ("simfast", simfast_bench.run),
        ("exec", exec_bench.run),
        ("bucket", bucket_bench.run),
        ("quant", quant_bench.run),
        ("step", step_bench.run),
        ("telemetry", telemetry_bench.run),
        ("faults", faults_bench.run),
        ("overlap", overlap_bench.run),
    ]
    only = set(args.only.split(",")) if args.only else None

    summary = []
    failed = 0
    for name, fn in all_benches:
        if only and name not in only:
            continue
        print(f"\n{'=' * 72}\n## {name}\n{'=' * 72}")
        t0 = time.perf_counter()
        try:
            with tracer.span(f"bench/{name}"):
                out = fn()
            derived = ""
            metrics = {}
            if isinstance(out, dict):
                for key in ("saving", "max", "max_gen_err", "speedups",
                            "ok", "worst"):
                    if key in out:
                        derived = f"{key}={out[key]}"
                        break
                # scalar metrics (e.g. cold-generation wall-clock) ride
                # into the --json summary so trajectories are tracked
                metrics = {k: v for k, v in out.items()
                           if isinstance(v, (int, float, str, bool))}
            summary.append((name, time.perf_counter() - t0, derived,
                            metrics))
        except Exception as e:   # pragma: no cover
            failed += 1
            summary.append((name, time.perf_counter() - t0,
                            f"ERROR {e!r}", {}))
            import traceback
            traceback.print_exc()

    print(f"\n{'=' * 72}\nname,seconds,derived")
    for name, dt, derived, _ in summary:
        print(f"{name},{dt:.2f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({name: {"seconds": round(dt, 4), "derived": derived,
                              **({"metrics": metrics} if metrics else {})}
                       for name, dt, derived, metrics in summary},
                      f, indent=2)
        print(f"wrote {args.json}")
    if args.trace:
        tracer.export_chrome(args.trace)
        print(f"wrote {args.trace} ({len(tracer.spans)} spans)")
    if args.metrics:
        from repro.runtime.metrics import default_metrics
        default_metrics().export(args.metrics)
        print(f"wrote {args.metrics}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
