"""Table 4 — GPU-testbed AllReduce: n DGX-like machines × 8 GPUs,
GenTree's hierarchical plan (intra-machine reduce + inter-machine CPS)
vs a global Ring ("NCCL"). Simulated with NVLink-class intra-machine
bandwidth and 4×200 Gbps NICs per machine, GDR on."""
from __future__ import annotations

import dataclasses

from repro.core.cost_model import GenModelParams
from repro.core.gentree import baseline_plan, gentree
from repro.core.simulator import Simulator
from repro.core.topology import TopoNode, _server
from .common import fmt_table

GBPS = 1e9 / 8.0

# level params: intra-machine fabric is NVLink-fast with high w_t (NVSwitch
# has no PFC incast); the inter-machine fabric keeps the RoCE ε/w_t.
GPU_PARAMS = {
    "root_sw": GenModelParams(alpha=2e-5, beta=6.4e-12, gamma=0.0,
                              delta=0.0, epsilon=6.0e-13, w_t=9),
    "middle_sw": GenModelParams(alpha=1e-5, beta=3.2e-12, gamma=0.0,
                                delta=0.0, epsilon=0.0, w_t=64),
    "server": GenModelParams(alpha=5e-6, beta=0.0, gamma=5e-13,
                             delta=2e-13, epsilon=0.0, w_t=64),
    "cross_dc": GenModelParams(alpha=2e-5, beta=6.4e-12, gamma=0.0,
                               delta=0.0, epsilon=6.0e-13, w_t=9),
}


def dgx_cluster(machines: int, gpus: int = 8) -> TopoNode:
    root = TopoNode(name="spine", level="root_sw")
    for m in range(machines):
        mach = TopoNode(name=f"dgx{m}", uplink_bw=4 * 200 * GBPS,
                        uplink_latency=2e-6, level="middle_sw")
        mach.children = [_server(f"g{m}_{i}", 600e9, 1e-6)   # NVLink-ish
                         for i in range(gpus)]
        root.children.append(mach)
    return root.finalize()


def run(sizes=(1e7, 3.2e7, 1e8, 3.2e8), machines=(2, 4, 8)) -> dict:
    rows = []
    speed = {}
    for m in machines:
        topo = dgx_cluster(m)
        sim = Simulator(topo, GPU_PARAMS)
        for s in sizes:
            r = gentree(topo, s, params=GPU_PARAMS)
            t_ring = sim.simulate(baseline_plan("ring", topo, s)).total
            sp = t_ring / r.predicted_time
            speed[(m, s)] = sp
            rows.append({"#GPUs": m * 8, "size": f"{s:.1e}",
                         "GenTree_ms": f"{r.predicted_time * 1e3:.3f}",
                         "Ring(NCCL)_ms": f"{t_ring * 1e3:.3f}",
                         "speedup": f"{sp:.2f}×"})
    print(fmt_table(rows, ["#GPUs", "size", "GenTree_ms", "Ring(NCCL)_ms",
                           "speedup"],
                    "Table 4 — GPU testbed (simulated, GenTree vs global "
                    "Ring)"))
    mx = max(speed.values())
    print(f"max speedup {mx:.2f}× (paper: 1.65× over NCCL, converging "
          f"to ~1.2× at scale)")
    return {"speedups": speed, "max": mx}


if __name__ == "__main__":
    run()
