"""§Roofline — render the per-(arch × shape) roofline table from the
dry-run artifacts (artifacts/dryrun_single_pod.json). Re-run the dry-run
with  `python -m repro.launch.dryrun --all --json artifacts/...`  to
refresh. Falls back to lowering a single fast cell live if no artifact
exists (keeps `python -m benchmarks.run` self-contained)."""
from __future__ import annotations

import json
import os

from .common import fmt_table

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "dryrun_single_pod.json")


def load_results(path: str = ART) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)["results"]


def run() -> dict:
    results = load_results()
    if not results:
        print("no dry-run artifact found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--json artifacts/dryrun_single_pod.json` first")
        return {}
    rows = []
    worst = None
    most_coll = None
    for r in results:
        if "skipped" in r or "error" in r:
            continue
        frac = r.get("roofline_fraction", 0.0)
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_comp_ms": f"{r['compute_s'] * 1e3:.1f}",
            "t_mem_ms": f"{r['memory_s'] * 1e3:.1f}",
            "t_coll_ms": f"{r['collective_s'] * 1e3:.1f}",
            "dominant": r["dominant"],
            "useful": f"{r['useful_ratio']:.2f}",
            "roofline": f"{frac:.3f}"})
        if worst is None or frac < worst[1]:
            worst = (f"{r['arch']}×{r['shape']}", frac)
        cr = r["collective_s"] / max(r["compute_s"] + r["memory_s"], 1e-12)
        if most_coll is None or cr > most_coll[1]:
            most_coll = (f"{r['arch']}×{r['shape']}", cr)
    print(fmt_table(rows, ["arch", "shape", "t_comp_ms", "t_mem_ms",
                           "t_coll_ms", "dominant", "useful", "roofline"],
                    "§Roofline — single-pod (16×16) baseline, "
                    "197 TFLOP/s · 819 GB/s · 50 GB/s"))
    print(f"worst roofline fraction: {worst[0]} ({worst[1]:.3f}); "
          f"most collective-bound: {most_coll[0]}")
    return {"worst": worst, "most_collective": most_coll, "rows": rows}


if __name__ == "__main__":
    run()
