"""Table 3 — CPU-testbed AllReduce comparison (GenTree vs CPS / Ring /
RHD at 8, 12, 15 servers, S = 1e8 floats), on the simulator with the
paper's fitted parameters. Expected pattern (paper): GenTree ≤ all
baselines; RHD collapses at non-power-of-two N."""
from __future__ import annotations

from repro.core.cost_model import PAPER_TABLE5
from repro.core.gentree import baseline_plan, gentree
from repro.core.simulator import Simulator
from repro.core.topology import single_switch
from .common import fmt_table


def run(s: float = 1e8, ns=(8, 12, 15)) -> dict:
    rows = {}
    algos = ["gentree", "cps", "ring", "rhd"]
    table = {a: {} for a in algos}
    decisions = {}
    for n in ns:
        topo = single_switch(n)
        sim = Simulator(topo, PAPER_TABLE5)
        r = gentree(topo, s)
        table["gentree"][n] = r.predicted_time
        decisions[n] = (r.decisions["root"].algo,
                        r.decisions["root"].factors)
        for kind in ("cps", "ring", "rhd"):
            table[kind][n] = sim.simulate(baseline_plan(kind, topo, s)).total
    rows = [{"algorithm": a,
             **{f"N={n}": f"{table[a][n]:.3f}" for n in ns}}
            for a in algos]
    print(fmt_table(rows, ["algorithm"] + [f"N={n}" for n in ns],
                    "Table 3 — CPU testbed (simulated, seconds, S=1e8)"))
    print("GenTree choices:", {n: f"{a}{f or ''}"
                               for n, (a, f) in decisions.items()})
    speedups = {}
    for n in ns:
        best_base = min(table[a][n] for a in ("cps", "ring", "rhd"))
        worst_base = max(table[a][n] for a in ("cps", "ring", "rhd"))
        speedups[n] = {
            "vs_best": best_base / table["gentree"][n],
            "vs_worst": worst_base / table["gentree"][n]}
        print(f"N={n}: speedup vs best baseline "
              f"{speedups[n]['vs_best']:.2f}×, vs worst (incl. RHD) "
              f"{speedups[n]['vs_worst']:.2f}×  "
              f"(paper: up to 2.4×, 1.2× excl. RHD)")
    return {"table": table, "speedups": speedups, "decisions": decisions}


if __name__ == "__main__":
    run()
