"""Table 6 — the AllReduce plans GenTree selects per switch-local sub-tree
on the paper's six evaluation topologies × three data sizes."""
from __future__ import annotations

from repro.core import topology as T
from repro.core.gentree import gentree
from .common import fmt_table

TOPOS = {
    "SS24": lambda: T.single_switch(24),
    "SS32": lambda: T.single_switch(32),
    "SYM384": lambda: T.symmetric_tree(16, 24),
    "SYM512": lambda: T.symmetric_tree(16, 32),
    "ASY384": lambda: T.asymmetric_tree(16, 32, 16),
    "CDC384": lambda: T.cross_dc(),
}


def _summarize(decisions) -> dict[str, str]:
    """Collapse per-switch decisions into level classes (paper style)."""
    out = {}
    for name, d in sorted(decisions.items()):
        label = d.algo + ("x".join(map(str, d.factors))
                          if d.factors else "")
        if d.rearrange:
            label += "+rearr"
        key = ("Root SW" if name in ("root", "wan_root")
               else "DC Root" if name in ("dc0", "dc1")
               else "Middle SW")
        out.setdefault(key, set()).add(label)
    return {k: "/".join(sorted(v)) for k, v in out.items()}


def run(sizes=(1e7, 3.2e7, 1e8)) -> dict:
    rows = []
    all_dec = {}
    for tname, builder in TOPOS.items():
        for s in sizes:
            r = gentree(builder(), s)
            summ = _summarize(r.decisions)
            all_dec[(tname, s)] = summ
            for lvl, plan in summ.items():
                rows.append({"network": tname, "size": f"{s:.1e}",
                             "sub-tree": lvl, "plan": plan})
    print(fmt_table(rows, ["network", "size", "sub-tree", "plan"],
                    "Table 6 — GenTree plan selection"))
    return all_dec


if __name__ == "__main__":
    run()
