"""Guarded-execution overhead gates (DESIGN.md §12).

Every collective on the plan path now launches through `GuardedSchedule`
(retry + fallback ladder + injector poll + launch accounting). Two gates
keep that armor cheap:

  * **guarded-launch overhead < 3%** — the guarded `run_numpy` of a real
    lowered plan vs. the bare schedule. The guard's per-launch work
    (metrics counter, injector poll, wall-clock bracket) must be noise
    next to the collective it wraps.
  * **fallback-path overhead < 3%** — a *demoted* guard (sticky flat
    rung after a failure) dispatching its fallback vs. calling the
    fallback directly. Demotion must cost one failed attempt, not a per
    -launch tax.

An empty scoped FaultInjector masks any ambient $REPRO_FAULT_PLAN so the
measurement is deterministic. `benchmarks.run --json` records
`guarded_overhead_pct` / `fallback_overhead_pct` in BENCH_core.json.

    PYTHONPATH=src python -m benchmarks.faults_bench
"""
from __future__ import annotations

import time

import numpy as np

from .common import fmt_table

REPEATS = 30
N = 8
COLS = 200_000          # ~12.8 MB across the axis: ms-scale run_numpy


def _paired_times(fn_a, fn_b, repeats: int = REPEATS
                  ) -> tuple[float, float]:
    """Best-of-N for two paths, interleaved so ambient load (CI noise,
    co-running jobs) hits both equally. Minima, not medians: the floor
    is the intrinsic cost; everything above it is scheduler noise that
    would otherwise dominate a small relative overhead."""
    fn_a(), fn_b()                         # warm up both paths
    best_a = best_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def run() -> dict:
    from repro.core.lower import GuardedSchedule, GuardPolicy
    from repro.planner.service import PlannerService
    from repro.runtime.faults import FaultInjector, FaultPlan

    svc = PlannerService()
    inner = svc.get_axis_executable("data", N, float(COLS)).schedule
    X = np.random.default_rng(0).normal(size=(N, COLS))

    with FaultInjector(FaultPlan()):       # mask ambient chaos plans
        # ---- gate 1: guarded launch vs bare schedule ----------------------
        guarded = GuardedSchedule(inner)
        t_bare, t_guard = _paired_times(lambda: inner.run_numpy(X),
                                        lambda: guarded.run_numpy(X))
        guard_pct = 100.0 * (t_guard - t_bare) / t_bare

        # ---- gate 2: demoted fallback dispatch vs direct call -------------
        demoted = GuardedSchedule(
            inner, policy=GuardPolicy(max_retries=0, backoff=0.0))

        def planned_rung():
            raise RuntimeError("planned rung down")

        def flat_rung():
            return inner.run_numpy(X)

        # one real failure demotes; the ladder then serves the flat rung
        demoted._guarded("allreduce", planned_rung, flat_rung)
        assert demoted.demoted
        t_direct, t_ladder = _paired_times(
            flat_rung,
            lambda: demoted._guarded("allreduce", planned_rung, flat_rung))
        fallback_pct = 100.0 * (t_ladder - t_direct) / t_direct

    rows = [
        {"path": "guarded launch", "bare_ms": t_bare * 1e3,
         "armored_ms": t_guard * 1e3, "overhead_pct": guard_pct},
        {"path": "demoted fallback", "bare_ms": t_direct * 1e3,
         "armored_ms": t_ladder * 1e3, "overhead_pct": fallback_pct},
    ]
    print(fmt_table(
        [{k: (f"{v:.3f}" if isinstance(v, float) else v)
          for k, v in r.items()} for r in rows],
        ["path", "bare_ms", "armored_ms", "overhead_pct"],
        "guarded execution overhead (n=%d, %d cols)" % (N, COLS)))

    ok = guard_pct < 3.0 and fallback_pct < 3.0
    print(f"guarded-launch overhead {guard_pct:.2f}% "
          f"(gate < 3%), fallback-path overhead {fallback_pct:.2f}% "
          f"(gate < 3%): {'OK' if ok else 'FAIL'}")
    return {"ok": ok,
            "guarded_overhead_pct": round(guard_pct, 3),
            "fallback_overhead_pct": round(fallback_pct, 3),
            "guarded_launches": guarded.stats["launches"],
            "demoted_launches": demoted.stats["demoted_launches"]}


if __name__ == "__main__":
    run()
