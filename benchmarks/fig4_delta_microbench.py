"""Figure 4 — the memory-access (δ) microbenchmark, run FOR REAL on this
container's CPU: add x vectors at once for x = 2..N and fit
T(x) = (x+1)·S·δ + (x−1)·S·γ. Confirms the paper's claim that the average
per-add cost falls as fan-in grows (up to 66.7 % saving), and yields a
real (δ, γ) pair for this host.
"""
from __future__ import annotations

import numpy as np

from repro.core.fitting import fit_delta_gamma
from .common import fmt_table, timed


def run(s: int = 4_000_000, xs=tuple(range(2, 13))) -> dict:
    vecs = np.random.default_rng(0).standard_normal((max(xs), s)) \
        .astype(np.float32)
    rows = []
    times = []
    for x in xs:
        chunk = vecs[:x]

        def fused():
            return chunk.sum(axis=0)          # one x-ary pass

        _, t = timed(fused, repeats=3)
        times.append(t)
        rows.append({"x": x, "time_s": f"{t:.4f}",
                     "per_add_ms": f"{t / (x - 1) * 1e3:.2f}"})

    # chained pairwise baseline at max fan-in (the Ring compute pattern)
    x = max(xs)

    def chained():
        acc = vecs[0].copy()
        for i in range(1, x):
            acc += vecs[i]
        return acc

    _, t_chain = timed(chained, repeats=3)

    delta, gamma = fit_delta_gamma(np.array(xs, float), np.array(times), s)
    per_add_2 = times[0] / (xs[0] - 1)
    per_add_max = times[-1] / (xs[-1] - 1)
    saving = 1 - per_add_max / per_add_2
    print(fmt_table(rows, ["x", "time_s", "per_add_ms"],
                    "Fig. 4 — x-ary fused add microbenchmark (real CPU)"))
    print(f"chained pairwise x={x}: {t_chain:.4f}s vs fused {times[-1]:.4f}s"
          f"  (fused {t_chain / times[-1]:.2f}× faster)")
    print(f"fitted δ={delta:.3e} s/float, γ={gamma:.3e} s/float; "
          f"per-add saving at x={xs[-1]}: {saving:.1%} "
          f"(paper: up to 66.7 %)")
    return {"delta": delta, "gamma": gamma, "saving": saving,
            "chain_over_fused": t_chain / times[-1]}


if __name__ == "__main__":
    run()
