"""Gradient bucket-size sweep on SYM512-style meshes (DESIGN.md §9).

For each mesh-axis factorization the bench sweeps powers-of-two bucket
sizes through `PlannerService.get_bucket_plan` and prints the modeled
double-buffered pipeline time next to the serial (unpipelined) and
per-leaf (one schedule launch per gradient leaf — the pre-bucketing
execution model) baselines. Gates:

  * the chosen bucket size IS the GenModel argmin of the sweep;
  * modeled pipelined time <= serial time at the chosen size;
  * modeled pipelined time < modeled per-leaf time on every mesh
    (the Table-6-style topologies of the acceptance criteria).

`benchmarks.run --json` records `bucket_sweep_best_ms` (flagship mesh,
SYM512) and `pipeline_overlap_ratio` (pipelined/serial at the argmin —
< 1.0 means overlap wins) in BENCH_core.json so the trajectory is
tracked across PRs. Model-only: no devices needed.

    PYTHONPATH=src python -m benchmarks.bucket_bench [--json PATH]
"""
from __future__ import annotations

from repro.core.bucketing import pipelined_time, serial_time
from repro.planner.service import PlannerService

from .common import fmt_table

# DP-axis views of the Table-6-scale networks: leaf axis rides the
# pod/ICI fabric ("root_sw"), outer axes the DCI ("cross_dc") — the
# factorizations launch/mesh.py would produce for these chip counts.
MESHES = {
    "SYM512": [("data", 32), ("pod", 16)],     # 16 pods x 32 chips
    "SYM384": [("data", 24), ("pod", 16)],
    "SS32": [("data", 32)],                    # single-switch pod
}
FLAGSHIP = "SYM512"
# transformer-ish leaf census: a few big matrices, many small vectors;
# the sweep total IS the leaf-census total, so the per-leaf baseline and
# the bucketed candidates price the same workload
LEAF_SIZES = [1_000_000] * 12 + [250_000] * 24 + [25_000] * 60 + [4096] * 96
TOTAL_FLOATS = float(sum(LEAF_SIZES))          # ~80 MB of f32 gradients


def run() -> dict:
    svc = PlannerService()
    rows = []
    out: dict = {"ok": True}
    for mesh_name, axes in MESHES.items():
        bp = svc.get_bucket_plan(axes, TOTAL_FLOATS,
                                 leaf_sizes=LEAF_SIZES)
        # Live gate: recompute the pipeline model from the recorded
        # per-axis halves (t_rs/t_ag) instead of re-minimizing the stored
        # totals — a service that ranked by the wrong field, or whose
        # stored times drifted from the model, fails here.
        for bf, row in bp.sweep.items():
            re_p = pipelined_time(row["t_rs"], row["t_ag"],
                                  row["num_buckets"])
            re_s = serial_time(row["t_rs"], row["t_ag"],
                               row["num_buckets"])
            assert abs(re_p - row["pipelined"]) < 1e-12, (mesh_name, bf)
            assert abs(re_s - row["serial"]) < 1e-12, (mesh_name, bf)
        argmin = min(bp.sweep, key=lambda b: (pipelined_time(
            bp.sweep[b]["t_rs"], bp.sweep[b]["t_ag"],
            bp.sweep[b]["num_buckets"]), b))
        assert bp.bucket_floats == argmin, (
            f"{mesh_name}: chosen bucket {bp.bucket_floats} != GenModel "
            f"argmin {argmin}")
        assert bp.predicted_pipelined <= bp.predicted_serial + 1e-12, (
            f"{mesh_name}: pipelined model worse than serial")
        assert bp.predicted_pipelined < bp.predicted_per_leaf, (
            f"{mesh_name}: pipelined {bp.predicted_pipelined:.6f}s does "
            f"not beat per-leaf {bp.predicted_per_leaf:.6f}s")
        for bf in sorted(bp.sweep):
            row = bp.sweep[bf]
            rows.append({
                "mesh": mesh_name,
                "bucket (MiB)": f"{bf * 4 / 2**20:.2f}",
                "K": row["num_buckets"],
                "pipelined ms": f"{row['pipelined'] * 1e3:.3f}",
                "serial ms": f"{row['serial'] * 1e3:.3f}",
                "chosen": "<=" if bf == bp.bucket_floats else "",
            })
        overlap = (bp.predicted_pipelined / bp.predicted_serial
                   if bp.predicted_serial else 1.0)
        speedup_vs_leaf = bp.predicted_per_leaf / bp.predicted_pipelined
        print(f"{mesh_name}: chosen {bp.bucket_floats * 4 / 2**20:.2f} MiB "
              f"buckets (K={bp.num_buckets}), pipelined "
              f"{bp.predicted_pipelined * 1e3:.3f} ms, serial "
              f"{bp.predicted_serial * 1e3:.3f} ms, per-leaf "
              f"{bp.predicted_per_leaf * 1e3:.3f} ms "
              f"({speedup_vs_leaf:.1f}x vs per-leaf)")
        out[f"{mesh_name}_best_ms"] = round(
            bp.predicted_pipelined * 1e3, 4)
        out[f"{mesh_name}_vs_per_leaf"] = round(speedup_vs_leaf, 2)
        if mesh_name == FLAGSHIP:
            out["bucket_sweep_best_ms"] = round(
                bp.predicted_pipelined * 1e3, 4)
            out["pipeline_overlap_ratio"] = round(overlap, 4)
            out["bucket_floats"] = bp.bucket_floats

    print(fmt_table(rows, ["mesh", "bucket (MiB)", "K", "pipelined ms",
                           "serial ms", "chosen"],
                    "bucket-size sweep (GenModel-priced, double-buffered "
                    "pipeline model)"))
    return out


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    out = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
