"""Gradient bucket-size sweep on SYM512-style meshes (DESIGN.md §9/§15).

For each mesh-axis factorization the bench sweeps powers-of-two bucket
sizes through `PlannerService.get_bucket_plan` and prints the modeled
contended pipeline time next to the optimistic (naive max) pipeline,
serial (unpipelined) and per-leaf (one schedule launch per gradient
leaf — the pre-bucketing execution model) baselines. Gates:

  * the chosen bucket size IS the GenModel argmin of the sweep, ranked
    on the CONTENDED pipeline estimate (per-link occupancy merge,
    DESIGN.md §15) re-derived live from the recorded t_rs/t_ag/t_joint;
  * naive pipelined <= contended <= serial at every candidate (the
    §15 sandwich — contention can only cost, and never worse than
    back-to-back halves);
  * modeled contended time < modeled per-leaf time on every mesh
    (the Table-6-style topologies of the acceptance criteria).

`benchmarks.run --json` records `bucket_sweep_best_ms` (flagship mesh,
SYM512), the PREDICTED `pipeline_overlap_ratio` (naive pipelined/serial
— the optimistic model) and the MEASURED `pipeline_overlap_ratio_contended`
(contended/serial — what link sharing leaves of the overlap) in
BENCH_core.json so the trajectory is tracked across PRs. The
(predicted, contended) pair per mesh is fed back through
`PlannerService.observe`, so the online loop's residual rings see the
contention gap exactly as a trainer's measured timings would land.
Model-only: no devices needed.

    PYTHONPATH=src python -m benchmarks.bucket_bench [--json PATH]
"""
from __future__ import annotations

from repro.core.bucketing import (contended_pipelined_time, pipelined_time,
                                  serial_time)
from repro.planner.service import PlannerService

from .common import fmt_table

# DP-axis views of the Table-6-scale networks: leaf axis rides the
# pod/ICI fabric ("root_sw"), outer axes the DCI ("cross_dc") — the
# factorizations launch/mesh.py would produce for these chip counts.
MESHES = {
    "SYM512": [("data", 32), ("pod", 16)],     # 16 pods x 32 chips
    "SYM384": [("data", 24), ("pod", 16)],
    "SS32": [("data", 32)],                    # single-switch pod
}
FLAGSHIP = "SYM512"
# transformer-ish leaf census: a few big matrices, many small vectors;
# the sweep total IS the leaf-census total, so the per-leaf baseline and
# the bucketed candidates price the same workload
LEAF_SIZES = [1_000_000] * 12 + [250_000] * 24 + [25_000] * 60 + [4096] * 96
TOTAL_FLOATS = float(sum(LEAF_SIZES))          # ~80 MB of f32 gradients


def run() -> dict:
    svc = PlannerService()
    rows = []
    out: dict = {"ok": True}
    for mesh_name, axes in MESHES.items():
        bp = svc.get_bucket_plan(axes, TOTAL_FLOATS,
                                 leaf_sizes=LEAF_SIZES)
        # Live gate: recompute the pipeline models from the recorded
        # per-axis halves (t_rs/t_ag) and contended joint (t_joint)
        # instead of re-minimizing the stored totals — a service that
        # ranked by the wrong field, or whose stored times drifted from
        # the model, fails here.
        for bf, row in bp.sweep.items():
            k = row["num_buckets"]
            tj = row["t_joint"] if k > 1 else None
            re_p = pipelined_time(row["t_rs"], row["t_ag"], k)
            re_c = contended_pipelined_time(row["t_rs"], row["t_ag"],
                                            k, tj)
            re_s = serial_time(row["t_rs"], row["t_ag"], k)
            assert abs(re_p - row["pipelined"]) < 1e-12, (mesh_name, bf)
            assert abs(re_c - row["contended"]) < 1e-12, (mesh_name, bf)
            assert abs(re_s - row["serial"]) < 1e-12, (mesh_name, bf)
            # §15 sandwich: contention can only cost, never more than
            # giving up overlap entirely
            assert re_p <= re_c + 1e-15 and re_c <= re_s + 1e-15, \
                (mesh_name, bf, re_p, re_c, re_s)
        argmin = min(bp.sweep, key=lambda b: (contended_pipelined_time(
            bp.sweep[b]["t_rs"], bp.sweep[b]["t_ag"],
            bp.sweep[b]["num_buckets"],
            bp.sweep[b]["t_joint"]
            if bp.sweep[b]["num_buckets"] > 1 else None), b))
        assert bp.bucket_floats == argmin, (
            f"{mesh_name}: chosen bucket {bp.bucket_floats} != GenModel "
            f"argmin {argmin}")
        assert bp.predicted_pipelined <= bp.predicted_contended + 1e-15, (
            f"{mesh_name}: contended below the optimistic lower bound")
        assert bp.predicted_contended <= bp.predicted_serial + 1e-15, (
            f"{mesh_name}: contended model worse than serial")
        assert bp.predicted_contended < bp.predicted_per_leaf, (
            f"{mesh_name}: contended {bp.predicted_contended:.6f}s does "
            f"not beat per-leaf {bp.predicted_per_leaf:.6f}s")
        for bf in sorted(bp.sweep):
            row = bp.sweep[bf]
            rows.append({
                "mesh": mesh_name,
                "bucket (MiB)": f"{bf * 4 / 2**20:.2f}",
                "K": row["num_buckets"],
                "naive ms": f"{row['pipelined'] * 1e3:.3f}",
                "contended ms": f"{row['contended'] * 1e3:.3f}",
                "serial ms": f"{row['serial'] * 1e3:.3f}",
                "chosen": "<=" if bf == bp.bucket_floats else "",
            })
        predicted = (bp.predicted_pipelined / bp.predicted_serial
                     if bp.predicted_serial else 1.0)
        measured = (bp.predicted_contended / bp.predicted_serial
                    if bp.predicted_serial else 1.0)
        speedup_vs_leaf = bp.predicted_per_leaf / bp.predicted_contended
        # feed the (predicted naive, contended) pair into the online
        # loop exactly as a trainer's measured sync would land: the
        # residual ring keyed by the plan fingerprint records how far
        # the optimistic model sat from the contention-aware one
        obs = svc.observe("root_sw", axes[0][1], float(bp.bucket_floats),
                          measured=bp.predicted_contended,
                          predicted=bp.predicted_pipelined, key=bp.key)
        print(f"{mesh_name}: chosen {bp.bucket_floats * 4 / 2**20:.2f} MiB "
              f"buckets (K={bp.num_buckets}), contended "
              f"{bp.predicted_contended * 1e3:.3f} ms (naive "
              f"{bp.predicted_pipelined * 1e3:.3f} ms), serial "
              f"{bp.predicted_serial * 1e3:.3f} ms, per-leaf "
              f"{bp.predicted_per_leaf * 1e3:.3f} ms "
              f"({speedup_vs_leaf:.1f}x vs per-leaf; overlap mode "
              f"{bp.overlap.get('mode')}; observe residual "
              f"{obs['rel_residual']:.4f})")
        out[f"{mesh_name}_best_ms"] = round(
            bp.predicted_contended * 1e3, 4)
        out[f"{mesh_name}_vs_per_leaf"] = round(speedup_vs_leaf, 2)
        if mesh_name == FLAGSHIP:
            out["bucket_sweep_best_ms"] = round(
                bp.predicted_contended * 1e3, 4)
            out["pipeline_overlap_ratio"] = round(predicted, 4)
            out["pipeline_overlap_ratio_contended"] = round(measured, 4)
            out["bucket_floats"] = bp.bucket_floats
            out["overlap_mode"] = bp.overlap.get("mode", "sequential")

    print(fmt_table(rows, ["mesh", "bucket (MiB)", "K", "naive ms",
                           "contended ms", "serial ms", "chosen"],
                    "bucket-size sweep (GenModel-priced, contended "
                    "pipeline model, DESIGN.md §15)"))
    return out


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    out = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
