"""Cross-family overlap pricing on Table-6-style meshes (DESIGN.md §15).

For each topology the bench generates the GenTree AllReduce plan, splits
it into its RS/AG halves, and prices the two halves run CONCURRENTLY
round-by-round through the per-link occupancy merge — the contended
steady state of the bucket pipeline (bucket k's ReduceScatter against
bucket k−1's AllGather). Gates:

  * FastEngine's vectorized occupancy merge and the reference
    `cost_model.contended_pair_time` walk agree at 1e-9 on every mesh;
  * `overlap_gain_ratio` = contended pair / sequential pair <= 1.0
    everywhere (the planner can always fall back to back-to-back
    issuance) and STRICTLY < 1.0 on the Table-6 two-level mesh, where
    server-local and middle-switch rounds run on disjoint links;
  * the contended quote is sandwiched by `core.optimality`'s
    overlap-adjusted bounds (naive pipeline below, serial above).

`benchmarks.run --json` records `overlap_gain_ratio` (Table-6 mesh) and
`contended_vs_naive_pipeline_error` — how far the optimistic
max(t_rs, t_ag) steady state sat from the honest contended estimate on
a K-bucket pipeline — in BENCH_core.json. Model-only: no devices.

    PYTHONPATH=src python -m benchmarks.overlap_bench [--json PATH]
"""
from __future__ import annotations

from repro.core import topology
from repro.core.bucketing import contended_pipelined_time, pipelined_time
from repro.core.cost_model import contended_pair_time
from repro.core.gentree import gentree
from repro.core.optimality import overlap_certificate
from repro.core.overlap import occupancy_summary
from repro.core.plans import family_halves
from repro.core.simfast import FastEngine

from .common import fmt_table

SIZE = 1e6                     # 1 MB-class payload (Table-6 regime)
PIPE_K = 8                     # steady-state buckets for the error metric
FLAGSHIP = "TREE8"             # Table-6 two-level mesh (acceptance gate)


def _topos() -> dict:
    return {
        "SS8": topology.single_switch(8),
        # 2 middle switches x 4 servers — the Table-6 two-level mesh the
        # 8-device execution tests run on
        "TREE8": topology.symmetric_tree(2, 4),
        "CDC16": topology.cross_dc(dc0_middle=2, dc0_servers=4,
                                   dc1_middle=2, dc1_servers=4),
    }


def run() -> dict:
    rows = []
    out: dict = {"ok": True}
    worst_agree = 0.0
    for name, topo in _topos().items():
        plan = gentree(topo, SIZE).plan
        rs_half, ag_half = family_halves(plan)
        eng = FastEngine(topo)
        t_rs, t_ag = eng.halves_totals(plan)
        t_seq = t_rs + t_ag
        t_joint = eng.contended_halves_total(rs_half, ag_half)
        t_ref = contended_pair_time(topo, rs_half, ag_half)
        agree = abs(t_joint - t_ref) / max(1e-30, t_ref)
        worst_agree = max(worst_agree, agree)
        assert agree <= 1e-9, (
            f"{name}: FastEngine {t_joint} vs reference {t_ref} "
            f"diverge ({agree:.2e})")
        gain = t_joint / t_seq if t_seq else 1.0
        assert gain <= 1.0 + 1e-12, (
            f"{name}: contended pair {t_joint} prices above sequential "
            f"{t_seq} — the merge clamp is broken")
        if name == FLAGSHIP:
            assert gain < 1.0, (
                f"{name}: no overlap gain on the two-level mesh — "
                f"disjoint-link rounds should price below sequential")
        # the honest K-bucket pipeline vs the optimistic max() model
        naive = pipelined_time(t_rs, t_ag, PIPE_K)
        cont = contended_pipelined_time(t_rs, t_ag, PIPE_K, t_joint)
        err = (cont - naive) / cont if cont else 0.0
        cert = overlap_certificate(t_rs, t_ag, PIPE_K, cont)
        assert cert["sandwiched"], (name, cert)
        summ = occupancy_summary(topo, rs_half.steps[0],
                                 ag_half.steps[0]) \
            if rs_half.steps and ag_half.steps else {}
        rows.append({
            "mesh": name,
            "t_rs ms": f"{t_rs * 1e3:.3f}",
            "t_ag ms": f"{t_ag * 1e3:.3f}",
            "joint ms": f"{t_joint * 1e3:.3f}",
            "seq ms": f"{t_seq * 1e3:.3f}",
            "gain": f"{gain:.4f}",
            "naive err": f"{err:.4f}",
            "shared links": summ.get("links_shared", 0),
        })
        out[f"{name}_overlap_gain_ratio"] = round(gain, 6)
        out[f"{name}_contended_vs_naive_pipeline_error"] = round(err, 6)
        if name == FLAGSHIP:
            out["overlap_gain_ratio"] = round(gain, 6)
            out["contended_vs_naive_pipeline_error"] = round(err, 6)
    out["engine_agreement_rel"] = worst_agree

    print(fmt_table(rows, ["mesh", "t_rs ms", "t_ag ms", "joint ms",
                           "seq ms", "gain", "naive err", "shared links"],
                    "contended RS/AG overlap (per-link occupancy merge, "
                    "DESIGN.md §15)"))
    return out


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    out = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
