"""Shared helpers for the per-table/figure benchmarks."""
from __future__ import annotations

import time


def fmt_table(rows: list[dict], cols: list[str], title: str) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    out = [f"== {title} =="]
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(widths[c])
                             for c in cols))
    return "\n".join(out)


def timed(fn, *args, repeats: int = 3, **kw):
    """Median wall time of fn (benchmark-grade: warmup + repeats)."""
    fn(*args, **kw)          # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return out, ts[len(ts) // 2]
