"""Figure 3 — incast: extra overhead of x-to-x communication vs fan-in,
on the flow-level simulator with the paper's fitted parameters (this
container has no RoCE fabric; the paper's own ε/w_t from Table 5 drive the
simulation, reproducing the Fig. 3 shape: flat below w_t, linear above).
"""
from __future__ import annotations

from repro.core.cost_model import PAPER_TABLE5
from repro.core.gentree import baseline_plan
from repro.core.simulator import Simulator
from repro.core.topology import single_switch
from .common import fmt_table


def run(s: float = 2e7, xs=tuple(range(2, 16))) -> dict:
    rows = []
    base = None
    extras = {}
    for x in xs:
        topo = single_switch(x)
        sim = Simulator(topo, PAPER_TABLE5)
        # x-to-x full mesh = the CPS ReduceScatter step pattern
        res = sim.simulate(baseline_plan("cps", topo, s))
        per_step = res.per_step[0]
        if base is None:
            base = per_step
        extras[x] = res.incast_extra
        rows.append({"x": x, "step_time_s": f"{per_step:.4f}",
                     "incast_extra_s": f"{res.incast_extra:.4f}"})
    print(fmt_table(rows, ["x", "step_time_s", "incast_extra_s"],
                    "Fig. 3 — x-to-x incast overhead (simulated, paper "
                    "Table-5 params, w_t=9)"))
    w_t = PAPER_TABLE5["middle_sw"].w_t
    flat = all(extras[x] == 0 for x in xs if x <= w_t)
    growing = all(extras[x2] >= extras[x1]
                  for x1, x2 in zip(xs, xs[1:]) if x1 > w_t)
    print(f"flat below w_t={w_t}: {flat}; growing above: {growing}")
    return {"flat_below": flat, "growing_above": growing, "extras": extras}


if __name__ == "__main__":
    run()
