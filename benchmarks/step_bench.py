"""Whole-step co-planning vs naïve per-call planning (DESIGN.md §14).

A MoE training step issues every collective family at once: gradient
AllReduces per bucket, ZeRO ReduceScatter/AllGather halves, the
expert-parallel AllToAll pair per MoE layer, and a pipeline-boundary P2P
shift. `PlannerService.get_step_plan` prices that whole census jointly
under one GenModel basis — per family an argmin over per-call /
coalesced / pipelined regimes across the allowed wire precisions.

Gate: the jointly-planned step must never lose to pricing each call
independently — per-call is itself a candidate regime, so
`ratio = total_best / total_per_call <= 1` by construction, and on the
MoE-style mix below the coalesced α-amortisation must make it strictly
< 1. `benchmarks.run --json` records `step_plan_vs_per_call_ratio` (and
the joint/per-call totals) in BENCH_core.json so the trajectory is
tracked across PRs. Model-only: no devices needed.

    PYTHONPATH=src python -m benchmarks.run --only step
"""
from __future__ import annotations

from repro.planner.service import PlannerService

from .common import fmt_table

MESH = [("data", 32), ("pod", 16)]              # SYM512-style DP view

# deepseek_moe_16b-flavoured census: 24 gradient-bucket AllReduces,
# ZeRO-3 RS/AG halves per bucket, dispatch+combine AllToAll per MoE
# layer (26 layers x 2), one pipeline-boundary permute
MOE_MIX = {
    "allreduce": {"count": 24, "size_floats": 2_500_000},
    "reduce_scatter": {"count": 24, "size_floats": 2_500_000},
    "allgather": {"count": 24, "size_floats": 2_500_000},
    "all_to_all": {"count": 52, "size_floats": 131_072},
    "p2p": {"count": 1, "size_floats": 1_048_576},
}


def run() -> dict:
    svc = PlannerService()
    sp = svc.get_step_plan(MESH, MOE_MIX)

    rows = []
    for fam, q in sp.quotes.items():
        rows.append({
            "family": fam,
            "count": q["count"],
            "per-call ms": f"{q['count'] * q['per_call_total'] * 1e3:.3f}",
            "joint ms": f"{q['joint_total'] * 1e3:.3f}",
            "pipelined ms": f"{q['pipelined'] * 1e3:.3f}",
            "mode": q["mode"],
            "wire": q["precision"],
        })
    print(fmt_table(rows, ["family", "count", "per-call ms", "joint ms",
                           "pipelined ms", "mode", "wire"],
                    "whole-step family argmin (MoE-style mix, SYM512 DP "
                    "view)"))
    print(f"step totals: per-call {sp.total_per_call * 1e3:.3f} ms, "
          f"joint {sp.total_joint * 1e3:.3f} ms, best "
          f"{sp.total_best * 1e3:.3f} ms  ->  ratio {sp.ratio:.4f}")

    # consistency invariant: the stored per-family term breakdowns must
    # sum to the joint total exactly (same walk, same basis)
    terms_total = sum(sum(q["joint"].values()) for q in sp.quotes.values())
    assert abs(terms_total - sp.total_joint) <= 1e-9 * sp.total_joint, (
        terms_total, sp.total_joint)

    # the gate: joint planning beats naïve per-call planning on a
    # multi-call MoE step (<= 1 by construction; strictly < 1 here
    # because coalescing amortises α across every repeated family)
    assert sp.ratio <= 1.0 + 1e-12, sp.ratio
    assert sp.ratio < 1.0, (
        f"jointly-planned MoE step must beat per-call planning, got "
        f"ratio {sp.ratio:.6f}")

    # every family in the mix came back with a leaf-axis executable
    missing = [f for f in MOE_MIX if f not in sp.schedules]
    assert not missing, missing

    return {"ok": True,
            "step_plan_vs_per_call_ratio": round(sp.ratio, 6),
            "step_plan_per_call_ms": round(sp.total_per_call * 1e3, 4),
            "step_plan_best_ms": round(sp.total_best * 1e3, 4),
            "step_plan_precision": sp.precision}


if __name__ == "__main__":
    run()
