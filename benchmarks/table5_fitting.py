"""Table 5 / §3.4 — the fitting toolkit: recover GenModel parameters from
co-located-PS benchmark curves. Ground truth = the simulator with known
parameters; fit quality = relative error of the recovered (α, δ, ε, w_t)."""
from __future__ import annotations

import numpy as np

from repro.core import cost_model as cm
from repro.core.fitting import detect_w_t, fit_from_cps_benchmarks
from .common import fmt_table


def run() -> dict:
    true = cm.GenModelParams()
    ns, sizes, times = [], [], []
    for n in range(2, 16):
        for s in (1e7, 3.2e7, 1e8):
            ns.append(n)
            sizes.append(s)
            times.append(cm.cost_cps(n, s, true))
    fit = fit_from_cps_benchmarks(np.array(ns), np.array(sizes),
                                  np.array(times))
    rows = [{"param": p, "true": f"{getattr(true, p):.3e}",
             "fitted": f"{getattr(fit, p):.3e}"}
            for p in ("alpha", "delta", "epsilon")]
    rows.append({"param": "w_t", "true": true.w_t, "fitted": fit.w_t})
    print(fmt_table(rows, ["param", "true", "fitted"],
                    "§3.4 — parameter fitting from CPS benchmarks"))
    err = {p: abs(getattr(fit, p) - getattr(true, p))
           / max(abs(getattr(true, p)), 1e-30)
           for p in ("alpha", "delta", "epsilon")}
    ok = all(e < 0.15 for e in err.values()) and fit.w_t == true.w_t
    print(f"recovery errors: "
          + ", ".join(f"{p}={e:.1%}" for p, e in err.items())
          + f", w_t exact: {fit.w_t == true.w_t}")
    return {"errors": err, "w_t_ok": fit.w_t == true.w_t, "ok": ok}


if __name__ == "__main__":
    run()
