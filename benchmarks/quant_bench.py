"""Quantized compressed collectives, priced end-to-end (DESIGN.md §13).

Two gates:

  * **wire ratio** — the fp8 wire format (8-bit payload + one f32 scale
    per 128-lane tile) must move <= 0.27x the f32 bytes, scales
    included, at every payload size probed (exact `Precision.wire_bytes`
    accounting, partial tiles and all);
  * **priced argmin** — the (bucket x precision) sweep must PICK a
    compressed wire on a bandwidth-dominated level (big β: the β·S
    saving dwarfs the extra quant passes) and REJECT compression on a
    γ/δ-dominated level (memory-bound: the quant passes cost more than
    the wire saving) — same tolerance, same mesh, opposite verdicts.
    Compression is a *priced* decision, not a flag.

`benchmarks.run --json` records `quant_wire_ratio` (fp8, 1 MiB payload)
and `quant_sweep_best_ms` (flagship mesh, tolerance-opened sweep) in
BENCH_core.json so the trajectory is tracked across PRs. Model-only: no
devices needed.

    PYTHONPATH=src python -m benchmarks.quant_bench [--json PATH]
"""
from __future__ import annotations

from dataclasses import replace

from repro.core.cost_model import PRECISIONS, TPU_V5E
from repro.core.bucketing import BucketConfig
from repro.planner.service import PlannerService

from .common import fmt_table

MESH = [("data", 32), ("pod", 16)]              # SYM512-style DP view
LEAF_SIZES = [1_000_000] * 12 + [250_000] * 24 + [25_000] * 60
TOTAL_FLOATS = float(sum(LEAF_SIZES))
TOLERANCE = 0.3                                 # opens every lossy wire

WIRE_GATE = 0.27                                # fp8 incl. scales vs f32


def _bandwidth_dominated() -> dict:
    """TPU_V5E with β inflated 50x: transport-bound — compression wins."""
    return {lvl: replace(p, beta=p.beta * 50.0)
            for lvl, p in TPU_V5E.items()}


def _compute_dominated() -> dict:
    """β/ε nearly free, γ/δ inflated 100x: every quant pass is priced at
    full memory cost while the wire saving is worthless."""
    return {lvl: replace(p, beta=p.beta * 1e-4, epsilon=p.epsilon * 1e-4,
                         gamma=p.gamma * 100.0, delta=p.delta * 100.0)
            for lvl, p in TPU_V5E.items()}


def run() -> dict:
    out: dict = {"ok": True}

    # ---- gate (a): exact wire-byte accounting ------------------------------
    fp8 = PRECISIONS["fp8"]
    rows = []
    worst = 0.0
    for n in (1, 100, 128, 129, 4096, 250_000, 1 << 20):
        ratio = fp8.wire_bytes(n) / (4 * n)
        # the gate applies from one scale tile up — a lone element is
        # all scale overhead (5 B vs 4 B) and no planner would compress
        # it; the row stays in the table to document the floor
        if n >= fp8.scale_block:
            worst = max(worst, ratio)
        rows.append({"elements": n,
                     "fp8 bytes": fp8.wire_bytes(n),
                     "f32 bytes": 4 * n,
                     "ratio": f"{ratio:.4f}",
                     "gated": "yes" if n >= fp8.scale_block else ""})
    print(fmt_table(rows, ["elements", "fp8 bytes", "f32 bytes", "ratio",
                           "gated"],
                    "fp8 wire bytes (payload + per-tile f32 scales)"))
    assert worst <= WIRE_GATE, (
        f"fp8 wire ratio {worst:.4f} exceeds the {WIRE_GATE} gate")
    out["quant_wire_ratio"] = round(fp8.wire_bytes(1 << 20) / (4 << 20), 4)

    # ---- gate (b): compression is a priced verdict -------------------------
    sweep_rows = []
    verdicts = {}
    for regime, params in (("bandwidth", _bandwidth_dominated()),
                           ("compute", _compute_dominated())):
        svc = PlannerService(params=params)
        lossy = svc.get_bucket_plan(
            MESH, TOTAL_FLOATS, leaf_sizes=LEAF_SIZES,
            config=BucketConfig(tolerance=TOLERANCE))
        full = svc.get_bucket_plan(MESH, TOTAL_FLOATS,
                                   leaf_sizes=LEAF_SIZES)
        verdicts[regime] = lossy.precision
        # opening the tolerance can never price WORSE: f32 stays in the
        # candidate set, so the argmin only improves
        assert lossy.predicted_pipelined <= full.predicted_pipelined \
            + 1e-12, regime
        sweep_rows.append({
            "regime": regime,
            "precision": lossy.precision,
            "sweep ms": f"{lossy.predicted_pipelined * 1e3:.3f}",
            "f32 ms": f"{full.predicted_pipelined * 1e3:.3f}",
            "saving": f"{(1 - lossy.predicted_pipelined / full.predicted_pipelined) * 100:.1f}%",
        })
        print(f"{regime}-dominated: sweep chose {lossy.precision} "
              f"({lossy.predicted_pipelined * 1e3:.3f} ms vs f32 "
              f"{full.predicted_pipelined * 1e3:.3f} ms)")
        if regime == "bandwidth":
            out["quant_sweep_best_ms"] = round(
                lossy.predicted_pipelined * 1e3, 4)
            out["quant_sweep_precision"] = lossy.precision
    print(fmt_table(sweep_rows,
                    ["regime", "precision", "sweep ms", "f32 ms", "saving"],
                    "priced (bucket x precision) argmin, tolerance=0.3"))
    assert verdicts["bandwidth"] != "f32", (
        "bandwidth-dominated level must pick a compressed wire")
    assert verdicts["compute"] == "f32", (
        "γ/δ-dominated level must reject compression")
    return out


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    out = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
