"""Executed-plan step time vs `lax.psum` (DESIGN.md §8).

For the first time the repo can measure what it *runs*, not only what it
*prices*: lowered GenTree plans and lowered flat builders execute under
shard_map on an 8-device host-CPU mesh next to XLA's native psum, and the
per-step wall-clock lands in BENCH_core.json so the executed-plan
trajectory is tracked across PRs.

Numbers here are host-CPU ppermute emulation — psum is expected to win on
this substrate (XLA fuses the whole reduction); the benchmark's gates are
correctness (every executed schedule matches psum) and the recorded
trend, not a speed win. Run standalone with

    PYTHONPATH=src python -m benchmarks.exec_bench [--json PATH]

or as part of `benchmarks.run --only exec`. The measurement runs in a
subprocess so the 8-device XLA flag does not leak into sibling benchmarks.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import fmt_table

_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.core import plans, topology
from repro.core.gentree import gentree
from repro.core.lower import lower_plan

N, SIZE = 8, 1 << 16
mesh = jax.make_mesh((N,), ("x",))
x = jax.random.normal(jax.random.PRNGKey(0), (N, SIZE), jnp.float32)


def bench(fn):
    f = jax.jit(shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                          in_specs=P("x"), out_specs=P("x")))
    out = f(x)
    jax.block_until_ready(out)          # compile + warm
    reps, times = 5, []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        times.append(time.perf_counter() - t0)
    return np.asarray(f(x))[0], sorted(times)[reps // 2]


want, psum_s = bench(lambda v: jax.lax.psum(v, "x"))
rows = {"psum": {"ms": psum_s * 1e3, "vs_psum": 1.0, "ok": True}}

CASES = {
    "exec_gentree_ss8": gentree(topology.single_switch(N), float(SIZE)).plan,
    "exec_gentree_sym2x4": gentree(topology.symmetric_tree(2, 4),
                                   float(SIZE)).plan,
    "exec_ring": plans.ring(N, float(SIZE)),
    "exec_cps": plans.cps(N, float(SIZE)),
}
for name, plan in CASES.items():
    cs = lower_plan(plan)
    got, dt = bench(lambda v, cs=cs: cs.allreduce(v, "x"))
    ok = bool(np.allclose(got, want, rtol=1e-5, atol=1e-5))
    rows[name] = {"ms": dt * 1e3, "vs_psum": dt / psum_s, "ok": ok,
                  "rounds": cs.total_rounds()}
print("RESULTS " + json.dumps(rows))
"""


def run() -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"exec bench driver failed: {out.stderr[-2000:]}")
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULTS ")][-1]
    rows = json.loads(line[len("RESULTS "):])

    table = [{"schedule": k, "step ms": f"{v['ms']:.2f}",
              "vs psum": f"{v['vs_psum']:.1f}x",
              "rounds": v.get("rounds", "-"),
              "correct": "yes" if v["ok"] else "NO"}
             for k, v in rows.items()]
    print(fmt_table(table, ["schedule", "step ms", "vs psum", "rounds",
                            "correct"],
                    "executed plan step time vs lax.psum (8 host devices)"))

    all_ok = all(v["ok"] for v in rows.values())
    if not all_ok:
        raise AssertionError(f"executed schedule diverged from psum: {rows}")
    # scalar metrics ride into BENCH_core.json via benchmarks.run
    flat = {"ok": all_ok, "psum_ms": round(rows["psum"]["ms"], 3)}
    for k, v in rows.items():
        if k != "psum":
            flat[f"{k}_ms"] = round(v["ms"], 3)
            flat[f"{k}_vs_psum"] = round(v["vs_psum"], 2)
    return flat


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    out = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
