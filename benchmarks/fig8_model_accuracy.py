"""Figure 8 + 10 — GenModel accuracy & time-cost breakdown.

Methodology mirrors the paper's §3.4/§5.1 exactly: GenModel is FIT to
co-located-PS benchmark curves (N = 2..15) on the target system, then used
to *predict* the cost of plans it never saw (Ring, hierarchical CPS) —
prediction error vs ground truth is the score. Ground truth here is the
flow-level simulator (parameterized by the paper's Table-5 fits, with
link-level incast and PFC-style sender counting), standing in for the
RoCE testbed this container does not have. The (α,β,γ) comparison point
is the same fit with δ = ε = 0 — the best the legacy model could do.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cost_model as cm
from repro.core.cost_model import PAPER_TABLE5, GenModelParams
from repro.core.fitting import fit_from_cps_benchmarks
from repro.core.gentree import baseline_plan
from repro.core.simulator import Simulator
from repro.core.topology import single_switch
from .common import fmt_table


def _actual(kind, fac, n, s) -> float:
    topo = single_switch(n)
    sim = Simulator(topo, PAPER_TABLE5)
    plan = baseline_plan(
        kind if fac is None else f"hcps:{'x'.join(map(str, fac))}", topo, s)
    return sim.simulate(plan).total


def _closed(kind, fac, n, s, p):
    if kind == "hcps":
        return cm.cost_hcps(fac, s, p)
    return cm.CLOSED_FORMS[kind](n, s, p)


def fit_genmodel(sizes=(1e7, 3.2e7, 1e8), n_max: int = 15) -> GenModelParams:
    """§3.4: run the CPS benchmark at N=2..n_max and fit."""
    ns, ss, ts = [], [], []
    for n in range(2, n_max + 1):
        for s in sizes:
            ns.append(n)
            ss.append(s)
            ts.append(_actual("cps", None, n, s))
    return fit_from_cps_benchmarks(np.array(ns, float), np.array(ss, float),
                                   np.array(ts))


def run(s: float = 1e8) -> dict:
    fitted = fit_genmodel()
    legacy = fitted.legacy()
    print(f"fitted on CPS curves: α={fitted.alpha:.2e} "
          f"2β+γ={2 * fitted.beta + fitted.gamma:.2e} "
          f"δ={fitted.delta:.2e} ε={fitted.epsilon:.2e} w_t={fitted.w_t}")

    cands = {
        12: [("ring", None), ("cps", None), ("hcps", [6, 2]),
             ("hcps", [4, 3]), ("hcps", [2, 6]), ("hcps", [3, 2, 2])],
        15: [("ring", None), ("cps", None), ("hcps", [5, 3]),
             ("hcps", [3, 5])],
    }
    rows, errs_gen, errs_leg, picks = [], [], [], {}
    for n, lst in cands.items():
        actual = {kf: _actual(kf[0], kf[1], n, s) for kf in
                  [(k, tuple(f) if f else None) for k, f in lst]}
        for kind, fac in lst:
            a = actual[(kind, tuple(fac) if fac else None)]
            g = _closed(kind, fac, n, s, fitted)
            l = _closed(kind, fac, n, s, legacy)
            errs_gen.append(abs(g - a) / a)
            errs_leg.append(abs(l - a) / a)
            rows.append({"N": n, "plan": kind + (str(fac) if fac else ""),
                         "actual_s": f"{a:.3f}",
                         "genmodel_s": f"{g:.3f}",
                         "legacy_s": f"{l:.3f}",
                         "gen_err": f"{abs(g - a) / a:.1%}",
                         "legacy_err": f"{abs(l - a) / a:.1%}"})
        def _label(kind, fac):
            return kind + ("x".join(map(str, fac)) if fac else "")

        key = min(actual, key=actual.get)
        best_gen = min(lst, key=lambda kf: _closed(*kf, n, s, fitted))
        best_leg = min(lst, key=lambda kf: _closed(*kf, n, s, legacy))
        picks[n] = {"actual": _label(*key),
                    "genmodel": _label(*best_gen),
                    "legacy": _label(*best_leg)}
    print(fmt_table(rows, ["N", "plan", "actual_s", "genmodel_s",
                           "legacy_s", "gen_err", "legacy_err"],
                    "Fig. 8 — fit-then-predict accuracy vs flow-level "
                    "ground truth"))
    print(f"max GenModel error: {max(errs_gen):.1%} (paper: ≤2.6 %)   "
          f"max (α,β,γ) error: {max(errs_leg):.1%} (paper: ≤19.8 %)")
    agree = all(p["actual"] == p["genmodel"] for p in picks.values())
    for n, p in picks.items():
        print(f"N={n}: truth prefers {p['actual']}; GenModel picks "
              f"{p['genmodel']}; legacy picks {p['legacy']}")
    print(f"GenModel picks the true winner everywhere: {agree}")

    # Fig. 10 — per-term breakdown at N=12 with the fitted parameters
    brows = []
    zero = GenModelParams(alpha=0, beta=0, gamma=0, delta=0, epsilon=0,
                          w_t=fitted.w_t)
    for kind, fac in cands[12]:
        terms = {}
        for t in ("alpha", "beta", "gamma", "delta", "epsilon"):
            p = dataclasses.replace(zero, **{t: getattr(fitted, t)})
            terms[t] = _closed(kind, fac, 12, s, p)
        brows.append({"plan": kind + (str(fac) if fac else ""),
                      **{t: f"{v:.3f}" for t, v in terms.items()}})
    print(fmt_table(brows, ["plan", "alpha", "beta", "gamma", "delta",
                            "epsilon"],
                    "Fig. 10 — GenModel time-cost breakdown, N=12 "
                    "(fitted params)"))
    return {"max_gen_err": max(errs_gen), "max_legacy_err": max(errs_leg),
            "picks": picks, "picks_agree": agree}


if __name__ == "__main__":
    run()
