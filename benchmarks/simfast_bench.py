"""Compiled plan-evaluation engine — cold GenTree speedup gate.

Acceptance gate (ISSUE 2 / DESIGN.md §7): cold `gentree()` on SYM512
(16 middle switches × 32 servers) with the compiled engine must be >= 10x
faster than the pre-PR reference path (per-candidate IR construction +
pure-Python incast-aware simulation), and both paths must agree on every
per-switch decision and cost within 1e-9.

The reference leg is timed once (it is the slow path being replaced — tens
of seconds); the fast leg is the median of several runs. Cold-generation
wall-clock for both legs is returned so `benchmarks.run --json` records
the trajectory across PRs.
"""
from __future__ import annotations

import time

from repro.core.gentree import gentree
from repro.core.topology import symmetric_tree

from .common import fmt_table

REQUIRED_SPEEDUP = 10.0
SIZE = 1e8


def run() -> dict:
    t0 = time.perf_counter()
    ref = gentree(symmetric_tree(16, 32), SIZE, engine="reference")
    ref_s = time.perf_counter() - t0

    fast_times = []
    fast = None
    for _ in range(3):
        t0 = time.perf_counter()
        fast = gentree(symmetric_tree(16, 32), SIZE, engine="fast")
        fast_times.append(time.perf_counter() - t0)
    fast_s = sorted(fast_times)[len(fast_times) // 2]
    speedup = ref_s / fast_s

    # decision + cost equivalence: the fast path must not silently change
    # plan selection (the bit-for-bit ranking invariant, DESIGN.md §7)
    worst = abs(ref.predicted_time - fast.predicted_time)
    for sw, dr in ref.decisions.items():
        df = fast.decisions[sw]
        assert (dr.algo, dr.factors, dr.rearrange) == \
            (df.algo, df.factors, df.rearrange), (sw, dr, df)
        worst = max(worst, abs(dr.cost - df.cost))
    assert worst < 1e-9, f"fast/reference cost divergence {worst:.3e}"

    rows = [
        {"path": "reference (pre-PR pure-Python search)",
         "seconds": f"{ref_s:.3f}"},
        {"path": "fast (compiled batched search)",
         "seconds": f"{fast_s:.3f}"},
    ]
    print(fmt_table(rows, ["path", "seconds"],
                    "simfast: cold gentree() on SYM512 (512 servers)"))
    print(f"speedup: {speedup:.1f}x (required >= {REQUIRED_SPEEDUP:.0f}x); "
          f"max decision-cost divergence {worst:.2e}")

    assert speedup >= REQUIRED_SPEEDUP, (
        f"cold gentree only {speedup:.1f}x faster than the reference path "
        f"(need >= {REQUIRED_SPEEDUP:.0f}x)")
    return {"ok": True, "speedups": f"{speedup:.1f}x",
            "cold_fast_s": fast_s, "cold_ref_s": ref_s,
            "max_divergence": worst}


if __name__ == "__main__":
    run()
