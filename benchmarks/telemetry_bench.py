"""Telemetry hot-path overhead + online-refit convergence (DESIGN.md §10).

Two gates keep the closed loop honest:

  * **observe() overhead < 1% of a simulated step** — feeding a measured
    collective into the loop (ring add + residual update + CPS-equivalent
    sample + drift check) must be noise next to the step it instruments.
    The "simulated step" is the repo's own smoke training step
    (`launch.train.run_training`, manual engine, sync="plan"): the bench
    reads the median per-step wall time straight from the `train/step`
    telemetry ring the trainer feeds — the same datapath the watchdog
    reads — so the gate prices observe() against exactly the step it
    would instrument in production.
  * **refit convergence within 10%** — the synthetic drift scenario (the
    acceptance criterion of PR 5): a service mis-seeded 3× low on α and
    6× low on β observes ground-truth measurements, refits from
    telemetry, and afterwards every observed (n, S) point must price
    within 10% of measured.
  * **tracer overhead < 2% of a smoke train step** — the same smoke run
    executed twice, once with the span tracer disabled and once enabled
    (fresh telemetry hub each, so the two medians are clean); the traced
    run's median per-step wall time may exceed the untraced one by at
    most 2%. The traced run's Chrome trace and metrics snapshot are
    exported as `BENCH_trace.json` / `BENCH_metrics.json` so CI uploads
    a loadable trace artifact alongside the numbers.

`benchmarks.run --json` records `telemetry_overhead_pct`,
`trace_overhead_pct` and `refit_residual_ratio` in BENCH_core.json so the
trajectory is tracked across PRs. Runs headless on CPU (the smoke train
step jits on the local device; no multi-device mesh needed).

    PYTHONPATH=src python -m benchmarks.telemetry_bench [--json PATH]
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.cost_model import PAPER_TABLE5
from repro.core.simulator import Simulator
from repro.core.sync import level_switch_topo
from repro.planner.service import PlannerService, RefitPolicy

from .common import fmt_table

OBSERVE_CALLS = 2000
SIM_STEPS = 50
SIZES = [(8, 1e6), (8, 4e6), (4, 1e6), (8, 1.6e7), (4, 4e6),
         (8, 2e6), (8, 8e6), (4, 2e6)]


def _mis_seeded_service(policy: RefitPolicy) -> PlannerService:
    true = PAPER_TABLE5
    wrong = dict(true)
    wrong["root_sw"] = dataclasses.replace(
        true["root_sw"], alpha=true["root_sw"].alpha / 3,
        beta=true["root_sw"].beta / 6)
    return PlannerService(params=wrong, refit_policy=policy)


def _measure(svc, n, size):
    """Ground truth: the chosen plan simulated under the TRUE params."""
    resp = svc.get_axis_executable("data", n, size, level="root_sw")
    topo = level_switch_topo(n, PAPER_TABLE5, "root_sw")
    meas = Simulator(topo, PAPER_TABLE5,
                     unit_bytes=4).simulate(resp.plan).total
    return resp, meas


def run() -> dict:
    out: dict = {"ok": True}

    # ---- gate 1: observe() hot-path overhead ------------------------------
    svc = _mis_seeded_service(RefitPolicy(enabled=False))
    resp, meas = _measure(svc, 8, 4e6)

    # the simulated step the overhead is charged against: the repo's own
    # smoke training step, whose per-step wall times land in the
    # train/step telemetry ring (the watchdog datapath) as run_training
    # executes
    from repro.launch.train import TrainConfig, run_training
    from repro.runtime.telemetry import default_telemetry
    run_training(TrainConfig(arch="stablelm-12b", steps=SIM_STEPS,
                             seq_len=32, global_batch=4, engine="manual",
                             sync="plan", log_every=10 ** 6),
                 smoke=True, on_log=lambda *a, **k: None)
    ring = default_telemetry().ring("train/step")
    assert ring.count >= SIM_STEPS, "trainer did not feed the step ring"
    step_s = ring.percentile(50.0)               # median: jit-proof

    # BOTH observe branches, warmed first: explicit predicted (the e2e
    # closed-loop scenario) AND default pricing (what the production
    # wiring — train's sync probe, serve's decode observe — actually
    # calls; its exact-size halves pricing is memoized per params
    # version, so the steady state is what the gate bounds)
    svc.observe("root_sw", 8, 4e6, meas, predicted=resp.predicted_time,
                key=resp.key)                    # warm create-on-demand
    t0 = time.perf_counter()
    for _ in range(OBSERVE_CALLS):
        svc.observe("root_sw", 8, 4e6, meas,
                    predicted=resp.predicted_time, key=resp.key)
    observe_s = (time.perf_counter() - t0) / OBSERVE_CALLS

    svc.observe("root_sw", 8, 4e6, meas, key=resp.key)   # warm pricing
    t0 = time.perf_counter()
    for _ in range(OBSERVE_CALLS):
        svc.observe("root_sw", 8, 4e6, meas, key=resp.key)
    observe_def_s = (time.perf_counter() - t0) / OBSERVE_CALLS

    overhead_pct = 100.0 * max(observe_s, observe_def_s) / step_s
    rows = [{"metric": "simulated train step (median)",
             "value": f"{step_s * 1e6:.1f} us"},
            {"metric": "observe() call (explicit predicted)",
             "value": f"{observe_s * 1e6:.1f} us"},
            {"metric": "observe() call (default pricing)",
             "value": f"{observe_def_s * 1e6:.1f} us"},
            {"metric": "overhead (worst branch)",
             "value": f"{overhead_pct:.3f} %"}]
    assert overhead_pct < 1.0, (
        f"observe() overhead {overhead_pct:.2f}% of a simulated step "
        f"(gate: < 1%)")

    # ---- gate 2: refit convergence on the synthetic drift scenario --------
    svc = _mis_seeded_service(RefitPolicy(min_samples=6,
                                          drift_threshold=0.15, cooldown=6))
    refits = 0
    for n, size in SIZES * 3:
        resp, meas = _measure(svc, n, size)
        obs = svc.observe("root_sw", n, size, meas,
                          predicted=resp.predicted_time, key=resp.key)
        refits += int(obs["refit"])
    assert refits >= 1, "synthetic drift scenario never triggered a refit"

    worst = 0.0
    for n, size in SIZES:
        resp, meas = _measure(svc, n, size)
        worst = max(worst, abs(resp.predicted_time - meas) / meas)
    rows.append({"metric": "refits fired", "value": str(refits)})
    rows.append({"metric": "worst post-refit residual",
                 "value": f"{worst * 100:.2f} %"})
    assert worst < 0.10, (
        f"post-refit predicted cost diverges {worst * 100:.1f}% from "
        f"measured (gate: < 10%)")

    # ---- gate 3: span-tracer overhead on the smoke train step -------------
    # Same smoke config twice — untraced then traced — each against a
    # FRESH telemetry hub so the two train/step medians don't mix with
    # each other or with earlier benches in the same process. The traced
    # run's spans + metrics are exported for the CI artifact upload.
    from repro.runtime.telemetry import (Telemetry, peek_default_telemetry,
                                         set_default_telemetry)
    from repro.runtime.trace import Tracer, set_default_tracer
    from repro.runtime.metrics import default_metrics

    tcfg = TrainConfig(arch="stablelm-12b", steps=SIM_STEPS,
                       seq_len=32, global_batch=4, engine="manual",
                       sync="plan", log_every=10 ** 6)
    old_tele = peek_default_telemetry()
    old_tracer = set_default_tracer(Tracer(enabled=False))
    try:
        set_default_telemetry(Telemetry())
        run_training(tcfg, smoke=True, on_log=lambda *a, **k: None)
        untraced_s = default_telemetry().ring("train/step").percentile(50.0)

        traced_tracer = Tracer(enabled=True)
        set_default_tracer(traced_tracer)
        set_default_telemetry(Telemetry())
        run_training(tcfg, smoke=True, on_log=lambda *a, **k: None)
        traced_s = default_telemetry().ring("train/step").percentile(50.0)

        traced_tracer.export_chrome("BENCH_trace.json")
        default_metrics().export("BENCH_metrics.json")
    finally:
        set_default_tracer(old_tracer)
        set_default_telemetry(old_tele)

    trace_overhead_pct = max(
        0.0, 100.0 * (traced_s - untraced_s) / untraced_s)
    rows.append({"metric": "smoke step untraced (median)",
                 "value": f"{untraced_s * 1e6:.1f} us"})
    rows.append({"metric": "smoke step traced (median)",
                 "value": f"{traced_s * 1e6:.1f} us"})
    rows.append({"metric": "tracer overhead",
                 "value": f"{trace_overhead_pct:.3f} %"})
    rows.append({"metric": "spans recorded (traced run)",
                 "value": str(len(traced_tracer.spans))})
    assert traced_tracer.spans, "traced smoke run recorded no spans"
    assert trace_overhead_pct < 2.0, (
        f"span tracer costs {trace_overhead_pct:.2f}% of a smoke train "
        f"step (gate: < 2%)")

    print(fmt_table(rows, ["metric", "value"],
                    "telemetry hot path + online refit convergence"))
    out["telemetry_overhead_pct"] = round(overhead_pct, 4)
    out["trace_overhead_pct"] = round(trace_overhead_pct, 4)
    out["refit_residual_ratio"] = round(worst, 4)
    out["refits"] = refits
    out["trace_spans"] = len(traced_tracer.spans)
    return out


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
