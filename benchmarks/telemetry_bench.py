"""Telemetry hot-path overhead + online-refit convergence (DESIGN.md §10).

Two gates keep the closed loop honest:

  * **observe() overhead < 1% of a simulated step** — feeding a measured
    collective into the loop (ring add + residual update + CPS-equivalent
    sample + drift check) must be noise next to the step it instruments.
    The "simulated step" is the repo's own smoke training step
    (`launch.train.run_training`, manual engine, sync="plan"): the bench
    reads the median per-step wall time straight from the `train/step`
    telemetry ring the trainer feeds — the same datapath the watchdog
    reads — so the gate prices observe() against exactly the step it
    would instrument in production.
  * **refit convergence within 10%** — the synthetic drift scenario (the
    acceptance criterion of PR 5): a service mis-seeded 3× low on α and
    6× low on β observes ground-truth measurements, refits from
    telemetry, and afterwards every observed (n, S) point must price
    within 10% of measured.

`benchmarks.run --json` records `telemetry_overhead_pct` and
`refit_residual_ratio` in BENCH_core.json so the trajectory is tracked
across PRs. Runs headless on CPU (the smoke train step jits on the local
device; no multi-device mesh needed).

    PYTHONPATH=src python -m benchmarks.telemetry_bench [--json PATH]
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.cost_model import PAPER_TABLE5
from repro.core.simulator import Simulator
from repro.core.sync import level_switch_topo
from repro.planner.service import PlannerService, RefitPolicy

from .common import fmt_table

OBSERVE_CALLS = 2000
SIM_STEPS = 50
SIZES = [(8, 1e6), (8, 4e6), (4, 1e6), (8, 1.6e7), (4, 4e6),
         (8, 2e6), (8, 8e6), (4, 2e6)]


def _mis_seeded_service(policy: RefitPolicy) -> PlannerService:
    true = PAPER_TABLE5
    wrong = dict(true)
    wrong["root_sw"] = dataclasses.replace(
        true["root_sw"], alpha=true["root_sw"].alpha / 3,
        beta=true["root_sw"].beta / 6)
    return PlannerService(params=wrong, refit_policy=policy)


def _measure(svc, n, size):
    """Ground truth: the chosen plan simulated under the TRUE params."""
    resp = svc.get_axis_executable("data", n, size, level="root_sw")
    topo = level_switch_topo(n, PAPER_TABLE5, "root_sw")
    meas = Simulator(topo, PAPER_TABLE5,
                     unit_bytes=4).simulate(resp.plan).total
    return resp, meas


def run() -> dict:
    out: dict = {"ok": True}

    # ---- gate 1: observe() hot-path overhead ------------------------------
    svc = _mis_seeded_service(RefitPolicy(enabled=False))
    resp, meas = _measure(svc, 8, 4e6)

    # the simulated step the overhead is charged against: the repo's own
    # smoke training step, whose per-step wall times land in the
    # train/step telemetry ring (the watchdog datapath) as run_training
    # executes
    from repro.launch.train import TrainConfig, run_training
    from repro.runtime.telemetry import default_telemetry
    run_training(TrainConfig(arch="stablelm-12b", steps=SIM_STEPS,
                             seq_len=32, global_batch=4, engine="manual",
                             sync="plan", log_every=10 ** 6),
                 smoke=True, on_log=lambda *a, **k: None)
    ring = default_telemetry().ring("train/step")
    assert ring.count >= SIM_STEPS, "trainer did not feed the step ring"
    step_s = ring.percentile(50.0)               # median: jit-proof

    # BOTH observe branches, warmed first: explicit predicted (the e2e
    # closed-loop scenario) AND default pricing (what the production
    # wiring — train's sync probe, serve's decode observe — actually
    # calls; its exact-size halves pricing is memoized per params
    # version, so the steady state is what the gate bounds)
    svc.observe("root_sw", 8, 4e6, meas, predicted=resp.predicted_time,
                key=resp.key)                    # warm create-on-demand
    t0 = time.perf_counter()
    for _ in range(OBSERVE_CALLS):
        svc.observe("root_sw", 8, 4e6, meas,
                    predicted=resp.predicted_time, key=resp.key)
    observe_s = (time.perf_counter() - t0) / OBSERVE_CALLS

    svc.observe("root_sw", 8, 4e6, meas, key=resp.key)   # warm pricing
    t0 = time.perf_counter()
    for _ in range(OBSERVE_CALLS):
        svc.observe("root_sw", 8, 4e6, meas, key=resp.key)
    observe_def_s = (time.perf_counter() - t0) / OBSERVE_CALLS

    overhead_pct = 100.0 * max(observe_s, observe_def_s) / step_s
    rows = [{"metric": "simulated train step (median)",
             "value": f"{step_s * 1e6:.1f} us"},
            {"metric": "observe() call (explicit predicted)",
             "value": f"{observe_s * 1e6:.1f} us"},
            {"metric": "observe() call (default pricing)",
             "value": f"{observe_def_s * 1e6:.1f} us"},
            {"metric": "overhead (worst branch)",
             "value": f"{overhead_pct:.3f} %"}]
    assert overhead_pct < 1.0, (
        f"observe() overhead {overhead_pct:.2f}% of a simulated step "
        f"(gate: < 1%)")

    # ---- gate 2: refit convergence on the synthetic drift scenario --------
    svc = _mis_seeded_service(RefitPolicy(min_samples=6,
                                          drift_threshold=0.15, cooldown=6))
    refits = 0
    for n, size in SIZES * 3:
        resp, meas = _measure(svc, n, size)
        obs = svc.observe("root_sw", n, size, meas,
                          predicted=resp.predicted_time, key=resp.key)
        refits += int(obs["refit"])
    assert refits >= 1, "synthetic drift scenario never triggered a refit"

    worst = 0.0
    for n, size in SIZES:
        resp, meas = _measure(svc, n, size)
        worst = max(worst, abs(resp.predicted_time - meas) / meas)
    rows.append({"metric": "refits fired", "value": str(refits)})
    rows.append({"metric": "worst post-refit residual",
                 "value": f"{worst * 100:.2f} %"})
    assert worst < 0.10, (
        f"post-refit predicted cost diverges {worst * 100:.1f}% from "
        f"measured (gate: < 10%)")

    print(fmt_table(rows, ["metric", "value"],
                    "telemetry hot path + online refit convergence"))
    out["telemetry_overhead_pct"] = round(overhead_pct, 4)
    out["refit_residual_ratio"] = round(worst, 4)
    out["refits"] = refits
    return out


def main() -> None:
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    res = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
