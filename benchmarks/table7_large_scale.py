"""Table 7 — large-scale simulation: GenTree (and GenTree* without data
rearrangement on CDC384) vs Ring / Co-located PS / RHD (power-of-two only)
on six topologies × three data sizes."""
from __future__ import annotations

import math
import time

from repro.core.cost_model import PAPER_TABLE5
from repro.core.gentree import baseline_plan, gentree
from repro.core.simulator import Simulator
from repro.core.topology import TopoNode
from .common import fmt_table
from .table6_plan_selection import TOPOS


def run(sizes=(1e7, 3.2e7, 1e8),
        topos=("SS24", "SS32", "SYM384", "SYM512", "ASY384", "CDC384")
        ) -> dict:
    rows = []
    speedups = {}
    cold_gen_s = 0.0   # cold GenTree + GenTree-seq generation wall-clock
    for tname in topos:
        builder = TOPOS[tname]
        n = builder().num_servers()
        pow2 = (n & (n - 1)) == 0
        times: dict[str, dict[float, float]] = {}
        for s in sizes:
            topo = builder()
            sim = Simulator(topo, PAPER_TABLE5)
            t0 = time.perf_counter()
            times.setdefault("GenTree", {})[s] = gentree(
                topo, s).predicted_time
            # GenTree-seq = the paper's stream-emulator scheduling
            # (sequential sibling sub-plans); our default overlaps them.
            times.setdefault("GenTree-seq", {})[s] = gentree(
                builder(), s, concurrent=False).predicted_time
            cold_gen_s += time.perf_counter() - t0
            if tname == "CDC384":
                times.setdefault("GenTree*", {})[s] = gentree(
                    builder(), s, enable_rearrangement=False).predicted_time
            for kind, label in (("ring", "Ring"), ("cps", "C-PS")):
                times.setdefault(label, {})[s] = sim.simulate(
                    baseline_plan(kind, topo, s)).total
            if pow2:
                times.setdefault("RHD", {})[s] = sim.simulate(
                    baseline_plan("rhd", topo, s)).total
        for algo, by_size in times.items():
            rows.append({"topo": tname, "algorithm": algo,
                         **{f"{s:.0e}": f"{by_size[s]:.3f}"
                            for s in sizes}})
        base = [a for a in times if not a.startswith("GenTree")]
        sp = max(max(times[a][s] for a in base)
                 / times["GenTree"][s] for s in sizes)
        sp_seq = max(max(times[a][s] for a in base)
                     / times["GenTree-seq"][s] for s in sizes)
        speedups[tname] = {"concurrent": sp, "sequential": sp_seq}
    print(fmt_table(rows, ["topo", "algorithm"]
                    + [f"{s:.0e}" for s in sizes],
                    "Table 7 — large-scale simulation (seconds)"))
    for tname, sp in speedups.items():
        print(f"{tname}: max speedup {sp['concurrent']:.1f}× "
              f"(paper-style sequential scheduling: "
              f"{sp['sequential']:.1f}×)")
    print("(paper: 1.2×–7.4×; the beyond-paper concurrent sub-plan "
          "scheduling widens it)")
    print(f"cold GenTree+GenTree-seq generation wall-clock "
          f"(all topologies/sizes): {cold_gen_s:.2f}s")
    return {"speedups": speedups, "cold_gen_s": cold_gen_s}


if __name__ == "__main__":
    run()
