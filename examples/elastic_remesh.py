"""Elastic scaling demo: train on an 8-device mesh, checkpoint, lose half
the fleet, restore the SAME checkpoint onto a 4-device mesh, and keep
training with identical semantics (the data pipeline is pure in the step
index, so the loss sequence continues exactly).

Run:  PYTHONPATH=src python examples/elastic_remesh.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import tempfile

import jax

from repro.launch.train import TrainConfig, run_training


def main():
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    devs = jax.devices()
    print(f"{len(devs)} devices available")

    mesh8 = jax.make_mesh((8, 1), ("data", "model"))
    tc = dict(arch="rwkv6-1.6b", seq_len=64, global_batch=8, lr=1e-3,
              ckpt_dir=ckpt, ckpt_every=10, log_every=5)
    print("— phase 1: 8-device mesh, steps 0–19 —")
    out1 = run_training(TrainConfig(**tc, steps=20), mesh=mesh8)

    # "lose a pod": continue on half the devices. The checkpoint is
    # device-agnostic (numpy), so restore just re-shards onto the new
    # mesh (runtime.elastic_remesh under the hood of the restore path).
    mesh4 = jax.make_mesh((4, 1), ("data", "model"))
    print("— phase 2: restored onto a 4-device mesh, steps 20–39 —")
    out2 = run_training(TrainConfig(**tc, steps=40), mesh=mesh4)

    print(f"loss at handover: {out1['losses'][-1]:.4f} → "
          f"continued to {out2['losses'][-1]:.4f} on the smaller mesh")
    assert out2["losses"][-1] < out1["losses"][0]
    print("elastic re-mesh OK ✓")


if __name__ == "__main__":
    main()
