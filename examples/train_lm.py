"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the local devices, with the paper's technique as the gradient-sync
strategy (manual ZeRO-3 engine + GenModel-selected collectives), async
checkpointing, fault-tolerant loop, and straggler watchdog.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import os
# default: single local device (fastest on a 1-core container); set
# XLA_FLAGS=--xla_force_host_platform_device_count=4 to exercise DP.

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch.train import TrainConfig, run_training
from repro.models.config import ModelConfig


def cfg_100m() -> ModelConfig:
    """~100M dense LM (GPT-2-medium-ish) in the stablelm family."""
    base = get_config("stablelm-12b")
    return dataclasses.replace(
        base, name="lm-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, d_head=64, d_ff=2048, vocab=8192)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--engine", default="auto",
                    choices=["manual", "auto"],
                    help="auto = pjit/XLA collectives; manual = ZeRO-3 "
                    "shard_map with GenModel-selected plans (slower on "
                    "CPU, the paper's technique end-to-end)")
    ap.add_argument("--sync", default="gentree")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # register the 100M config under a temp name by monkey-loading
    import repro.configs as C
    import types
    mod = types.ModuleType("repro.configs.lm_100m")
    mod.CONFIG = cfg_100m()
    mod.SUPPORTED_SHAPES = ("train_4k",)
    import sys
    sys.modules["repro.configs.lm_100m"] = mod

    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    n = mod.CONFIG.params_count()
    print(f"training lm-100m ({n/1e6:.0f}M params) on "
          f"{len(jax.devices())} devices, engine={args.engine}, "
          f"sync={args.sync}")
    out = run_training(
        TrainConfig(arch="lm-100m", steps=args.steps, seq_len=128,
                    global_batch=max(2, len(jax.devices())),
                    engine=args.engine, sync=args.sync,
                    lr=6e-4, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                    log_every=20),
        mesh=mesh, smoke=False)
    losses = out["losses"]
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} over "
          f"{len(losses)} steps")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
