"""Online recalibration demo: the closed measure→fit→generate→execute loop.

A PlannerService is deliberately mis-calibrated (α 3× low, β 6× low on
the pod fabric — a model that thinks the cluster is much faster than it
is). A simulated "cluster" measures what the chosen plans ACTUALLY cost
(ground-truth GenModel params). Feeding those measurements back through
`PlannerService.observe` makes the drift detector fire, refit the level
class from telemetry — through the same core.fitting least squares the
offline harness uses — and hot-swap every derived schedule: stale plans
become unreachable (new fingerprints) and the next lookup lowers fresh
schedules under the refitted model. Finally, measured per-device arrival
offsets replace the synthetic skew model (DESIGN.md §10).

Run:  PYTHONPATH=src python examples/online_recalibration.py
"""
import dataclasses

from repro.core.cost_model import PAPER_TABLE5
from repro.core.simulator import Simulator
from repro.core.sync import level_switch_topo
from repro.planner.service import PlannerService, RefitPolicy

TRUE = PAPER_TABLE5                     # what the cluster really is
SIZES = [(8, 1e6), (8, 4e6), (4, 1e6), (8, 1.6e7), (4, 4e6),
         (8, 2e6), (8, 8e6), (4, 2e6)]


def measure_on_cluster(svc, n, size):
    """The 'cluster': simulate the service's chosen plan under the TRUE
    params — on real hardware this would be a wall-clock timing of the
    executed CompiledSchedule (launch.train's sync probe does exactly
    that)."""
    resp = svc.get_axis_executable("data", n, size, level="root_sw")
    topo = level_switch_topo(n, TRUE, "root_sw")
    measured = Simulator(topo, TRUE, unit_bytes=4).simulate(resp.plan).total
    return resp, measured


def main():
    wrong = dict(TRUE)
    wrong["root_sw"] = dataclasses.replace(
        TRUE["root_sw"], alpha=TRUE["root_sw"].alpha / 3,
        beta=TRUE["root_sw"].beta / 6)
    svc = PlannerService(params=wrong, refit_policy=RefitPolicy(
        min_samples=6, drift_threshold=0.15, cooldown=6))

    bp_before = svc.get_bucket_plan([("data", 8)], float(1 << 18))
    print(f"mis-calibrated service up: bucket plan key "
          f"{bp_before.key[:12]}…, "
          f"{svc.cache.derived_count()} derived schedule(s) cached")

    # ---- phase 1: observe until the drift detector fires ------------------
    print("\n— phase 1: training observes measured sync costs —")
    for step in range(3 * len(SIZES)):
        n, size = SIZES[step % len(SIZES)]
        resp, measured = measure_on_cluster(svc, n, size)
        obs = svc.observe("root_sw", n, size, measured,
                          predicted=resp.predicted_time, key=resp.key)
        if step < 3 or obs["refit"]:
            print(f"  step {step:2d}: predicted {obs['predicted'] * 1e3:7.3f}"
                  f" ms, measured {measured * 1e3:7.3f} ms, drift "
                  f"{obs['drift']:.2f}" + ("  → REFIT" if obs["refit"]
                                           else ""))
        if obs["refit"]:
            break
    assert svc.refits, "drift never fired — mis-seed harder"
    print(f"  refit dropped {svc.refits[0]['dropped']} derived artifact(s); "
          f"derived_count now {svc.cache.derived_count()}")

    # ---- phase 2: replanned under the refitted model ----------------------
    print("\n— phase 2: fresh plans under the refitted params —")
    bp_after = svc.get_bucket_plan([("data", 8)], float(1 << 18))
    assert bp_after.key != bp_before.key                 # unreachable
    assert bp_after.axis_plans[0].schedule is not \
        bp_before.axis_plans[0].schedule                 # hot-swapped
    print(f"  new bucket plan key {bp_after.key[:12]}… "
          f"(old key misses; schedule identity differs)")
    worst = 0.0
    for n, size in SIZES:
        resp, measured = measure_on_cluster(svc, n, size)
        worst = max(worst, abs(resp.predicted_time - measured) / measured)
    print(f"  worst post-refit |predicted − measured| / measured: "
          f"{worst * 100:.2f}%  (acceptance gate: < 10%)")
    assert worst < 0.10

    # ---- phase 3: empirical skew from measured arrivals -------------------
    print("\n— phase 3: measured arrival offsets replace synthetic skew —")
    for _ in range(4):      # e.g. per-device barrier timings of 8 ranks
        svc.observe_arrivals([0.0, 0.002, 0.0, 0.015, 0.0, 0.001,
                              0.03, 0.0])
    model = svc.adopt_empirical_skew()
    print(f"  adopted SkewModel(dist={model.dist!r}, "
          f"scale={model.scale:.3f}s) from "
          f"{svc.telemetry.arrivals.n_devices} devices — plan "
          f"fingerprints now include the measured arrival pattern")
    r = svc.get_plan(level_switch_topo(8, svc.params, "root_sw"), 1 << 22)
    print(f"  re-ranked under measured skew: {r.algo} "
          f"(expected skewed time {r.expected_skewed_time:.4f}s)")
    print("\nonline recalibration OK ✓")


if __name__ == "__main__":
    main()
