"""Plan AllReduce for YOUR cluster: fit GenModel from benchmark curves,
build the topology, and let GenTree generate the per-switch plan — the
paper's §3.4 + §4 workflow end-to-end, including the multi-pod TPU tree
used by the launcher's gradient-sync strategy.

Run:  PYTHONPATH=src python examples/plan_a_cluster.py
"""
import time

import numpy as np

from repro.core import cost_model as cm
from repro.core.fitting import fit_from_cps_benchmarks
from repro.core.gentree import gentree
from repro.core.sync import plan_axes_gentree
from repro.core.topology import cross_dc, tpu_pod_tree

# -- 1. fit from (simulated) co-located-PS benchmark curves ---------------
true = cm.GenModelParams()
ns, sizes, times = [], [], []
for n in range(2, 16):
    for s in (1e7, 3.2e7, 1e8):
        ns.append(n), sizes.append(s)
        times.append(cm.cost_cps(n, s, true))     # your measurements here
fit = fit_from_cps_benchmarks(np.array(ns), np.array(sizes),
                              np.array(times))
print(f"fitted: α={fit.alpha:.2e}  δ={fit.delta:.2e}  "
      f"ε={fit.epsilon:.2e}  w_t={fit.w_t}")

# -- 2. GenTree on a cross-datacenter tree ---------------------------------
topo = cross_dc(dc0_middle=4, dc0_servers=16, dc1_middle=4, dc1_servers=8)
r = gentree(topo, 3.2e7)
print(f"\ncross-DC plan ({topo.num_servers()} servers), predicted "
      f"{r.predicted_time * 1e3:.1f} ms:")
for sw, d in sorted(r.decisions.items()):
    extra = f" rearrange→{d.rearrange}" if d.rearrange else ""
    print(f"  {sw:12s} {d.algo}{d.factors or ''}{extra}")

# -- 3. the TPU-pod tree the trainer's sync strategy uses -------------------
pods = tpu_pod_tree(n_pods=2, chips_per_pod=16)
r2 = gentree(pods, 1e8, params=cm.TPU_V5E)
print(f"\nTPU 2-pod tree plan, predicted {r2.predicted_time * 1e3:.2f} ms:")
for sw, d in sorted(r2.decisions.items()):
    print(f"  {sw:12s} {d.algo}{d.factors or ''}")

# -- 4. per-mesh-axis plan selection (what sync.sync_gradients executes) ---
plans = plan_axes_gentree([("data", 16), ("pod", 2)],
                          size_floats=1.2e9)      # 1.2B-param gradient
print("\ngradient-sync plans for mesh axes (data=16, pod=2):")
for p in plans:
    print(f"  axis {p.axis!r}: {p.strategy}{p.factors or ''}")

# -- 5. productionized: the cached, calibrated, skew-aware PlannerService --
# Steps 1-3 by hand are what the planner subsystem automates (DESIGN.md §5):
# calibrate() refits every level class from microbench curves, get_plan()
# memoizes GenTree output behind a fingerprinted, size-bucketed LRU cache,
# and a SkewModel re-ranks candidates by expected cost under imbalanced
# process arrivals instead of assuming synchronized starts.
from repro.planner import CalibrationConfig, PlannerService, SkewModel

svc = PlannerService(skew=SkewModel(dist="exponential", scale=5e-3))
svc.calibrate(cfg=CalibrationConfig(backend="simulator"))
for attempt in ("cold", "warm"):
    t0 = time.perf_counter()
    resp = svc.get_plan(topo, nbytes=128 << 20)
    dt = time.perf_counter() - t0
    print(f"\n{attempt} get_plan ({resp.source}): algo={resp.algo}, "
          f"predicted {resp.predicted_time * 1e3:.1f} ms"
          + (f", expected under skew {resp.expected_skewed_time * 1e3:.1f} ms"
             if resp.expected_skewed_time is not None else "")
          + f"  [{dt * 1e3:.2f} ms lookup]")
cs = svc.stats()["cache"]
print(f"cache: {cs['hits']} hits / {cs['misses']} misses, "
      f"hit rate {cs['hit_rate']:.0%}")
