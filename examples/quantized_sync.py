"""One bucketed fp8 gradient sync, priced per GenModel term (DESIGN.md §13).

Runs a gradient sync with `SyncConfig(strategy="plan", precision="fp8",
tolerance=0.3)` on an 8-host-device mesh: the bucket-plan sweep argmins
jointly over bucket size AND wire precision, the chosen schedule moves
fp8 payloads + per-tile f32 scales through the coalesced ppermute rounds,
and the folds run the fused dequant-accumulate kernel. The measured step
is fed back through `PlannerService.observe(precision="fp8")`, so the
cost ledger decomposes the quoted prediction into per-term seconds with
the quant passes charged to γ/δ and the shrunk wire to β/incast — then
prints that ledger next to the full-precision pricing of the same sync.

Run:  PYTHONPATH=src python examples/quantized_sync.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.cost_model import PRECISIONS
from repro.core.sync import SyncConfig, sync_gradients
from repro.planner.service import default_service


def main():
    n = 8
    axes = [("data", n)]
    mesh = jax.make_mesh((n,), ("data",))
    cfg = SyncConfig(strategy="plan", precision="fp8", tolerance=0.3)

    key = jax.random.PRNGKey(0)
    grads = {}
    for i, size in enumerate((65536, 16384, 4096, 257)):
        key, sub = jax.random.split(key)
        grads[f"leaf{i}"] = jax.random.normal(sub, (n, size), jnp.float32)
    total = float(sum(v[0].size for v in grads.values()))

    stats = {}
    f = shard_map(
        lambda g: jax.tree.map(
            lambda v: v[None],
            sync_gradients(jax.tree.map(lambda v: v[0], g), axes, cfg,
                           stats=stats)),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    step = jax.jit(f)
    got = step(grads)                       # compile + trace
    t0 = time.perf_counter()
    got = jax.block_until_ready(step(grads))
    measured = time.perf_counter() - t0

    # correctness against psum, within the fp8 error budget
    budget = PRECISIONS["fp8"].error_budget
    worst = 0.0
    for k, v in grads.items():
        want = np.asarray(v.sum(0), np.float64)
        err = np.abs(np.asarray(got[k], np.float64)[0] - want).max() / \
            (np.abs(want).max() + 1e-30)
        worst = max(worst, err)
        assert err < budget, (k, err)
    print(f"fp8 bucketed sync == psum within budget "
          f"(worst rel err {worst:.4f} < {budget}), "
          f"precision={stats.get('precision')}, "
          f"buckets={stats.get('num_buckets')}, measured {measured:.4f} s")

    # ---- the cost ledger, per term (DESIGN.md §11 + §13) -------------------
    svc = default_service()
    svc.observe("root_sw", n, total, measured, precision="fp8",
                dtype="float32")
    entry = svc.telemetry.ledger.entries("root_sw")[-1]
    full = svc._axis_halves_time(n, "root_sw", total, "float32",
                                 svc._effective_axis_params())
    print(f"\nquoted prediction {entry.predicted:.3e} s "
          f"(f32 pricing of the same sync: {sum(full):.3e} s) "
          f"vs measured {entry.measured:.3e} s")
    print(f"{'term':>8s}  {'seconds':>12s}  {'share':>7s}")
    tot = sum(entry.shares.values()) or 1.0
    for term, sec in sorted(entry.shares.items(), key=lambda kv: -kv[1]):
        print(f"{term:>8s}  {sec:12.3e}  {sec / tot * 100:6.1f}%")
    print("\nthe quant passes ride γ (adds) and δ (memory ops); β and the"
          "\nincast term price the compressed wire — the trade the sweep"
          "\nargmins over (DESIGN.md §13).")


if __name__ == "__main__":
    main()
