"""Batched serving example: prefill a prompt batch, then decode with a KV
cache — the inference side of every dry-run decode cell, runnable on CPU
with a reduced config.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-27b]
"""
import argparse

from repro.launch.serve import ServeConfig, serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b",
                    help="any assigned architecture id")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()
    out = serve(ServeConfig(arch=args.arch, batch=args.batch,
                            max_new=args.max_new))
    print(f"generated {out['tokens'].shape} tokens "
          f"(batch × steps) with a sliding-window KV cache")


if __name__ == "__main__":
    main()
