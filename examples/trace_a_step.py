"""Trace one bucketed, double-buffered sync step (DESIGN.md §9 + §11).

Enables the process-wide span tracer, runs a gradient sync with
`SyncConfig(strategy="plan")` — the GenTree plan lowered to a compiled
schedule, partitioned into GenModel-sized buckets with bucket k's
AllGather overlapping bucket k+1's ReduceScatter — on an 8-host-device
mesh, and exports a Chrome-trace JSON you can load in chrome://tracing
or https://ui.perfetto.dev.

The spans inside the shard_map body (`sync/bucketed`, per-bucket
`bucket/rs` / `bucket/ag`, per-round `exec/...`) fire at *trace time* —
they record the staging-out of the schedule, nested exactly as the
schedule executes, not device wall-clock (DESIGN.md §11). The planner
spans (`planner/generate_plan`, `planner/bucket_sweep`) and the metrics
(cache hits/misses, bucket counts, pipeline occupancy) are host-side and
real either way.

Run:  PYTHONPATH=src python examples/trace_a_step.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.sync import SyncConfig, sync_gradients
from repro.runtime.metrics import default_metrics
from repro.runtime.trace import default_tracer

TRACE_PATH = "trace_a_step.json"
METRICS_PATH = "trace_a_step_metrics.json"


def main():
    tracer = default_tracer()
    tracer.enabled = True

    n = 8
    axes = [("data", n)]
    mesh = jax.make_mesh((n,), ("data",))
    # bucket_bytes pinned below the pytree size so the step really runs
    # multiple buckets and the RS(k+1)/AG(k) overlap shows in the trace
    cfg = SyncConfig(strategy="plan", bucket_bytes=8192, pipeline=True)

    # a small mixed pytree of "gradients", replicated rows per device
    key = jax.random.PRNGKey(0)
    grads = {}
    for i, size in enumerate((4096, 1536, 257, 64)):
        key, sub = jax.random.split(key)
        grads[f"leaf{i}"] = jax.random.normal(sub, (n, size), jnp.float32)

    stats = {}
    f = shard_map(
        lambda g: jax.tree.map(
            lambda v: v[None],
            sync_gradients(jax.tree.map(lambda v: v[0], g), axes, cfg,
                           stats=stats)),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    with tracer.span("example/sync_step", leaves=len(grads)):
        got = jax.jit(f)(grads)

    # correctness: the traced step is still the psum answer
    for k, v in grads.items():
        want = np.asarray(v.sum(0))
        err = np.abs(np.asarray(got[k])[0] - want).max() / \
            (np.abs(want).max() + 1e-30)
        assert err < 1e-5, (k, err)
    assert stats.get("num_buckets", 0) >= 2, "expected a multi-bucket step"
    print(f"bucketed sync == psum  (buckets={stats.get('num_buckets')}, "
          f"predicted pipelined {stats.get('predicted_pipelined'):.2e} s "
          f"vs serial {stats.get('predicted_serial'):.2e} s)")

    tracer.export_chrome(TRACE_PATH)
    default_metrics().export(METRICS_PATH)

    # prove the artifact is loadable and the spans nest as the schedule
    # executes: sync -> bucket halves -> rounds
    with open(TRACE_PATH) as fh:
        doc = json.load(fh)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    names = {e["name"] for e in events}
    for expected in ("example/sync_step", "sync/bucketed", "exec/round"):
        assert expected in names, f"missing span {expected!r}"
    print(f"wrote {TRACE_PATH}: {len(events)} spans "
          f"({len(names)} distinct), e.g. "
          + ", ".join(sorted(names)[:6]))
    print(f"wrote {METRICS_PATH} (+ .prom): "
          f"{len(default_metrics().snapshot())} metrics")
    print("load the trace in chrome://tracing or ui.perfetto.dev")


if __name__ == "__main__":
    main()
