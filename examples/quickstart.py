"""Quickstart — the paper's contribution in five minutes.

1. Fit GenModel to benchmark curves (here: the paper's own Table-5 fits).
2. Price the classic AllReduce plans and see the δ/ε trade-off.
3. Let GenTree pick the plan for a topology.
4. Execute exactly that plan as a JAX collective schedule and verify it
   against lax.psum.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import cost_model as cm
from repro.core.collectives import allreduce
from repro.core.gentree import gentree
from repro.core.topology import single_switch

# -- 1. GenModel: T = A·α + B·β + C·γ + D·δ + max(w−w_t,0)·B·ε -------------
params = cm.GenModelParams()        # the paper's CPU-testbed fit
S = 1e8                             # 100M floats, like the paper

print("plan pricing at N=12 (seconds):")
for name, cost in [
        ("ring", cm.cost_ring(12, S, params)),
        ("cps (fan-in 12 > w_t=9 → incast!)", cm.cost_cps(12, S, params)),
        ("hcps 6×2 (the paper's sweet spot)",
         cm.cost_hcps([6, 2], S, params))]:
    print(f"  {name:40s} {cost:.3f}")

# -- 2. the two new optimalities cannot both hold (Theorem 2) ---------------
from repro.core import optimality, plans
p_cps = plans.cps(12, S)
p_ring = plans.ring(12, S)
print(f"\nCPS:  δ-optimal={optimality.is_delta_optimal(p_cps)} "
      f"ε-optimal={optimality.is_epsilon_optimal(p_cps, params.w_t)}")
print(f"Ring: δ-optimal={optimality.is_delta_optimal(p_ring)} "
      f"ε-optimal={optimality.is_epsilon_optimal(p_ring, params.w_t)}")

# -- 3. GenTree picks the plan for the topology -----------------------------
result = gentree(single_switch(12), S)
dec = result.decisions["root"]
print(f"\nGenTree on 12-server switch picks: {dec.algo} {dec.factors} "
      f"(predicted {result.predicted_time:.3f}s)")

# -- 4. run that plan as a JAX collective schedule --------------------------
mesh = jax.make_mesh((8,), ("x",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))

f = shard_map(
    lambda v: allreduce(v[0], "x", "hcps", factors=(4, 2))[None],
    mesh=mesh, in_specs=P("x"), out_specs=P("x"))
got = np.asarray(f(x))
want = np.asarray(x.sum(0))
assert np.allclose(got, np.tile(want, (8, 1)), rtol=1e-4, atol=1e-4)
print("\nhcps(4,2) AllReduce on an 8-device mesh matches lax.psum ✓")
