"""Linear-recurrence layers: RWKV6 (Finch) time-mix and Mamba-style
selective SSM (Hymba's parallel branch).

RWKV6 uses a *chunked parallel* form: within a chunk of C tokens the pair
weight for (t, s<t) is exp(Λ_t − Λ_s) per channel with Λ the running
log-decay sum — every exponent is ≤ 0, so the form is unconditionally
numerically stable (no 1/decay blow-ups). Cross-chunk state is carried by
lax.scan. The (C, C, K) pair tensor is the compute hot-spot a Mosaic kernel
would fuse on real TPU; the XLA form lowers everywhere and has the right
FLOP count.

Mamba's decay is per (channel, state) — not separable — so Hymba's SSM
branch runs a chunk-checkpointed sequential scan (outer scan saves one
carry per chunk; the inner steps are rematerialized in backward), keeping
activation memory at T/C × state instead of T × state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import Params, dense_init, rmsnorm


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------
def init_rwkv(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim
    lora = 64
    ks = jax.random.split(key, 12)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dtype),
        "wr": dense_init(ks[1], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[2], (d, h * hd), dtype=dtype),
        "wv": dense_init(ks[3], (d, h * hd), dtype=dtype),
        "wg": dense_init(ks[4], (d, h * hd), dtype=dtype),
        "wo": dense_init(ks[5], (h * hd, d), dtype=dtype),
        "w0": (jax.random.normal(ks[6], (h * hd,), jnp.float32) * 0.5
               - 2.0).astype(jnp.float32),
        "w_a": dense_init(ks[7], (d, lora), dtype=dtype),
        "w_b": dense_init(ks[8], (lora, h * hd), scale=0.01, dtype=dtype),
        "u": (jax.random.normal(ks[9], (h, hd), jnp.float32) * 0.1
              ).astype(jnp.float32),
        "ln_x": jnp.zeros((h * hd,), dtype),
        # channel mix
        "cm_mu": jax.random.uniform(ks[10], (2, d), jnp.float32).astype(dtype),
        "cm_k": dense_init(ks[11], (d, cfg.d_ff), dtype=dtype),
        "cm_v": dense_init(jax.random.fold_in(key, 99), (cfg.d_ff, d),
                           dtype=dtype),
        "cm_r": dense_init(jax.random.fold_in(key, 98), (d, d), dtype=dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x: (B, T, D) → x shifted right by one (first slot = prev or 0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_chunk(r, k, v, logw, u, s0, chunk: int):
    """Chunked WKV. r/k/v: (B,H,T,K|V); logw: (B,H,T,K) ≤ 0;
    u: (H,K); s0: (B,H,K,V). Returns (out (B,H,T,V), s_final)."""
    B, H, T, K = k.shape
    V = v.shape[-1]
    C = min(chunk, T)
    while T % C:          # largest divisor of T not exceeding `chunk`
        C -= 1
    nc = T // C

    def body(s, inputs):
        rc, kc, vc, lw = inputs                    # (B,H,C,·)
        linc = jnp.cumsum(lw, axis=2)              # inclusive Λ (B,H,C,K)
        lexc = linc - lw                           # exclusive
        # state contribution
        o1 = jnp.einsum("bhtk,bhkv->bhtv", rc * jnp.exp(lexc), s)
        # intra-chunk pairs (s < t): exponent lexc_t − linc_s ≤ 0
        expo = lexc[:, :, :, None, :] - linc[:, :, None, :, :]  # (B,H,C,C,K)
        tmask = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])
        expo = jnp.where(tmask[None, None, :, :, None], expo, -jnp.inf)
        pair = jnp.exp(expo)
        att = jnp.einsum("bhtk,bhsk,bhtsk->bhts", rc, kc, pair)
        o2 = jnp.einsum("bhts,bhsv->bhtv", att, vc)
        # bonus (current token)
        bonus = jnp.einsum("bhtk,bhtk->bht", rc, kc * u[None, :, None, :])
        o3 = bonus[..., None] * vc
        # state update
        ltot = linc[:, :, -1:, :]                  # (B,H,1,K)
        s_new = jnp.exp(ltot.squeeze(2))[..., None] * s + jnp.einsum(
            "bhtk,bhtv->bhkv", kc * jnp.exp(ltot - linc), vc)
        return s_new, o1 + o2 + o3

    def split(a):
        return a.reshape(B, H, nc, C, a.shape[-1]).transpose(2, 0, 1, 3, 4)

    s_fin, outs = lax.scan(
        body, s0, (split(r), split(k), split(v), split(logw)))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, V)
    return out, s_fin


def rwkv_time_mix(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  state: jax.Array | None = None, chunk: int = 32,
                  shift_prev: jax.Array | None = None):
    """x: (B,T,D) → (out, final_state). state: (B,H,K,V)."""
    B, T, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    xs = _token_shift(x, shift_prev)
    mu = p["mu"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xsf = xs.astype(jnp.float32)

    def mix(i):
        return (xf + mu[i] * (xsf - xf)).astype(x.dtype)

    r = (mix(0) @ p["wr"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    k = (mix(1) @ p["wk"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    v = (mix(2) @ p["wv"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu((mix(3) @ p["wg"]).astype(jnp.float32))
    # data-dependent decay (RWKV6): w = exp(−exp(w0 + tanh(x A) B))
    dd = jnp.tanh((mix(4) @ p["w_a"]).astype(jnp.float32)) @ \
        p["w_b"].astype(jnp.float32)
    logw = -jnp.exp(p["w0"][None, None] + dd)          # (B,T,H·hd) ≤ 0
    logw = logw.reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    if jax.default_backend() == "tpu":
        # Pallas kernel: state + pair tile stay in VMEM (kernels/wkv.py)
        from repro.kernels.wkv import wkv as _wkv_kernel_call
        out, s_fin = _wkv_kernel_call(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), logw, p["u"], state, chunk=chunk)
    else:
        out, s_fin = _wkv_chunk(r.astype(jnp.float32),
                                k.astype(jnp.float32),
                                v.astype(jnp.float32), logw, p["u"], state,
                                chunk)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
    out = rmsnorm(out, p["ln_x"]).astype(jnp.float32) * g
    return (out.astype(x.dtype) @ p["wo"]), s_fin


def rwkv_channel_mix(p: Params, x: jax.Array,
                     shift_prev: jax.Array | None = None) -> jax.Array:
    xs = _token_shift(x, shift_prev)
    mu = p["cm_mu"].astype(jnp.float32)
    xf, xsf = x.astype(jnp.float32), xs.astype(jnp.float32)
    xk = (xf + mu[0] * (xsf - xf)).astype(x.dtype)
    xr = (xf + mu[1] * (xsf - xf)).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid((xr @ p["cm_r"]).astype(jnp.float32)
                          ).astype(x.dtype) * (kk @ p["cm_v"])


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (Hymba branch)
# ---------------------------------------------------------------------------
def init_mamba(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "in_x": dense_init(ks[0], (d, di), dtype=dtype),
        "in_z": dense_init(ks[1], (d, di), dtype=dtype),
        "w_dt": dense_init(ks[2], (di, 1), scale=0.1, dtype=jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "w_b": dense_init(ks[3], (di, n), dtype=dtype),
        "w_c": dense_init(ks[4], (di, n), dtype=dtype),
        "log_a": (-jnp.exp(jax.random.normal(ks[5], (di, n), jnp.float32)
                           * 0.5)).astype(jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out": dense_init(jax.random.fold_in(key, 7), (di, d), dtype=dtype),
    }


def mamba_ssm(p: Params, x: jax.Array, cfg: ModelConfig, *,
              state: jax.Array | None = None, chunk: int = 16):
    """x: (B,T,D) → (out, final_state). state: (B, Di, N).

    Sequential scan, chunk-checkpointed: the outer scan carries one state
    per chunk; inner steps recompute in backward (jax.checkpoint)."""
    B, T, D = x.shape
    di, n = p["log_a"].shape
    xb = (x @ p["in_x"]).astype(jnp.float32)            # (B,T,Di)
    z = jax.nn.silu((x @ p["in_z"]).astype(jnp.float32))
    # per-channel step size: broadcast the rank-1 dt over channels + bias
    dt = jax.nn.softplus(xb @ p["w_dt"] + p["dt_bias"][None, None])  # (B,T,Di)
    b_t = xb @ p["w_b"].astype(jnp.float32) / di ** 0.5  # (B,T,N)
    c_t = xb @ p["w_c"].astype(jnp.float32) / di ** 0.5  # (B,T,N)
    u = jax.nn.silu(xb)                                  # (B,T,Di)

    C = min(chunk, T)
    while T % C:          # largest divisor of T not exceeding `chunk`
        C -= 1
    nc = T // C
    if state is None:
        state = jnp.zeros((B, di, n), jnp.float32)

    if jax.default_backend() == "tpu":
        # Pallas kernel: (BD, N) state tile stays in VMEM for the whole
        # sequence (kernels/ssm_scan.py)
        from repro.kernels.ssm_scan import ssm_scan
        ys, s_fin = ssm_scan(u, dt, b_t, c_t, p["log_a"], state, chunk=C)
        y = (ys + u * p["d_skip"][None, None]) * z
        return (y.astype(x.dtype) @ p["out"]), s_fin

    def chunk_body(s, inp):
        xc, dtc, bc, cc = inp   # (B,C,Di), (B,C,Di), (B,C,N), (B,C,N)

        def step(s, i):
            decay = jnp.exp(dtc[:, i][:, :, None] * p["log_a"][None])
            s = decay * s + (dtc[:, i] * xc[:, i])[:, :, None] * \
                bc[:, i][:, None, :]
            y = jnp.einsum("bdn,bn->bd", s, cc[:, i])
            return s, y

        s, ys = lax.scan(step, s, jnp.arange(C))
        return s, ys.transpose(1, 0, 2)                 # (B,C,Di)

    def split(a):
        return a.reshape(B, nc, C, a.shape[-1]).transpose(1, 0, 2, 3)

    s_fin, outs = lax.scan(jax.checkpoint(chunk_body), state,
                           (split(u), split(dt), split(b_t), split(c_t)))
    y = outs.transpose(1, 0, 2, 3).reshape(B, T, di)
    y = (y + u * p["d_skip"][None, None]) * z
    return (y.astype(x.dtype) @ p["out"]), s_fin
