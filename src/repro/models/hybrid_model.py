"""Hymba-style hybrid: every layer runs attention heads and a Mamba SSM
branch *in parallel* on the same input, normalizes each branch output, and
averages them (arXiv:2411.13676, meta-tokens omitted — DESIGN.md
§Arch-applicability).

Decode state = KV cache (bounded by the sliding window for local layers)
+ per-layer SSM state, so `long_500k` decode is O(window + state), not O(T).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (Params, attention, attention_decode, dense_init,
                     init_attention, init_mlp, mlp, rmsnorm)
from .actsharding import constrain
from .recurrence import init_mamba, mamba_ssm
from .transformer import window_array


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    L = cfg.n_layers
    keys = jax.random.split(key, L + 2)

    def layer(k):
        ks = jax.random.split(k, 3)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "ln_attn": jnp.zeros((cfg.d_model,), dtype),
            "ln_ssm": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "ssm": init_mamba(ks[1], cfg, dtype),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
        }

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[layer(keys[i]) for i in range(L)])
    return {
        "layers": stacked,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "embed": dense_init(keys[L], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dtype),
        "lm_head": dense_init(keys[L + 1], (cfg.d_model, cfg.vocab),
                              dtype=dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> dict:
    hd = cfg.head_dim
    di = cfg.ssm_expand * cfg.d_model
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, seq, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, seq, hd), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, di, cfg.ssm_state),
                         jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _combine(cfg, lp, a, s):
    a = rmsnorm(a, lp["ln_attn"])
    s = rmsnorm(s, lp["ln_ssm"])
    return ((a.astype(jnp.float32) + s.astype(jnp.float32)) * 0.5
            ).astype(a.dtype)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            remat: bool = True, ssm_chunk: int = 16, **_kw) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, _ = x.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    wins = window_array(cfg)

    def body(x, inp):
        lp, w = inp
        z = rmsnorm(x, lp["ln1"])
        a = attention(lp["attn"], z, cfg, window=w, positions=positions)
        s, _ = mamba_ssm(lp["ssm"], z, cfg, chunk=ssm_chunk)
        x = x + _combine(cfg, lp, a, s)
        x = constrain(x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"])))
        return x, None

    blk = jax.checkpoint(body) if remat else body
    x, _ = lax.scan(blk, x, (params["layers"], wins))
    x = rmsnorm(x, params["ln_f"])
    return x @ params["lm_head"]


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, **kw) -> jax.Array:
    logits = forward(params, cfg, batch["tokens"], **kw)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            cache_len: int, ssm_chunk: int = 16, **_kw
            ) -> tuple[jax.Array, dict]:
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, _ = x.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    wins = window_array(cfg)

    def body(x, inp):
        lp, w = inp
        z = rmsnorm(x, lp["ln1"])
        from .layers import _qkv
        _, k, v = _qkv(lp["attn"], z, cfg, positions, None)
        a = attention(lp["attn"], z, cfg, window=w, positions=positions)
        s, s_fin = mamba_ssm(lp["ssm"], z, cfg, chunk=ssm_chunk)
        x = x + _combine(cfg, lp, a, s)
        x = constrain(x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"])))
        return x, (k, v, s_fin)

    x, (ks, vs, ss) = lax.scan(jax.checkpoint(body), x,
                               (params["layers"], wins))
    x = rmsnorm(x, params["ln_f"])
    logits = x[:, -1:] @ params["lm_head"]
    cache = init_cache(cfg, B, cache_len, ks.dtype)
    cache["k"] = lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0))
    cache["v"] = lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0))
    cache["ssm"] = ss
    cache["pos"] = jnp.full((B,), T, jnp.int32)
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: dict,
                tokens: jax.Array, **_kw) -> tuple[jax.Array, dict]:
    x = jnp.take(params["embed"], tokens, axis=0)
    B = x.shape[0]
    pos = cache["pos"]
    wins = window_array(cfg)

    def body(x, inp):
        lp, w, ck, cv, cs = inp
        z = rmsnorm(x, lp["ln1"])
        a, nk, nv = attention_decode(lp["attn"], z, ck, cv, pos, cfg,
                                     window=w)
        s, ns = mamba_ssm(lp["ssm"], z, cfg, state=cs, chunk=1)
        x = x + _combine(cfg, lp, a, s)
        x = constrain(x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"])))
        return x, (nk, nv, ns)

    x, (nks, nvs, nss) = lax.scan(
        body, x, (params["layers"], wins, cache["k"], cache["v"],
                  cache["ssm"]))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["lm_head"]
    return logits, {"k": nks, "v": nvs, "ssm": nss, "pos": pos + 1}
