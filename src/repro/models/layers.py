"""Shared transformer layers: GQA attention (causal / sliding-window /
softcap / qk-norm / RoPE / M-RoPE), SwiGLU MLP, MoE (dense-masked and
sorted-dispatch), RMSNorm.

Attention never materializes a (T, T) score tensor: the train/prefill path
scans over query blocks (online softmax against the full K for global
layers; a banded KV slice for sliding-window layers, making local layers
O(T·W)). This is the flash algorithm expressed in XLA ops so it lowers on
any backend; the Pallas kernel (kernels/flash_attention.py) is the
TPU-native variant selected with impl="pallas".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig

Params = dict[str, Any]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, scale: float | None = None, dtype=jnp.bfloat16):
    fan_in = shape[-2] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def _rope_freqs(d: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (..., T, D_head); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE. x: (B, H, T, D); positions: (3, B, T) —
    one position stream per (t, h, w) section of the rotary dims."""
    d = x.shape[-1]
    half = d // 2
    freqs = _rope_freqs(d, theta)                       # (half,)
    # section s owns freqs[start:start+sections[s]] (cumulative over half)
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)
    pos = positions[sec_id]                             # (half, B, T) gather
    pos = jnp.moveaxis(pos, 0, -1)                      # (B, T, half)
    ang = pos[:, None, :, :].astype(jnp.float32) * freqs  # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qkv(p: Params, x: jax.Array, cfg: ModelConfig,
         positions: jax.Array | None, mrope_positions: jax.Array | None):
    B, T, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, T, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, T, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta,
                        cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta,
                        cfg.mrope_sections)
    elif positions is not None:
        q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def _sdpa_block(q, k, v, mask, scale, softcap, remask: bool = True):
    """One (bq × Tk) attention rectangle; returns (out, m, l) f32.

    remask=False skips the post-exp re-mask — one fewer full pass over the
    (bq, Tk) tile. Only safe when every query row has at least one valid
    key (causal self-attention rows always see themselves); the chunked-KV
    path keeps remask=True because whole blocks can be fully masked
    (m = −inf there would make exp(s − m) = 1, not 0)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    if remask:
        p = jnp.where(mask, p, 0.0)
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o, m, l


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              window: jax.Array | int = 0, causal: bool = True,
              positions: jax.Array | None = None,
              mrope_positions: jax.Array | None = None,
              block_q: int = 512, kv_override=None) -> jax.Array:
    """Full-sequence attention (train / prefill), q-block scanned.

    window: static int (banded path when > 0) or traced scalar (masked
    path — used under scan over heterogeneous layers).
    """
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T)[None, :].astype(jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions, mrope_positions)
    if kv_override is not None:
        k, v = kv_override
    hd = cfg.head_dim
    scale = hd ** -0.5
    rep = cfg.n_heads // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    Tk = k.shape[2]

    bq = min(block_q, T)
    if T % bq:
        bq = T  # fallback: single block
    nq = T // bq

    static_window = isinstance(window, int)
    if static_window and window > 0 and causal and Tk == T and window < T:
        # ---- banded path: each q block sees [start, start+span) of KV ----
        span = min(bq + (window // bq + 1) * bq, Tk)

        def body(carry, qi):
            start = jnp.maximum(qi * bq - (span - bq), 0)
            start = jnp.minimum(start, Tk - span)
            qb = lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=2)
            kb = lax.dynamic_slice_in_dim(k, start, span, axis=2)
            vb = lax.dynamic_slice_in_dim(v, start, span, axis=2)
            qpos = qi * bq + jnp.arange(bq)[:, None]
            kpos = start + jnp.arange(span)[None, :]
            mask = (kpos <= qpos) & (kpos > qpos - window)
            o, m, l = _sdpa_block(qb, kb, vb, mask[None, None],
                                  scale, cfg.attn_softcap, remask=False)
            return carry, (o / (l + 1e-30)).astype(x.dtype)

        _, outs = lax.scan(jax.checkpoint(body), None, jnp.arange(nq))
        # outs: (nq, B, H, bq, hd) → (B, H, T, hd)
        out = jnp.moveaxis(outs, 0, 2).reshape(B, cfg.n_heads, T, hd)
    elif cfg.attn_kv_block and Tk % cfg.attn_kv_block == 0 \
            and cfg.attn_kv_block < Tk:
        # ---- flash-in-XLA: online-softmax scan over KV blocks -------------
        # Materializes only (bq × bk) logit tiles + running (m, l, acc)
        # accumulators, instead of the full (bq × Tk) rectangle — the same
        # algorithm the Pallas kernel runs in VMEM, expressed in XLA ops so
        # the HBM traffic shrinks on every backend.
        bk = cfg.attn_kv_block
        nk = Tk // bk

        def q_body(carry, qi):
            qb = lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=2)
            qpos = qi * bq + jnp.arange(bq)[:, None] + (Tk - T)

            def kv_body(acc, ki):
                o_acc, m_acc, l_acc = acc
                kb = lax.dynamic_slice_in_dim(k, ki * bk, bk, axis=2)
                vb = lax.dynamic_slice_in_dim(v, ki * bk, bk, axis=2)
                kpos = ki * bk + jnp.arange(bk)[None, :]
                mask = jnp.ones((bq, bk), bool)
                if causal:
                    mask &= kpos <= qpos
                w = window
                if not static_window:
                    mask &= (w <= 0) | (kpos > qpos - w)
                elif w > 0:
                    mask &= kpos > qpos - w
                o, m, l = _sdpa_block(qb, kb, vb, mask[None, None],
                                      scale, cfg.attn_softcap)
                m_new = jnp.maximum(m_acc, m)
                alpha = jnp.exp(m_acc - m_new)
                beta = jnp.exp(m - m_new)
                return (o_acc * alpha + o * beta,
                        m_new, l_acc * alpha + l * beta), None

            o0 = jnp.zeros((B, cfg.n_heads, bq, hd), jnp.float32)
            m0 = jnp.full((B, cfg.n_heads, bq, 1), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, cfg.n_heads, bq, 1), jnp.float32)
            (o, _m, l), _ = lax.scan(kv_body, (o0, m0, l0), jnp.arange(nk))
            return carry, (o / (l + 1e-30)).astype(x.dtype)

        _, outs = lax.scan(jax.checkpoint(q_body), None, jnp.arange(nq))
        out = jnp.moveaxis(outs, 0, 2).reshape(B, cfg.n_heads, T, hd)
    else:
        # ---- q-block scan against full K (global layers) -----------------
        def body(carry, qi):
            qb = lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=2)
            qpos = qi * bq + jnp.arange(bq)[:, None] + (Tk - T)
            kpos = jnp.arange(Tk)[None, :]
            mask = jnp.ones((bq, Tk), bool)
            if causal:
                mask &= kpos <= qpos
            w = window
            if not static_window:
                mask &= (w <= 0) | (kpos > qpos - w)
            elif w > 0:
                mask &= kpos > qpos - w
            o, m, l = _sdpa_block(qb, k, v, mask[None, None],
                                  scale, cfg.attn_softcap,
                                  remask=not causal)
            return carry, (o / (l + 1e-30)).astype(x.dtype)

        # nested remat: without it the backward stacks each q-block's (bq, Tk)
        # probability matrix as scan residuals (9.2 TB/device measured on
        # mixtral×train_4k); recompute from (q,k,v) instead.
        _, outs = lax.scan(jax.checkpoint(body), None, jnp.arange(nq))
        out = jnp.moveaxis(outs, 0, 2).reshape(B, cfg.n_heads, T, hd)

    out = out.transpose(0, 2, 1, 3).reshape(B, T, cfg.n_heads * hd)
    return out @ p["wo"]


def attention_decode(p: Params, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, pos: jax.Array, cfg: ModelConfig, *,
                     window: jax.Array | int = 0,
                     mrope_positions: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); cache_{k,v}: (B, Hkv, S, hd);
    pos: (B,) current write position. Returns (out, new_k, new_v)."""
    B = x.shape[0]
    hd = cfg.head_dim
    positions = pos[:, None].astype(jnp.int32)
    q, k_new, v_new = _qkv(p, x, cfg, positions, mrope_positions)
    # scatter the new K/V at `pos` along the seq axis, per batch element
    ck = jax.vmap(
        lambda c, kn, i: lax.dynamic_update_slice_in_dim(c, kn, i, axis=1)
    )(cache_k, k_new, pos)
    cv = jax.vmap(
        lambda c, vn, i: lax.dynamic_update_slice_in_dim(c, vn, i, axis=1)
    )(cache_v, v_new, pos)

    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(ck, rep, axis=1) if rep > 1 else ck
    v = jnp.repeat(cv, rep, axis=1) if rep > 1 else cv
    S = k.shape[2]
    kpos = jnp.arange(S)[None, :]                       # (1, S)
    valid = kpos <= pos[:, None]
    w = window
    if isinstance(w, int):
        if w > 0:
            valid &= kpos > pos[:, None] - w
    else:
        valid &= (w <= 0) | (kpos > pos[:, None] - w)
    mask = valid[:, None, None, :]                      # (B,1,1,S)
    o, m, l = _sdpa_block(q, k, v, mask, hd ** -0.5, cfg.attn_softcap)
    out = (o / (l + 1e-30)).astype(x.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * hd)
    return out @ p["wo"], ck, cv


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    return {"wi": dense_init(ks[0], (d, f), dtype=dtype),
            "wg": dense_init(ks[1], (d, f), dtype=dtype),
            "wo": dense_init(ks[2], (f, d), dtype=dtype)}


def mlp(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, fe = cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, cfg.n_experts), scale=0.02,
                             dtype=jnp.float32),
        "wi": dense_init(ks[1], (cfg.n_experts, d, fe), dtype=dtype),
        "wg": dense_init(ks[2], (cfg.n_experts, d, fe), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_experts, fe, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, fe * cfg.n_shared_experts, dtype)
    return p


def _moe_sorted_block(xt, topi, topv, p, E: int, k: int, D: int,
                      capacity_factor: float) -> jax.Array:
    """Capacity-bounded sort-based dispatch over ONE token block.
    Combine is gather-based (scatter-add onto the token tensor defeats
    SPMD — the output replicates and all-reduces)."""
    n = xt.shape[0]
    cap = int(n * k * capacity_factor / E) + 1
    cap = max(8, -(-cap // 8) * 8)                       # round up to 8
    e_flat = topi.reshape(-1)                            # (n·k,)
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[sorted_e]
    keep = rank < cap
    buf_idx = jnp.where(keep, sorted_e * cap + rank, E * cap)  # spill row
    tok_idx = order // k
    buf = jnp.zeros((E * cap + 1, D), xt.dtype)
    buf = buf.at[buf_idx].set(xt[tok_idx], mode="drop")
    eb = buf[: E * cap].reshape(E, cap, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", eb, p["wi"])
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * cap, D)
    inv = jnp.argsort(order)                             # (n·k,)
    slot_buf = jnp.take(buf_idx, inv)
    slot_keep = jnp.take(keep, inv)
    rows = jnp.take(y, jnp.minimum(slot_buf, E * cap - 1), axis=0)
    rows = jnp.where(slot_keep[:, None], rows.astype(jnp.float32), 0.0)
    return jnp.einsum("nkd,nk->nd", rows.reshape(n, k, D),
                      topv.astype(jnp.float32))


def _moe_sorted_block_ns(xt, topi, topv, p, E: int, k: int, D: int,
                         capacity_factor: float) -> jax.Array:
    """Scatter-free sorted dispatch (one token block).

    GSPMD replicates `scatter` ops with data-dependent indices — under
    vmap over DP-sharded groups the whole expert buffer ends up on every
    device. This formulation uses only sort_key_val / cumsum / gather,
    all of which GSPMD shards along batch dims:

      sort (expert_id, slot_id) → per-expert contiguous runs;
      buf[e, c] = x[token_of(run position starts[e] + c)]   (gather)
      combine: slot j reads y[buf_pos(j)]                    (gather)
    """
    n = xt.shape[0]
    cap = int(n * k * capacity_factor / E) + 1
    cap = max(8, -(-cap // 8) * 8)
    nk_ = n * k
    e_flat = topi.reshape(-1).astype(jnp.int32)           # (n·k,)
    slot = jnp.arange(nk_, dtype=jnp.int32)
    sorted_e, sorted_slot = lax.sort_key_val(e_flat, slot)
    counts = (jax.nn.one_hot(e_flat, E, dtype=jnp.int32)).sum(0)   # (E,)
    starts = jnp.cumsum(counts) - counts                  # (E,)
    # rank of each sorted position within its expert run
    rank = jnp.arange(nk_, dtype=jnp.int32) - starts[sorted_e]
    # token filling buffer cell (e, c): sorted position starts[e] + c
    pos = starts[:, None] + jnp.arange(cap, dtype=jnp.int32)[None]  # (E,cap)
    valid = jnp.arange(cap, dtype=jnp.int32)[None] < counts[:, None]
    pos = jnp.clip(pos, 0, nk_ - 1)
    tok_for_cell = jnp.take(sorted_slot, pos.reshape(-1)) // k      # (E·cap,)
    eb = jnp.take(xt, tok_for_cell, axis=0).reshape(E, cap, D)
    eb = jnp.where(valid.reshape(E, cap)[..., None], eb, 0)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", eb, p["wi"])
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * cap, D)
    # inverse: original slot j sits at sorted position inv[j]
    _, inv = lax.sort_key_val(sorted_slot,
                              jnp.arange(nk_, dtype=jnp.int32))
    rank_of_slot = jnp.take(rank, inv)                    # (n·k,)
    e_of_slot = e_flat
    keep = rank_of_slot < cap
    buf_pos = jnp.clip(e_of_slot * cap + rank_of_slot, 0, E * cap - 1)
    rows = jnp.take(y, buf_pos, axis=0)
    rows = jnp.where(keep[:, None], rows.astype(jnp.float32), 0.0)
    return jnp.einsum("nkd,nk->nd", rows.reshape(n, k, D),
                      topv.astype(jnp.float32))


def _moe_local_shardmap(p, xt, topi, topv, cfg, E, k, D,
                        capacity_factor) -> jax.Array:
    """Device-local MoE dispatch (DeepSpeed-style): a shard_map region
    over the DP axes keeps sort/scatter/combine local per data shard —
    GSPMD otherwise replicates the expert buffers (the global argsort is
    unpartitionable: measured 22 GB/layer of tuple all-reduce on
    mixtral×train_4k). Expert weights are ZeRO-gathered over DP
    explicitly (the cheap collective: ~300 MB/layer/device vs 22 GB);
    the 'model' axis stays auto so Fe keeps its TP sharding."""
    from jax.sharding import PartitionSpec as P
    from . import actsharding
    ctx = actsharding.mesh_ctx()
    n = xt.shape[0]
    if ctx is None:
        return _moe_sorted_block(xt, topi, topv, p, E, k, D,
                                 capacity_factor)
    mesh, dp = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dpn = 1
    for a in dp:
        dpn *= sizes[a]
    if n % dpn or n == dpn:
        return _moe_sorted_block(xt, topi, topv, p, E, k, D,
                                 capacity_factor)

    # dp-sharded axis of each weight leaf, from the same rule the
    # launcher sharded the stacked (L, ...) params with
    from repro.launch.sharding import leaf_spec

    def dp_spec(leaf):
        full = leaf_spec((1,) + leaf.shape, mesh)   # stacked-layout rule
        entries = list(full)[1:]
        return P(*[e if e in ("data", "pod") or isinstance(e, tuple)
                   else None for e in entries])

    w_specs = jax.tree.map(dp_spec, p)

    def local(w, xt_l, ti_l, tv_l):
        # ZeRO gather: undo the dp sharding of each weight leaf
        def gather(wl, spec):
            for ax, name in enumerate(spec):
                if name is None:
                    continue
                names = name if isinstance(name, tuple) else (name,)
                for nm in names:
                    wl = jax.lax.all_gather(wl, nm, axis=ax, tiled=True)
            return wl

        w = jax.tree.map(gather, w, w_specs,
                         is_leaf=lambda x: hasattr(x, "shape"))
        return _moe_sorted_block(xt_l, ti_l, tv_l, w, E, k, D,
                                 capacity_factor)

    from repro.core.compat import shard_map
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(w_specs, P(dp, None), P(dp, None), P(dp, None)),
        out_specs=P(dp, None),
        axis_names=set(dp),
        check_vma=False,
    )(p, xt, topi, topv)


def _moe_ep_block(xt, topi, topv, wi, wg, wo, ndev: int, E: int, k: int,
                  D: int, capacity_factor: float, a2a) -> jax.Array:
    """One device's expert-parallel dispatch (runs inside shard_map).

    Local tokens pack into the per-GLOBAL-expert capacity buffer (same
    sort-based pack as `_moe_sorted_block`), the buffer's per-owner
    chunks AllToAll to the expert owners, each owner runs its E/ndev
    local experts (weights `wi`/`wg`/`wo` are the LOCAL slices), and the
    outputs AllToAll back into the original buffer layout for the
    gather-based combine. `a2a` is the exchange callable — planned
    schedule or lax.all_to_all via `core.sync.ep_all_to_all`."""
    n = xt.shape[0]
    e_local = E // ndev
    cap = int(n * k * capacity_factor / E) + 1
    cap = max(8, -(-cap // 8) * 8)                       # round up to 8
    e_flat = topi.reshape(-1)                            # (n·k,)
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[sorted_e]
    keep = rank < cap
    buf_idx = jnp.where(keep, sorted_e * cap + rank, E * cap)  # spill row
    tok_idx = order // k
    buf = jnp.zeros((E * cap + 1, D), xt.dtype)
    buf = buf.at[buf_idx].set(xt[tok_idx], mode="drop")
    # owner-major: row j = my capacity rows for owner j's expert group
    send = buf[: E * cap].reshape(ndev, e_local * cap * D)
    recv = a2a(send)                  # row s = device s's rows for ME
    eb = recv.reshape(ndev, e_local, cap, D).transpose(1, 0, 2, 3) \
             .reshape(e_local, ndev * cap, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, wg)) * \
        jnp.einsum("ecd,edf->ecf", eb, wi)
    y = jnp.einsum("ecf,efd->ecd", h, wo)                # (e_local, n·cap, D)
    back = y.reshape(e_local, ndev, cap, D).transpose(1, 0, 2, 3) \
            .reshape(ndev, e_local * cap * D)
    got = a2a(back).reshape(E * cap, D)   # my buffer layout, expert outputs
    inv = jnp.argsort(order)
    slot_buf = jnp.take(buf_idx, inv)
    slot_keep = jnp.take(keep, inv)
    rows = jnp.take(got, jnp.minimum(slot_buf, E * cap - 1), axis=0)
    rows = jnp.where(slot_keep[:, None], rows.astype(jnp.float32), 0.0)
    return jnp.einsum("nkd,nk->nd", rows.reshape(n, k, D),
                      topv.astype(jnp.float32))


def _moe_ep(p, xt, topi, topv, cfg, E, k, D, capacity_factor) -> jax.Array:
    """Expert-parallel MoE dispatch (the planned-AllToAll path, ISSUE 9).

    Two entry contexts:
      * inside the manual trainer's shard_map — `core.sync.ep_context()`
        is set: params are the ZeRO-gathered FULL weights, so each device
        slices its expert group by axis index and exchanges over the
        context's axis (planned schedule when the context carries one);
      * under GSPMD jit — wraps a shard_map over the single live DP axis
        with the expert dim sharded in-spec.
    Falls back to the sorted/local paths when the expert count doesn't
    shard evenly or the mesh shape doesn't fit."""
    from repro.core import sync as _sync
    ep = _sync.ep_context()
    if ep is not None:
        if ep.size <= 1 or E % ep.size:
            return _moe_sorted_block(xt, topi, topv, p, E, k, D,
                                     capacity_factor)
        e_local = E // ep.size
        idx = lax.axis_index(ep.axis)

        def sl(w):
            return lax.dynamic_slice_in_dim(w, idx * e_local, e_local, 0)

        return _moe_ep_block(xt, topi, topv, sl(p["wi"]), sl(p["wg"]),
                             sl(p["wo"]), ep.size, E, k, D,
                             capacity_factor,
                             lambda v: _sync.ep_all_to_all(v, ep.axis))
    from jax.sharding import PartitionSpec as P
    from . import actsharding
    ctx = actsharding.mesh_ctx()
    n = xt.shape[0]
    if ctx is None:
        return _moe_sorted_block(xt, topi, topv, p, E, k, D,
                                 capacity_factor)
    mesh, dp = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    live = [a for a in dp if sizes[a] > 1]
    if len(live) != 1 or E % sizes[live[0]] or n % sizes[live[0]] \
            or n == sizes[live[0]]:
        return _moe_local_shardmap(p, xt, topi, topv, cfg, E, k, D,
                                   capacity_factor)
    axis, ndev = live[0], sizes[live[0]]

    def local(wi, wg, wo, xt_l, ti_l, tv_l):
        from repro.core import sync as _s
        return _moe_ep_block(xt_l, ti_l, tv_l, wi, wg, wo, ndev, E, k, D,
                             capacity_factor,
                             lambda v: _s.ep_all_to_all(v, axis))

    from repro.core.compat import shard_map
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis),
                  P(axis, None), P(axis, None), P(axis, None)),
        out_specs=P(axis, None),
        axis_names={axis},
        check_vma=False,
    )(p["wi"], p["wg"], p["wo"], xt, topi, topv)


def moe(p: Params, x: jax.Array, cfg: ModelConfig, *,
        dispatch: str = "sorted", capacity_factor: float = 1.25
        ) -> jax.Array:
    """x: (B, T, D). dispatch: "sorted" (capacity-bounded sort-based pack,
    FLOPs ≈ active-expert FLOPs × capacity factor), "dense" (computes all
    experts everywhere and masks — robust but E/top_k × wasteful; kept as
    the hillclimb baseline), or "ep" (expert-parallel: the sorted pack's
    capacity buffer exchanged owner-major over `sync.ep_all_to_all` so
    each device computes only its expert shard — DESIGN.md §14; falls
    back to the local sorted block when no EP context / mesh fits).

    cfg.moe_groups > 0 blocks the dispatch into G groups sorted
    independently (per-group capacity): a global argsort over the sharded
    token axis forces XLA to replicate the expert buffers on every device
    (measured: 22 GB of tuple all-reduce per mixtral layer); per-group
    sort keeps buffers DP-local."""
    B, T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * T, D)
    n = B * T
    logits = (xt.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, k)                     # (n, k)
    topv = topv / (topv.sum(-1, keepdims=True) + 1e-9)

    if dispatch == "dense":
        # gate (n, E) with only top-k nonzero
        gate = jnp.zeros((n, E), jnp.float32).at[
            jnp.arange(n)[:, None], topi].set(topv)
        h = jnp.einsum("nd,edf->nef", xt, p["wg"])
        h = jax.nn.silu(h) * jnp.einsum("nd,edf->nef", xt, p["wi"])
        y = jnp.einsum("nef,efd->ned", h, p["wo"])
        out = jnp.einsum("ned,ne->nd", y.astype(jnp.float32), gate)
    elif dispatch == "ep":
        out = _moe_ep(p, xt, topi, topv, cfg, E, k, D, capacity_factor)
    elif dispatch == "local" or (dispatch == "sorted" and cfg.moe_local):
        out = _moe_local_shardmap(p, xt, topi, topv, cfg, E, k, D,
                                  capacity_factor)
    elif cfg.moe_groups > 1 and n % cfg.moe_groups == 0:
        G = cfg.moe_groups
        ng = n // G
        # FSDP gather-before-use: re-shard the expert weights so the
        # contracted d axis is NOT 'data'-sharded — otherwise GSPMD picks
        # the partial-sum plan and all-reduces (E, cap, f) activations
        # (~22 GB/layer on mixtral) instead of gathering ~300 MB of
        # weights. The constraint makes the cheap plan the only plan.
        from .actsharding import mesh_ctx
        ctx = mesh_ctx()
        pw = p
        if ctx is not None:
            mesh, _dp = ctx
            from jax.sharding import NamedSharding, PartitionSpec as SP
            model = dict(zip(mesh.axis_names,
                             mesh.devices.shape)).get("model", 1)

            def unfsdp(w, f_axis):
                spec = [None] * w.ndim
                if model > 1 and w.shape[f_axis] % model == 0:
                    spec[f_axis] = "model"
                return jax.lax.with_sharding_constraint(
                    w, NamedSharding(mesh, SP(*spec)))

            pw = dict(p)
            pw["wi"] = unfsdp(p["wi"], 2)     # (E, D, Fe) — Fe on model
            pw["wg"] = unfsdp(p["wg"], 2)
            pw["wo"] = unfsdp(p["wo"], 1)     # (E, Fe, D) — Fe on model
        out = jax.vmap(
            lambda xg, ig, vg: _moe_sorted_block_ns(
                xg, ig, vg, pw, E, k, D, capacity_factor)
        )(xt.reshape(G, ng, D), topi.reshape(G, ng, k),
          topv.reshape(G, ng, k)).reshape(n, D)
    else:
        out = _moe_sorted_block(xt, topi, topv, p, E, k, D,
                                capacity_factor)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xt).astype(jnp.float32)
    return out.astype(x.dtype).reshape(B, T, D)
