"""Unified decoder-only transformer covering the dense / moe / vlm families.

Layers are *stacked*: every parameter leaf carries a leading (L,) axis and
the forward pass is one `lax.scan` over layers (fast lowering at 64 layers,
uniform sharding). Per-layer heterogeneity (sliding windows in gemma-2/3,
hymba's global layers) rides along as an (L,) int array scanned with the
params, using the masked-window attention path.

Three entry points per model:
  * ``forward``       — full-sequence logits (training / prefill math)
  * ``prefill``       — forward + returns the populated KV cache
  * ``decode_step``   — one new token against a KV cache

The KV cache layout is (L, B, Hkv, S, hd) so the sequence axis is shardable
for long contexts and the layer axis matches the scanned params.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .actsharding import constrain
from .config import ModelConfig
from .layers import (Params, attention, attention_decode, dense_init,
                     init_attention, init_mlp, init_moe, mlp, moe, rmsnorm)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """Stacked-layer parameter pytree."""
    L = cfg.n_layers
    keys = jax.random.split(key, L + 2)

    def layer(k) -> Params:
        ks = jax.random.split(k, 4)
        p: Params = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(ks[0], cfg, dtype),
        }
        if cfg.n_experts:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        return p

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[layer(keys[i]) for i in range(L)])
    p: Params = {
        "layers": stacked,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "embed": dense_init(keys[L], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[L + 1], (cfg.d_model, cfg.vocab),
                                  dtype=dtype)
    return p


def window_array(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.array([cfg.window_for_layer(i) for i in range(cfg.n_layers)],
                     jnp.int32)


def _uniform_windows(cfg: ModelConfig) -> bool:
    ws = {cfg.window_for_layer(i) for i in range(cfg.n_layers)}
    return len(ws) == 1


def _grouped_layer_scan(layers: Params, cfg: ModelConfig, x, group_fn,
                        remat: bool = True):
    """Scan over pattern-period groups of layers (static windows inside);
    leftover layers (L % period) run unrolled at the end."""
    L, period = cfg.n_layers, len(cfg.window_pattern)
    full = (L // period) * period

    if full:
        grouped = jax.tree.map(
            lambda a: a[:full].reshape((full // period, period)
                                       + a.shape[1:]), layers)

        def body(x, lp_group):
            return group_fn(x, lp_group, range(period)), None

        blk = jax.checkpoint(body) if remat else body
        x, _ = lax.scan(blk, x, grouped)
    if full < L:
        tail = jax.tree.map(lambda a: a[full:], layers)
        fn = (jax.checkpoint(lambda x, t: group_fn(x, t, range(L - full)))
              if remat else (lambda x, t: group_fn(x, t, range(L - full))))
        x = fn(x, tail)
    return x


# ---------------------------------------------------------------------------
# forward (train / prefill math)
# ---------------------------------------------------------------------------
def _block(cfg: ModelConfig, lp: Params, x, window, positions,
           mrope_positions, moe_dispatch: str):
    h = attention(lp["attn"], rmsnorm(x, lp["ln1"]), cfg, window=window,
                  positions=positions, mrope_positions=mrope_positions)
    x = x + h
    z = rmsnorm(x, lp["ln2"])
    if cfg.n_experts:
        f = moe(lp["moe"], z, cfg, dispatch=moe_dispatch)
    else:
        f = mlp(lp["mlp"], z)
    return x + f


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array | None, *,
            embeds: jax.Array | None = None,
            positions: jax.Array | None = None,
            mrope_positions: jax.Array | None = None,
            moe_dispatch: str = "sorted",
            remat: bool = True) -> jax.Array:
    """tokens (B, T) int32 or embeds (B, T, D) → logits (B, T, V)."""
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.family == "dense" and cfg.tie_embeddings:
            x = x * (cfg.d_model ** 0.5)
    else:
        x = embeds
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]

    wins = window_array(cfg)
    static_win = cfg.window_for_layer(0) if _uniform_windows(cfg) else None

    if static_win is None:
        # Heterogeneous windows: scan over pattern-period layer GROUPS so
        # every sub-layer gets a STATIC window — the banded O(T·W)
        # attention path applies to local layers. A traced per-layer
        # window forces the masked O(T²) path for the whole stack
        # (EXPERIMENTS.md §Perf iter 10: gemma2 prefill 178 s → banded).
        def group_fn(x, lp_group, js):
            for j in js:
                lpj = jax.tree.map(lambda a, j=j: a[j], lp_group)
                x = constrain(_block(cfg, lpj, x, cfg.window_for_layer(j),
                                     positions, mrope_positions,
                                     moe_dispatch))
            return x

        x = _grouped_layer_scan(params["layers"], cfg, x, group_fn,
                                remat=remat)
    else:
        def body(x, inp):
            lp, _w = inp
            return constrain(_block(cfg, lp, x, static_win, positions,
                                    mrope_positions, moe_dispatch)), None

        blk = jax.checkpoint(body) if remat else body
        x, _ = lax.scan(blk, x, (params["layers"], wins))
    x = rmsnorm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.final_softcap > 0:
        logits = (jnp.tanh(logits.astype(jnp.float32) / cfg.final_softcap)
                  * cfg.final_softcap).astype(logits.dtype)
    return logits


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, **kw) -> jax.Array:
    logits = forward(params, cfg, batch.get("tokens"),
                     embeds=batch.get("embeds"),
                     mrope_positions=batch.get("mrope_positions"), **kw)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> dict:
    """Sliding-window layers only need min(window, seq) cache slots; the
    cache is allocated at the max over layers so the scanned layout stays
    rectangular (per-layer ragged caches don't scan)."""
    hd = cfg.head_dim
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, seq, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array | None, *,
            cache_len: int, embeds: jax.Array | None = None,
            mrope_positions: jax.Array | None = None,
            moe_dispatch: str = "sorted") -> tuple[jax.Array, dict]:
    """Forward over the prompt, recording K/V into a fresh cache of
    `cache_len` slots. Returns (last-token logits, cache)."""
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds
    B, T, _ = x.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    wins = window_array(cfg)
    static_win = cfg.window_for_layer(0) if _uniform_windows(cfg) else None
    hd = cfg.head_dim

    def one_layer(x, lp, win):
        z = rmsnorm(x, lp["ln1"])
        # recompute K/V for the cache (attention() also derives them; the
        # double projection is optimized away by CSE)
        from .layers import _qkv
        _, k, v, = _qkv(lp["attn"], z, cfg, positions, mrope_positions)
        h = attention(lp["attn"], z, cfg, window=win, positions=positions,
                      mrope_positions=mrope_positions)
        x = x + h
        zz = rmsnorm(x, lp["ln2"])
        f = moe(lp["moe"], zz, cfg, dispatch=moe_dispatch) if cfg.n_experts \
            else mlp(lp["mlp"], zz)
        return constrain(x + f), k, v

    if static_win is None:
        # pattern-period grouping: static window per sub-layer (see forward)
        def group_fn(x, lp_group, js):
            ks_, vs_ = [], []
            for j in js:
                lpj = jax.tree.map(lambda a, j=j: a[j], lp_group)
                x, k, v = one_layer(x, lpj, cfg.window_for_layer(j))
                ks_.append(k)
                vs_.append(v)
            return x, (jnp.stack(ks_), jnp.stack(vs_))

        L, period = cfg.n_layers, len(cfg.window_pattern)
        full = (L // period) * period
        parts_k, parts_v = [], []
        if full:
            grouped = jax.tree.map(
                lambda a: a[:full].reshape((full // period, period)
                                           + a.shape[1:]),
                params["layers"])

            def body2(x, lp_group):
                x, kv = group_fn(x, lp_group, range(period))
                return x, kv

            x, (gk, gv) = lax.scan(jax.checkpoint(body2), x, grouped)
            parts_k.append(gk.reshape((full,) + gk.shape[2:]))
            parts_v.append(gv.reshape((full,) + gv.shape[2:]))
        if full < L:
            tail = jax.tree.map(lambda a: a[full:], params["layers"])
            x, (tk, tv) = jax.checkpoint(
                lambda x, t: group_fn(x, t, range(L - full)))(x, tail)
            parts_k.append(tk)
            parts_v.append(tv)
        ks = jnp.concatenate(parts_k, axis=0)
        vs = jnp.concatenate(parts_v, axis=0)
    else:
        def body(x, inp):
            lp, _w = inp
            x, k, v = one_layer(x, lp, static_win)
            return x, (k, v)

        x, (ks, vs) = lax.scan(jax.checkpoint(body), x,
                               (params["layers"], wins))
    x = rmsnorm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x[:, -1:] @ head
    if cfg.final_softcap > 0:
        logits = (jnp.tanh(logits.astype(jnp.float32) / cfg.final_softcap)
                  * cfg.final_softcap).astype(logits.dtype)
    cache = init_cache(cfg, B, cache_len, ks.dtype)
    cache["k"] = lax.dynamic_update_slice(
        cache["k"], ks, (0, 0, 0, 0, 0))
    cache["v"] = lax.dynamic_update_slice(
        cache["v"], vs, (0, 0, 0, 0, 0))
    cache["pos"] = jnp.full((B,), T, jnp.int32)
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: dict,
                tokens: jax.Array | None, *,
                embeds: jax.Array | None = None,
                mrope_positions: jax.Array | None = None,
                moe_dispatch: str = "sorted") -> tuple[jax.Array, dict]:
    """One decode step. tokens: (B, 1) (or embeds (B, 1, D)).
    Returns (logits (B, 1, V), updated cache)."""
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds
    B = x.shape[0]
    pos = cache["pos"]
    wins = window_array(cfg)
    static_win = cfg.window_for_layer(0) if _uniform_windows(cfg) else None

    def body(x, inp):
        lp, w, ck, cv = inp
        win = static_win if static_win is not None else w
        z = rmsnorm(x, lp["ln1"])
        h, nk, nv = attention_decode(lp["attn"], z, ck, cv, pos, cfg,
                                     window=win,
                                     mrope_positions=mrope_positions)
        x = x + h
        zz = rmsnorm(x, lp["ln2"])
        f = moe(lp["moe"], zz, cfg, dispatch=moe_dispatch) if cfg.n_experts \
            else mlp(lp["mlp"], zz)
        return constrain(x + f), (nk, nv)

    x, (nks, nvs) = lax.scan(body, x, (params["layers"], wins,
                                       cache["k"], cache["v"]))
    x = rmsnorm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.final_softcap > 0:
        logits = (jnp.tanh(logits.astype(jnp.float32) / cfg.final_softcap)
                  * cfg.final_softcap).astype(logits.dtype)
    new_cache = {"k": nks, "v": nvs, "pos": pos + 1}
    return logits, new_cache
