"""RWKV6 (Finch) language model — attention-free, recurrent state.

State per layer: the (B, H, K, V) wkv matrix plus the 1-token shift buffers
for time-mix and channel-mix. Decode carries state instead of a KV cache —
O(1) per token regardless of context length, which is why the `long_500k`
shape runs for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .actsharding import constrain
from .layers import Params, dense_init, rmsnorm
from .recurrence import (init_rwkv, rwkv_channel_mix, rwkv_time_mix,
                         _token_shift)


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    L = cfg.n_layers
    keys = jax.random.split(key, L + 2)

    def layer(k):
        p = init_rwkv(k, cfg, dtype)
        p["ln1"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        return p

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[layer(keys[i]) for i in range(L)])
    return {
        "layers": stacked,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "embed": dense_init(keys[L], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dtype),
        "lm_head": dense_init(keys[L + 1], (cfg.d_model, cfg.vocab),
                              dtype=dtype),
    }


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    hd = cfg.head_dim
    return {
        "wkv": jnp.zeros((cfg.n_layers, batch, cfg.n_heads, hd, hd),
                         jnp.float32),
        "tm_shift": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
        "cm_shift": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
    }


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            state: dict | None = None, chunk: int = 32,
            remat: bool = True, **_kw) -> tuple[jax.Array, dict]:
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, _ = x.shape
    # shift buffers carry x's dtype: storing them narrower than the
    # activations breaks prefill→decode consistency
    st = state or init_state(cfg, B, dtype=x.dtype)

    def body(x, inp):
        lp, s_wkv, tm_prev, cm_prev = inp
        z = rmsnorm(x, lp["ln1"])
        h, s_new = rwkv_time_mix(lp, z, cfg, state=s_wkv, chunk=chunk,
                                 shift_prev=tm_prev.astype(z.dtype))
        x = x + h
        z2 = rmsnorm(x, lp["ln2"])
        x = constrain(x + rwkv_channel_mix(
            lp, z2, shift_prev=cm_prev.astype(z2.dtype)))
        return x, (s_new, z[:, -1:].astype(tm_prev.dtype),
                   z2[:, -1:].astype(cm_prev.dtype))

    blk = jax.checkpoint(body) if remat else body
    x, (wkv, tms, cms) = lax.scan(
        blk, x, (params["layers"], st["wkv"], st["tm_shift"],
                 st["cm_shift"]))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["lm_head"]
    return logits, {"wkv": wkv, "tm_shift": tms, "cm_shift": cms}


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, **kw) -> jax.Array:
    logits, _ = forward(params, cfg, batch["tokens"], **kw)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            cache_len: int = 0, **kw) -> tuple[jax.Array, dict]:
    logits, state = forward(params, cfg, tokens, remat=False, **kw)
    return logits[:, -1:], state


def decode_step(params: Params, cfg: ModelConfig, state: dict,
                tokens: jax.Array, **kw) -> tuple[jax.Array, dict]:
    """One token: T=1 forward threading the recurrent state (chunk=1)."""
    return forward(params, cfg, tokens, state=state, chunk=1, remat=False)
