"""Model + shape configuration schema for all assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 → d_model // n_heads
    # --- attention features ---
    qk_norm: bool = False
    attn_softcap: float = 0.0        # gemma2 logit softcap
    final_softcap: float = 0.0       # gemma2 final-logit softcap
    window_pattern: tuple[int, ...] = (0,)   # per-layer sliding windows,
    #                                          cycled over layers; 0 = global
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] | None = None   # qwen2-vl (t, h, w)
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2              # mamba inner expansion
    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    encoder_bidir: bool = True
    # --- embedding frontend stub (vlm/audio) ---
    embeds_input: bool = False       # forward takes embeddings, not token ids
    tie_embeddings: bool = False
    # --- perf knobs (hillclimbed in EXPERIMENTS.md §Perf) ---
    attn_kv_block: int = 0           # >0: online-softmax scan over KV blocks
    #                                  (flash in XLA — bounds materialized
    #                                  logits to block_q × attn_kv_block)
    moe_groups: int = 0              # >0: block the MoE dispatch into G
    #                                  DP-local groups (per-group argsort +
    #                                  capacity) so the expert buffers shard
    #                                  instead of replicating
    moe_local: bool = False          # shard_map the dispatch over the DP
    #                                  axes (device-local sort + explicit
    #                                  ZeRO weight gather)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def window_for_layer(self, i: int) -> int:
        return self.window_pattern[i % len(self.window_pattern)]

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def params_count(self) -> int:
        """Total parameter count (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        if self.family == "ssm":
            # rwkv6: time-mix r/k/v/g/out (5 d²) + decay LoRA + channel mix
            per_layer = 5 * d * d + 2 * d * 64 + (2 * d * f + d * d)
        elif self.n_experts:
            shared = self.n_shared_experts * 3 * d * self.d_ff_expert
            routed = self.n_experts * 3 * d * self.d_ff_expert
            per_layer = attn + shared + routed + d * self.n_experts
        else:
            per_layer = attn + 3 * d * f
        if self.family == "hybrid":
            di = self.ssm_expand * d
            per_layer += 2 * d * di + di * d + di * self.ssm_state * 2 + di
        n = self.n_layers * per_layer
        if self.is_encdec:
            enc_attn = 4 * d * d
            n += self.n_encoder_layers * (enc_attn + 2 * d * f)
            n += self.n_layers * attn                 # cross attention
        n += v * d * (1 if self.tie_embeddings else 2)
        return n

    def active_params_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if not self.n_experts:
            return self.params_count()
        d = self.d_model
        dense_moe = self.n_experts * 3 * d * self.d_ff_expert
        active_moe = (self.top_k) * 3 * d * self.d_ff_expert
        return self.params_count() - self.n_layers * (dense_moe - active_moe)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, cfg.n_kv_heads * 4 // cfg.n_heads),
        d_head=16,
        d_ff=128,
        vocab=512,
        d_ff_expert=32 if cfg.n_experts else 0,
        n_experts=min(cfg.n_experts, 8),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        window_pattern=tuple(min(w, 32) if w else 0
                             for w in cfg.window_pattern),
        mrope_sections=(8, 4, 4) if cfg.mrope_sections else None,
    )
