"""Activation-sharding constraints.

XLA SPMD propagation can drop the batch sharding of activations inside
scan-over-layers bodies (observed: hymba's 25-head attention replicating
the global batch on every device — a 16× HBM/FLOP inflation). Production
frameworks pin activations explicitly; model code calls `constrain(x)`
at block boundaries and the launcher installs a mesh-aware hook.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

_HOOK: Optional[Callable] = None
_MESH = None          # mesh for layer-level shard_map regions (MoE)


def set_hook(fn: Optional[Callable], mesh=None) -> None:
    global _HOOK, _MESH
    _HOOK = fn
    _MESH = mesh


def mesh_ctx():
    """(mesh, dp_axes) for layer-level shard_map regions, or None."""
    if _MESH is None:
        return None
    dp = tuple(a for a in _MESH.axis_names if a != "model")
    return _MESH, dp


def constrain(x: jax.Array) -> jax.Array:
    """Apply the installed activation constraint (identity by default)."""
    if _HOOK is None:
        return x
    return _HOOK(x)


def batch_dp_hook(mesh) -> Callable:
    """Constrain axis 0 (batch) of (B, T, D) activations to the DP axes,
    leaving the rest to the partitioner."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a != "model")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dpn = 1
    for a in dp:
        dpn *= sizes[a]

    def hook(x):
        if x.ndim >= 2 and x.shape[0] % dpn == 0 and x.shape[0] > 1:
            spec = P(dp, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return x

    return hook
