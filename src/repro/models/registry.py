"""Uniform model API over all families + per-cell input specs.

`build(cfg)` returns a ModelAPI exposing init / loss / prefill / decode and
`input_specs(shape)` — ShapeDtypeStruct stand-ins for every input of the
step that the (arch × shape) cell lowers (train_step for train shapes,
prefill for prefill shapes, decode_step for decode shapes). No allocation:
cache/state specs come from jax.eval_shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .config import ModelConfig, ShapeConfig
from . import encdec, hybrid_model, rwkv_model, transformer
from .encdec import N_AUDIO_FRAMES

Params = Any


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable            # (params, batch) -> scalar
    forward: Callable
    prefill: Callable            # (params, batch, cache_len) -> (logits, cache)
    decode_step: Callable        # (params, cache, batch) -> (logits, cache)
    init_cache: Callable         # (batch, seq) -> cache pytree

    # -- spec helpers --------------------------------------------------------
    def train_specs(self, shape: ShapeConfig) -> dict:
        B, T = shape.global_batch, shape.seq_len
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        batch = {"labels": sds((B, T), jnp.int32)}
        if cfg.family == "vlm":
            batch["embeds"] = sds((B, T, cfg.d_model), jnp.bfloat16)
            batch["mrope_positions"] = sds((3, B, T), jnp.int32)
        elif cfg.family == "audio":
            batch["frames"] = sds((B, N_AUDIO_FRAMES, cfg.d_model),
                                  jnp.bfloat16)
            batch["tokens"] = sds((B, T), jnp.int32)
        else:
            batch["tokens"] = sds((B, T), jnp.int32)
        return batch

    def prefill_specs(self, shape: ShapeConfig) -> dict:
        B, T = shape.global_batch, shape.seq_len
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        batch = {}
        if cfg.family == "vlm":
            batch["embeds"] = sds((B, T, cfg.d_model), jnp.bfloat16)
            batch["mrope_positions"] = sds((3, B, T), jnp.int32)
        elif cfg.family == "audio":
            batch["frames"] = sds((B, N_AUDIO_FRAMES, cfg.d_model),
                                  jnp.bfloat16)
            batch["tokens"] = sds((B, T), jnp.int32)
        else:
            batch["tokens"] = sds((B, T), jnp.int32)
        return batch

    def decode_specs(self, shape: ShapeConfig) -> dict:
        """{tokens/embeds: (B, 1, ...), cache: <family cache at seq_len>}."""
        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        batch: dict = {}
        if cfg.family == "vlm":
            batch["embeds"] = sds((B, 1, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = sds((B, 1), jnp.int32)
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"batch": batch, "cache": cache}

    def params_spec(self):
        return jax.eval_shape(
            lambda: self.init_params(jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
def _dense_api(cfg: ModelConfig) -> ModelAPI:
    def prefill(params, batch, cache_len, **kw):
        return transformer.prefill(
            params, cfg, batch.get("tokens"), cache_len=cache_len,
            embeds=batch.get("embeds"),
            mrope_positions=batch.get("mrope_positions"), **kw)

    def decode(params, cache, batch, **kw):
        return transformer.decode_step(
            params, cfg, cache, batch.get("tokens"),
            embeds=batch.get("embeds"), **kw)

    return ModelAPI(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.bfloat16: transformer.init_params(
            key, cfg, dtype),
        loss_fn=lambda p, b, **kw: transformer.loss_fn(p, cfg, b, **kw),
        forward=lambda p, b, **kw: transformer.forward(
            p, cfg, b.get("tokens"), embeds=b.get("embeds"),
            mrope_positions=b.get("mrope_positions"), **kw),
        prefill=prefill,
        decode_step=decode,
        init_cache=lambda b, s: transformer.init_cache(cfg, b, s),
    )


def _rwkv_api(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.bfloat16: rwkv_model.init_params(
            key, cfg, dtype),
        loss_fn=lambda p, b, **kw: rwkv_model.loss_fn(p, cfg, b, **kw),
        forward=lambda p, b, **kw: rwkv_model.forward(
            p, cfg, b["tokens"], **kw)[0],
        prefill=lambda p, b, cache_len: rwkv_model.prefill(
            p, cfg, b["tokens"], cache_len=cache_len),
        decode_step=lambda p, c, b: rwkv_model.decode_step(
            p, cfg, c, b["tokens"]),
        # the recurrent state is seq-length independent
        init_cache=lambda b, s: rwkv_model.init_state(cfg, b),
    )


def _hybrid_api(cfg: ModelConfig) -> ModelAPI:
    return ModelAPI(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.bfloat16: hybrid_model.init_params(
            key, cfg, dtype),
        loss_fn=lambda p, b, **kw: hybrid_model.loss_fn(p, cfg, b, **kw),
        forward=lambda p, b, **kw: hybrid_model.forward(
            p, cfg, b["tokens"], **kw),
        prefill=lambda p, b, cache_len: hybrid_model.prefill(
            p, cfg, b["tokens"], cache_len=cache_len),
        decode_step=lambda p, c, b: hybrid_model.decode_step(
            p, cfg, c, b["tokens"]),
        init_cache=lambda b, s: hybrid_model.init_cache(cfg, b, s),
    )


def _encdec_api(cfg: ModelConfig) -> ModelAPI:
    def prefill(params, batch, cache_len):
        return encdec.prefill(params, cfg, batch["tokens"],
                              frames=batch["frames"], cache_len=cache_len)

    return ModelAPI(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.bfloat16: encdec.init_params(
            key, cfg, dtype),
        loss_fn=lambda p, b, **kw: encdec.loss_fn(p, cfg, b, **kw),
        forward=lambda p, b, **kw: encdec.forward(
            p, cfg, b["tokens"], frames=b["frames"], **kw),
        prefill=prefill,
        decode_step=lambda p, c, b: encdec.decode_step(
            p, cfg, c, b["tokens"]),
        init_cache=lambda b, s: encdec.init_cache(cfg, b, s),
    )


def build(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "ssm":
        return _rwkv_api(cfg)
    if cfg.family == "hybrid":
        return _hybrid_api(cfg)
    if cfg.family == "audio":
        return _encdec_api(cfg)
    # dense / moe / vlm share the unified decoder stack
    return _dense_api(cfg)


def build_by_name(name: str) -> ModelAPI:
    from repro.configs import get_config
    return build(get_config(name))
