"""Whisper-style encoder-decoder. The conv/mel frontend is a STUB —
``input_specs`` provides precomputed frame embeddings (B, T_audio, D); the
backbone (bidirectional encoder, causal decoder with cross-attention) is
implemented in full.

Decode state = self-attention KV cache + the (static) cross-attention K/V
computed once from the encoder output at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .actsharding import constrain
from .config import ModelConfig
from .layers import (Params, _qkv, attention, attention_decode, dense_init,
                     init_attention, init_mlp, mlp, rmsnorm)

N_AUDIO_FRAMES = 1500   # whisper: 30 s of audio → 1500 frames post-conv


def _init_cross(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dtype),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    keys = jax.random.split(key, cfg.n_encoder_layers + cfg.n_layers + 3)

    def enc_layer(k):
        ks = jax.random.split(k, 2)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_layer(k):
        ks = jax.random.split(k, 3)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln_x": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(ks[0], cfg, dtype),
            "xattn": _init_cross(ks[1], cfg, dtype),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype),
        }

    enc = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[enc_layer(keys[i])
                         for i in range(cfg.n_encoder_layers)])
    dec = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[dec_layer(keys[cfg.n_encoder_layers + i])
          for i in range(cfg.n_layers)])
    i0 = cfg.n_encoder_layers + cfg.n_layers
    return {
        "encoder": enc,
        "decoder": dec,
        "ln_enc": jnp.zeros((cfg.d_model,), dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "embed": dense_init(keys[i0], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dtype),
        "lm_head": dense_init(keys[i0 + 1], (cfg.d_model, cfg.vocab),
                              dtype=dtype),
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           remat: bool = True) -> jax.Array:
    """frames: (B, T_audio, D) stub embeddings → encoder states."""
    x = frames
    B, T, _ = x.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]

    def body(x, lp):
        h = attention(lp["attn"], rmsnorm(x, lp["ln1"]), cfg, causal=False,
                      positions=positions)
        x = x + h
        return constrain(x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"]))), None

    blk = jax.checkpoint(body) if remat else body
    x, _ = lax.scan(blk, x, params["encoder"])
    return rmsnorm(x, params["ln_enc"])


def _cross_attend(xp: Params, z: jax.Array, xk: jax.Array, xv: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """z: (B, T, D) queries; xk/xv: (B, Hkv, Te, hd) precomputed."""
    B, T, _ = z.shape
    hd = cfg.head_dim
    q = (z @ xp["wq"]).reshape(B, T, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(xk, rep, axis=1) if rep > 1 else xk
    v = jnp.repeat(xv, rep, axis=1) if rep > 1 else xv
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    o = o.astype(z.dtype).transpose(0, 2, 1, 3).reshape(B, T, -1)
    return o @ xp["wo"]


def _cross_kv(xp: Params, enc: jax.Array, cfg: ModelConfig):
    B, Te, _ = enc.shape
    hd = cfg.head_dim
    k = (enc @ xp["wk"]).reshape(B, Te, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (enc @ xp["wv"]).reshape(B, Te, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return k, v


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            frames: jax.Array, remat: bool = True, **_kw) -> jax.Array:
    """Teacher-forced training forward: audio frames + decoder tokens."""
    enc = encode(params, cfg, frames, remat=remat)
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, _ = x.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]

    def body(x, lp):
        h = attention(lp["attn"], rmsnorm(x, lp["ln1"]), cfg,
                      positions=positions)
        x = x + h
        xk, xv = _cross_kv(lp["xattn"], enc, cfg)
        x = x + _cross_attend(lp["xattn"], rmsnorm(x, lp["ln_x"]), xk, xv, cfg)
        return constrain(x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"]))), None

    blk = jax.checkpoint(body) if remat else body
    x, _ = lax.scan(blk, x, params["decoder"])
    x = rmsnorm(x, params["ln_f"])
    return x @ params["lm_head"]


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, **kw) -> jax.Array:
    logits = forward(params, cfg, batch["tokens"], frames=batch["frames"],
                     **kw)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.bfloat16) -> dict:
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, seq, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, seq, hd), dtype),
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads,
                         N_AUDIO_FRAMES, hd), dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads,
                         N_AUDIO_FRAMES, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            frames: jax.Array, cache_len: int, **_kw
            ) -> tuple[jax.Array, dict]:
    enc = encode(params, cfg, frames, remat=True)
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, _ = x.shape
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]

    def body(x, lp):
        z = rmsnorm(x, lp["ln1"])
        _, k, v = _qkv(lp["attn"], z, cfg, positions, None)
        x = x + attention(lp["attn"], z, cfg, positions=positions)
        xk, xv = _cross_kv(lp["xattn"], enc, cfg)
        x = x + _cross_attend(lp["xattn"], rmsnorm(x, lp["ln_x"]), xk, xv, cfg)
        x = constrain(x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"])))
        return x, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = lax.scan(jax.checkpoint(body), x,
                                     params["decoder"])
    x = rmsnorm(x, params["ln_f"])
    logits = x[:, -1:] @ params["lm_head"]
    cache = init_cache(cfg, B, cache_len, ks.dtype)
    cache["k"] = lax.dynamic_update_slice(cache["k"], ks, (0, 0, 0, 0, 0))
    cache["v"] = lax.dynamic_update_slice(cache["v"], vs, (0, 0, 0, 0, 0))
    cache["xk"], cache["xv"] = xks, xvs
    cache["pos"] = jnp.full((B,), T, jnp.int32)
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: dict,
                tokens: jax.Array, **_kw) -> tuple[jax.Array, dict]:
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["pos"]

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        z = rmsnorm(x, lp["ln1"])
        h, nk, nv = attention_decode(lp["attn"], z, ck, cv, pos, cfg)
        x = x + h
        x = x + _cross_attend(lp["xattn"], rmsnorm(x, lp["ln_x"]), xk, xv,
                              cfg)
        x = constrain(x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"])))
        return x, (nk, nv)

    x, (nks, nvs) = lax.scan(body, x, (params["decoder"], cache["k"],
                                       cache["v"], cache["xk"],
                                       cache["xv"]))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["lm_head"]
    return logits, {"k": nks, "v": nvs, "xk": cache["xk"],
                    "xv": cache["xv"], "pos": pos + 1}
