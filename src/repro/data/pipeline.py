"""Deterministic synthetic token pipeline with host-side prefetch.

Stateless-resumable: batch at step k is a pure function of (seed, k), so a
job restarted from a step-k checkpoint regenerates the identical stream —
no data-loader state needs checkpointing (runtime/ft relies on this).

The generator is a Zipf-ish unigram sampler with a Markov flavour (next
token mixes a shifted copy of the current one) so the loss actually falls
during the example training runs — pure-uniform tokens would pin loss at
ln(V).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    markov_mix: float = 0.65     # P(next = f(cur)) — learnable structure
    embed_dim: int = 0           # vlm/audio stub embedding width
    frames: int = 0              # audio stub frame count


class SyntheticLM:
    """Batch factory: `batch_at(step)` is pure in (cfg.seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** -cfg.zipf_a
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        B, T = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(B, T + 1), p=self._probs)
        # markov structure: with prob markov_mix, next = (cur*7+3) % V —
        # applied sequentially so the chain composes (label_t really is
        # f(final token_t) wherever the coin lands heads)
        take = rng.random((B, T)) < cfg.markov_mix
        for t in range(T):
            follow = (toks[:, t] * 7 + 3) % cfg.vocab
            toks[:, t + 1] = np.where(take[:, t], follow, toks[:, t + 1])
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.embed_dim:        # vlm stub: embeddings instead of tokens
            out["embeds"] = rng.standard_normal(
                (B, T, cfg.embed_dim)).astype(np.float32) * 0.02
            out["mrope_positions"] = np.broadcast_to(
                np.arange(T, dtype=np.int32), (3, B, T)).copy()
            del out["tokens"]
        if cfg.frames:           # audio stub: frame embeddings
            out["frames"] = rng.standard_normal(
                (B, cfg.frames, cfg.embed_dim)).astype(np.float32) * 0.02
        return out


def make_pipeline(cfg: DataConfig, start_step: int = 0,
                  prefetch: int = 2) -> Iterator[dict]:
    """Background-thread prefetched iterator starting at `start_step`."""
    src = SyntheticLM(cfg)
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(src.batch_at(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()
