"""Runtime telemetry — the shared measurement datapath (DESIGN.md §10).

Every real timing the system produces at runtime used to be thrown away
or trapped in an ad-hoc EWMA (`StragglerWatchdog`). This module is the
one place they all land, so straggler detection, drift detection and
online recalibration consume a single datapath:

  * `TimingRing`   — fixed-capacity ring buffer of samples with streaming
    statistics: count/mean/EWMA are O(1) per `add`, percentiles are
    computed over the retained window on demand. The EWMA uses the same
    half-life decay the old watchdog did, so `StragglerWatchdog` routes
    through a ring without changing its `observe(step, dt) -> bool`
    contract.
  * `ResidualTracker` — predicted-vs-measured relative residuals, keyed
    by plan fingerprint or level class. `drift()` (median |residual|) is
    what `PlannerService`'s refit policy watches; `bias()` keeps the
    sign so a systematically slow cluster is distinguishable from noise.
  * `ArrivalEstimator` — per-device arrival-offset rings. Feed it the
    per-device arrival times of each collective (or step barrier) and it
    maintains median offsets relative to the earliest arrival — the
    measured process-arrival pattern `SkewModel(dist="empirical")`
    prices instead of synthetic draws.
  * `Telemetry`    — the facade: create-on-demand rings and trackers,
    per-level calibration samples for the online refit
    (`planner.calibrate.TelemetryProvider`), and re-measure windows:
    after a straggler / remesh / fault-tolerant resume the pre-event
    residuals and arrival offsets describe hardware that no longer
    exists, so `remeasure()` drops them (raw timing rings survive for
    trend display) and logs the event.

Thread-safe: the training loop, the serving self-check and the planner
service may observe concurrently. The hot path (`Telemetry.record`,
`TimingRing.add`) is a dict probe plus O(1) arithmetic — gated under 1%
of a simulated step by `benchmarks/telemetry_bench.py`.
"""
from __future__ import annotations

import bisect
import math
import threading
from collections import deque
from dataclasses import dataclass, field


class TimingRing:
    """Fixed-capacity ring of float samples with streaming statistics.

    `add` is O(1): it updates count, running sum (of the retained
    window), and — unless the caller excludes the sample from the
    baseline — the half-life EWMA. Percentiles sort the retained window
    on demand (O(W log W), W = capacity), which is cheap at the default
    capacity and keeps the hot path allocation-free. A per-ring lock
    guards the compound buffer/sum/EWMA update — concurrent observers
    (training loop, serve self-check, watchdog) share these rings.
    """

    __slots__ = ("capacity", "halflife", "_buf", "_next", "_count",
                 "_sum", "_ewma", "_total", "_lock")

    def __init__(self, capacity: int = 256, halflife: int = 20):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.halflife = int(halflife)
        self._buf: list[float] = [0.0] * self.capacity
        self._next = 0          # next write position
        self._count = 0         # retained samples (<= capacity)
        self._sum = 0.0         # sum of retained samples
        self._ewma: float | None = None
        self._total = 0         # lifetime samples (survives wraparound)
        self._lock = threading.Lock()

    def add(self, value: float, *, baseline: bool = True) -> None:
        """Record a sample. `baseline=False` keeps it out of the EWMA
        (a straggler step must not poison the straggler baseline) while
        still retaining it in the window for percentiles/means."""
        value = float(value)
        with self._lock:
            if self._count == self.capacity:
                self._sum -= self._buf[self._next]
            else:
                self._count += 1
            self._buf[self._next] = value
            self._next = (self._next + 1) % self.capacity
            self._sum += value
            self._total += 1
            if baseline:
                if self._ewma is None:
                    self._ewma = value
                else:
                    k = 2.0 ** (-1.0 / self.halflife)
                    self._ewma = k * self._ewma + (1.0 - k) * value

    @property
    def count(self) -> int:
        """Samples currently retained in the window."""
        return self._count

    @property
    def total(self) -> int:
        """Lifetime samples, including ones the ring has since dropped."""
        return self._total

    @property
    def ewma(self) -> float | None:
        return self._ewma

    @property
    def last(self) -> float | None:
        if not self._count:
            return None
        return self._buf[(self._next - 1) % self.capacity]

    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def window(self) -> list[float]:
        """Retained samples, oldest first."""
        if self._count < self.capacity:
            return self._buf[: self._count]
        return self._buf[self._next:] + self._buf[: self._next]

    def percentile(self, q: float) -> float | None:
        """q in [0, 100]; linear interpolation over the retained window.
        Empty-window contract: ``None`` (no sample can stand in for a
        percentile — 0.0 would read as "instant")."""
        with self._lock:
            if not self._count:
                return None
            xs = sorted(self._buf[: self._count]
                        if self._count < self.capacity else self._buf)
        pos = (len(xs) - 1) * min(max(q, 0.0), 100.0) / 100.0
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)

    def summary(self) -> dict:
        """Safe on an empty ring: percentile/ewma/last fields are None,
        count/total/mean are zero."""
        return {"count": self._count, "total": self._total,
                "mean": self.mean(), "ewma": self._ewma,
                "p50": self.percentile(50.0), "p95": self.percentile(95.0),
                "last": self.last}

    def reset(self) -> None:
        with self._lock:
            self._next = self._count = 0
            self._sum = 0.0
            self._ewma = None


class ResidualTracker:
    """Predicted-vs-measured tracking for one key (plan fingerprint or
    level class). Residuals are *relative*: (measured − predicted) /
    predicted, so drift thresholds mean the same thing across sizes.

    The window is kept sorted incrementally (bisect insert/remove per
    `record`, under a per-tracker lock — the three parallel structures
    must never desync under concurrent observers), so the streaming
    medians `drift()` and `bias()` are O(1) — they sit on the observe
    hot path, which is gated under 1% of a simulated step by
    `benchmarks/telemetry_bench.py`."""

    __slots__ = ("capacity", "_window", "_sorted_abs", "_sorted_signed",
                 "_total", "_lock")

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._window: deque[float] = deque()     # signed rels, in order
        self._sorted_abs: list[float] = []
        self._sorted_signed: list[float] = []
        self._total = 0
        self._lock = threading.Lock()

    def record(self, predicted: float, measured: float) -> float:
        denom = abs(float(predicted))
        rel = ((float(measured) - float(predicted)) / denom
               if denom > 0.0 else 0.0)
        with self._lock:
            if len(self._window) == self.capacity:
                old = self._window.popleft()
                del self._sorted_abs[bisect.bisect_left(self._sorted_abs,
                                                        abs(old))]
                del self._sorted_signed[
                    bisect.bisect_left(self._sorted_signed, old)]
            self._window.append(rel)
            bisect.insort(self._sorted_abs, abs(rel))
            bisect.insort(self._sorted_signed, rel)
            self._total += 1
        return rel

    @property
    def count(self) -> int:
        return len(self._window)

    @property
    def total(self) -> int:
        return self._total

    @staticmethod
    def _median(xs: list[float]) -> float:
        if not xs:
            return 0.0
        mid = len(xs) // 2
        return xs[mid] if len(xs) % 2 else 0.5 * (xs[mid - 1] + xs[mid])

    def drift(self) -> float | None:
        """Median |relative residual| over the window — the refit
        policy's trigger statistic (robust to straggler outliers).
        Empty-window contract: ``None`` (an empty tracker has measured
        nothing; 0.0 would read as "zero drift, model perfect")."""
        with self._lock:
            if not self._window:
                return None
            return self._median(self._sorted_abs)

    def bias(self) -> float | None:
        """Median signed relative residual (positive: model optimistic,
        the cluster is slower than predicted). ``None`` when empty."""
        with self._lock:
            if not self._window:
                return None
            return self._median(self._sorted_signed)

    def reset(self) -> None:
        with self._lock:
            self._window.clear()
            self._sorted_abs.clear()
            self._sorted_signed.clear()


class ArrivalEstimator:
    """Per-device arrival-offset estimation.

    `record(arrivals)` takes one collective's per-device arrival times
    (any common clock; only differences matter) and files each device's
    offset relative to the earliest arrival into that device's ring.
    `offsets()` returns the per-device median offsets — the measured
    process-arrival pattern that `SkewModel(dist="empirical")` prices.
    """

    def __init__(self, capacity: int = 64):
        self.capacity = int(capacity)
        self._rings: dict[int, TimingRing] = {}

    def record(self, arrivals) -> None:
        ts = [float(t) for t in arrivals]
        if not ts:
            return
        t0 = min(ts)
        for dev, t in enumerate(ts):
            ring = self._rings.get(dev)
            if ring is None:
                ring = self._rings[dev] = TimingRing(capacity=self.capacity)
            ring.add(t - t0)

    @property
    def n_devices(self) -> int:
        return len(self._rings)

    @property
    def count(self) -> int:
        """Collectives observed (min over devices; 0 when empty)."""
        if not self._rings:
            return 0
        return min(r.total for r in self._rings.values())

    def offsets(self) -> list[float]:
        """Median arrival offset per device, index-ordered."""
        return [self._rings[d].percentile(50.0)
                for d in sorted(self._rings)]

    def reset(self) -> None:
        self._rings.clear()


@dataclass
class LevelSample:
    """One online calibration sample for a level class: an executed
    collective's (n, size) point with its measured wall time and the
    CPS-equivalence factor computed at observe time (see
    `core.fitting.cps_equivalent_time`)."""
    n: int
    size_floats: float
    measured: float
    cps_equivalent: float


@dataclass
class LedgerEntry:
    """One priced collective in the cost ledger (DESIGN.md §11): the
    quoted prediction decomposed into per-term predicted seconds
    (``shares`` sums to ``predicted`` — enforced where it is built, see
    `cost_model.CostBreakdown.scaled_to`) next to the measured wall
    time. A window of these is what `core.fitting.attribute_term_drift`
    solves to name the drifting term."""
    level: str
    n: int
    size_floats: float
    predicted: float
    measured: float
    shares: dict[str, float]


class CostLedger:
    """Bounded per-level store of `LedgerEntry` rows. Pure storage —
    the attribution least-squares lives in `core.fitting` so this module
    stays stdlib-only. Cleared by `Telemetry.remeasure()` along with the
    other suspect state (old hardware, old prices)."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._entries: dict[str, deque[LedgerEntry]] = {}
        self._lock = threading.Lock()

    def record(self, entry: LedgerEntry) -> None:
        with self._lock:
            dq = self._entries.get(entry.level)
            if dq is None:
                dq = self._entries[entry.level] = deque(
                    maxlen=self.capacity)
            dq.append(entry)

    def entries(self, level: str) -> list[LedgerEntry]:
        with self._lock:
            return list(self._entries.get(level, ()))

    def count(self, level: str) -> int:
        with self._lock:
            return len(self._entries.get(level, ()))

    def levels(self) -> list[str]:
        with self._lock:
            return sorted(self._entries)

    def totals(self, level: str) -> dict[str, float]:
        """Summed predicted seconds per term over the retained window —
        the 'where does the model think the time goes' view."""
        out: dict[str, float] = {}
        for e in self.entries(level):
            for term, sec in e.shares.items():
                out[term] = out.get(term, 0.0) + sec
        return out

    def clear(self, level: str | None = None) -> None:
        with self._lock:
            if level is None:
                self._entries.clear()
            else:
                self._entries.pop(level, None)


@dataclass
class TelemetryEvent:
    kind: str
    info: dict = field(default_factory=dict)


class Telemetry:
    """Process-level measurement hub shared by the training loop, the
    serving self-check, the straggler watchdog and the planner service.

    Keys are free-form strings; the conventions used by the wiring:

      * ``train/step``            — per-step wall time (watchdog ring)
      * ``sync/<axis>``           — measured sync/probe time per DP axis
      * ``plan/<fingerprint>``    — residuals per plan cache key
      * ``level/<level-class>``   — residuals per Table-5 level class
        (what the refit policy watches)
    """

    def __init__(self, ring_capacity: int = 256, ewma_halflife: int = 20,
                 arrival_capacity: int = 64):
        self.ring_capacity = int(ring_capacity)
        self.ewma_halflife = int(ewma_halflife)
        self.arrivals = ArrivalEstimator(capacity=arrival_capacity)
        # bounded like the rings: a flaky cluster opens a re-measure
        # window per straggler, and a weeks-long deployment must not
        # grow (or serialize, via stats()) an unbounded event log
        self.events: deque[TelemetryEvent] = deque(maxlen=ring_capacity)
        self.ledger = CostLedger(capacity=ring_capacity)
        self._rings: dict[str, TimingRing] = {}
        self._residuals: dict[str, ResidualTracker] = {}
        self._samples: dict[str, list[LevelSample]] = {}
        self._lock = threading.RLock()

    # ---- timing rings ------------------------------------------------------
    def ring(self, key: str, *, halflife: int | None = None) -> TimingRing:
        """Create-on-demand ring. `halflife` overrides the hub default
        at creation time only (an existing ring keeps its decay — the
        first owner of a key defines its EWMA semantics)."""
        with self._lock:
            ring = self._rings.get(key)
            if ring is None:
                ring = self._rings[key] = TimingRing(
                    capacity=self.ring_capacity,
                    halflife=self.ewma_halflife if halflife is None
                    else halflife)
            return ring

    def record(self, key: str, value: float, *,
               baseline: bool = True) -> TimingRing:
        ring = self.ring(key)
        ring.add(value, baseline=baseline)
        return ring

    # ---- residuals ---------------------------------------------------------
    def residuals(self, key: str) -> ResidualTracker:
        with self._lock:
            rt = self._residuals.get(key)
            if rt is None:
                rt = self._residuals[key] = ResidualTracker(
                    capacity=self.ring_capacity)
            return rt

    def record_residual(self, key: str, predicted: float,
                        measured: float) -> float:
        return self.residuals(key).record(predicted, measured)

    # ---- online calibration samples ---------------------------------------
    def record_sample(self, level: str, sample: LevelSample) -> None:
        with self._lock:
            self._samples.setdefault(level, []).append(sample)
            # bound memory like the rings do: keep the freshest window
            if len(self._samples[level]) > self.ring_capacity:
                del self._samples[level][: -self.ring_capacity]

    def samples(self, level: str) -> list[LevelSample]:
        with self._lock:
            return list(self._samples.get(level, ()))

    def sample_count(self, level: str) -> int:
        """O(1) — `samples()` copies, and the observe hot path only
        needs the count."""
        with self._lock:
            return len(self._samples.get(level, ()))

    def clear_samples(self, level: str | None = None) -> None:
        with self._lock:
            if level is None:
                self._samples.clear()
            else:
                self._samples.pop(level, None)

    # ---- arrival offsets ---------------------------------------------------
    def record_arrivals(self, arrivals) -> None:
        with self._lock:
            self.arrivals.record(arrivals)

    # ---- re-measure windows ------------------------------------------------
    def remeasure(self, reason: str, info: dict | None = None) -> None:
        """Open a re-measure window after an event that changes what the
        cluster *is* (straggler exclusion, elastic remesh, fault-tolerant
        resume onto a new allocation): drop residual histories, online
        calibration samples and arrival offsets — they describe the old
        hardware — while keeping the raw timing rings for trend display.
        Drift detection restarts from fresh post-event samples."""
        with self._lock:
            self.events.append(TelemetryEvent(reason, dict(info or {})))
            for rt in self._residuals.values():
                rt.reset()
            self._samples.clear()
            self.arrivals.reset()
            self.ledger.clear()

    # ---- reporting ---------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "rings": {k: r.summary() for k, r in self._rings.items()},
                "residuals": {k: {"count": rt.count, "drift": rt.drift(),
                                  "bias": rt.bias()}
                              for k, rt in self._residuals.items()},
                "samples": {lvl: len(s) for lvl, s in self._samples.items()},
                "ledger": {lvl: self.ledger.count(lvl)
                           for lvl in self.ledger.levels()},
                "arrival_devices": self.arrivals.n_devices,
                "events": [(e.kind, e.info) for e in self.events],
            }


# ---------------------------------------------------------------------------
# Process-wide default hub (what the launchers and the default planner
# service share when none is passed explicitly)
# ---------------------------------------------------------------------------
_default: Telemetry | None = None
_default_lock = threading.Lock()


def default_telemetry() -> Telemetry:
    global _default
    with _default_lock:
        if _default is None:
            _default = Telemetry()
        return _default


def peek_default_telemetry() -> Telemetry | None:
    """The process-wide hub if one exists, WITHOUT creating it — event
    paths (remesh/resume) must not instantiate a hub just to clear it."""
    with _default_lock:
        return _default


def set_default_telemetry(tele: Telemetry | None) -> None:
    global _default
    with _default_lock:
        _default = tele
