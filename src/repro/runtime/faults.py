"""Seeded, deterministic fault injection for chaos testing (DESIGN.md §12).

* FaultPlan — an immutable, seed-derived sequence of FaultEvents. The same
  (seed, rates, steps) always generates the same events, so a failing
  chaos run is replayable bit-for-bit: re-run with the plan's `key()` and
  the exact failure sequence recurs.
* FaultInjector — a context manager that arms a FaultPlan. While active,
  - `FaultTolerantLoop` consults `step_events(step)` each step and applies
    step-scoped faults (device loss, link degrade/restore, delayed
    arrival, checkpoint/cache file corruption);
  - `GuardedSchedule` (core.lower) consults `check_launch()` before each
    collective launch and receives payload-corruption faults as raised
    `InjectedFault`s, exercising the fallback ladder.
  Every event fires exactly ONCE per injector (tracked by event id), so a
  device-loss at step k does not re-fire after restore-and-replay reaches
  step k again — chaos runs terminate.
* `REPRO_FAULT_PLAN` env var — arms a process-wide injector for CI chaos
  jobs without touching call sites: `seed=7,steps=256,payload_corrupt=0.05`
  (see `FaultPlan.parse`). Explicitly-entered injectors take precedence.

stdlib-only (no jax import): the module is safe to import from metrics/
telemetry-level code and from test collection on jax-free paths.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import random
import threading

from .metrics import default_metrics

# step-scoped kinds are applied by FaultTolerantLoop at step boundaries;
# "payload_corrupt" is launch-scoped (its `at` indexes guarded collective
# launches, consumed by GuardedSchedule.check_launch).
STEP_KINDS = ("device_loss", "link_degrade", "link_restore", "delay",
              "file_corrupt")
LAUNCH_KINDS = ("payload_corrupt",)
KINDS = STEP_KINDS + LAUNCH_KINDS


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault. `at` is a step index for STEP_KINDS and a
    guarded-launch ordinal for LAUNCH_KINDS. `target` names what the
    fault hits (a level class for link faults, "checkpoint"/"cache" for
    file corruption). `magnitude` is kind-specific: the bandwidth
    multiplier for link_degrade (0.5 → half bandwidth) or the sleep
    seconds for delay."""
    kind: str
    at: int
    target: str = ""
    magnitude: float = 0.0

    @property
    def ident(self) -> tuple:
        return (self.kind, self.at, self.target)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault schedule: events are fully determined by the
    generation inputs; `key()` digests them for replay bookkeeping."""
    seed: int = 0
    events: tuple = ()

    @classmethod
    def generate(cls, seed: int, steps: int, *,
                 device_loss: float = 0.0,
                 link_degrade: float = 0.0,
                 delay: float = 0.0,
                 payload_corrupt: float = 0.0,
                 file_corrupt: float = 0.0,
                 levels=("root_sw", "cross_dc"),
                 file_targets=("checkpoint", "cache")) -> "FaultPlan":
        """Draw per-step Bernoulli events at the given rates from a
        `random.Random(seed)` stream — no wall clock, no global RNG, so
        the same arguments always yield the same plan. A link_degrade is
        paired with a link_restore a deterministic number of steps later
        so degradation windows are bounded."""
        rng = random.Random(int(seed))
        events = []
        for step in range(int(steps)):
            if device_loss and rng.random() < device_loss:
                events.append(FaultEvent("device_loss", step))
            if link_degrade and rng.random() < link_degrade:
                lvl = levels[rng.randrange(len(levels))]
                factor = 0.25 + 0.5 * rng.random()      # 0.25x..0.75x bw
                events.append(FaultEvent("link_degrade", step, lvl,
                                         round(factor, 4)))
                heal = step + 1 + rng.randrange(8)
                if heal < steps:
                    events.append(FaultEvent("link_restore", heal, lvl))
            if delay and rng.random() < delay:
                events.append(FaultEvent(
                    "delay", step, magnitude=round(0.01 * (1 + 4 *
                                                          rng.random()), 4)))
            if payload_corrupt and rng.random() < payload_corrupt:
                # launch ordinal, decoupled from the step counter
                events.append(FaultEvent("payload_corrupt",
                                         rng.randrange(max(1, 4 * steps))))
            if file_corrupt and rng.random() < file_corrupt:
                tgt = file_targets[rng.randrange(len(file_targets))]
                events.append(FaultEvent("file_corrupt", step, tgt))
        # dedupe by identity (two draws can alias the same launch ordinal)
        seen, uniq = set(), []
        for ev in events:
            if ev.ident not in seen:
                seen.add(ev.ident)
                uniq.append(ev)
        return cls(seed=int(seed), events=tuple(uniq))

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse an env-var style spec: `seed=7,steps=256,delay=0.02,
        payload_corrupt=0.05,link_degrade=0.01,device_loss=0,
        file_corrupt=0`. A bare integer is shorthand for that seed with
        mild survivable defaults (no device loss)."""
        spec = (spec or "").strip()
        kv = {}
        if spec:
            if "=" not in spec:
                kv["seed"] = spec
            else:
                for part in spec.split(","):
                    part = part.strip()
                    if not part:
                        continue
                    k, _, v = part.partition("=")
                    kv[k.strip()] = v.strip()
        seed = int(float(kv.pop("seed", 0)))
        steps = int(float(kv.pop("steps", 256)))
        rates = {"device_loss": 0.0, "link_degrade": 0.0, "delay": 0.02,
                 "payload_corrupt": 0.02, "file_corrupt": 0.0}
        for k in list(rates):
            if k in kv:
                rates[k] = float(kv.pop(k))
        if kv:
            raise ValueError(f"unknown fault-plan keys: {sorted(kv)}")
        return cls.generate(seed, steps, **rates)

    def key(self) -> str:
        h = hashlib.sha256(repr((self.seed, self.events)).encode())
        return h.hexdigest()[:16]

    def events_at(self, step: int) -> list:
        return [e for e in self.events
                if e.at == step and e.kind in STEP_KINDS]

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)


class InjectedFault(RuntimeError):
    """Raised when an armed fault fires (device loss, corrupted payload).
    Carries the triggering event so handlers can log exactly what hit."""

    def __init__(self, event: FaultEvent):
        super().__init__(f"injected fault: {event.kind} at {event.at}"
                         + (f" target={event.target}" if event.target
                            else ""))
        self.event = event


_LOCK = threading.Lock()
_STACK: list = []                 # explicitly entered injectors (LIFO)
_ENV_INJECTOR = None              # lazily built from REPRO_FAULT_PLAN
_ENV_SPEC_SEEN = None

ENV_VAR = "REPRO_FAULT_PLAN"


class FaultInjector:
    """Arms a FaultPlan for a scoped region. Context-manager entry pushes
    the injector onto a process-global stack (innermost wins) so library
    code reaches it via `active_injector()` without plumbing."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._fired: set = set()
        self._launches = 0
        self._launch_events = {e.at: e for e in plan.events
                               if e.kind in LAUNCH_KINDS}
        self._by_step: dict = {}
        for e in plan.events:
            if e.kind in STEP_KINDS:
                self._by_step.setdefault(e.at, []).append(e)
        self.counts: dict = {}
        self._lock = threading.Lock()

    # -- scoping ----------------------------------------------------------
    def __enter__(self):
        with _LOCK:
            _STACK.append(self)
        return self

    def __exit__(self, *exc):
        with _LOCK:
            if self in _STACK:
                _STACK.remove(self)
        return False

    # -- firing -----------------------------------------------------------
    def _record(self, ev: FaultEvent) -> None:
        self.counts[ev.kind] = self.counts.get(ev.kind, 0) + 1
        default_metrics().counter(
            "faults_injected_total",
            "fault events fired by the chaos injector").inc()

    def step_events(self, step: int) -> list:
        """Unfired step-scoped events due at `step`. Each event fires
        once per injector lifetime: restore-and-replay passing the same
        step again sees an empty list, so chaos runs terminate."""
        out = []
        with self._lock:
            for ev in self._by_step.get(step, ()):
                if ev.ident in self._fired:
                    continue
                self._fired.add(ev.ident)
                self._record(ev)
                out.append(ev)
        return out

    def check_launch(self, label: str = "") -> None:
        """Consume one guarded-launch ordinal; raise InjectedFault when a
        payload-corruption event is armed at this ordinal. Called by
        GuardedSchedule before dispatching a collective."""
        with self._lock:
            ordinal = self._launches
            self._launches += 1
            ev = self._launch_events.get(ordinal)
            if ev is None or ev.ident in self._fired:
                return
            self._fired.add(ev.ident)
            self._record(ev)
        raise InjectedFault(ev)

    def corrupt_file(self, path: str) -> bool:
        """Deterministically corrupt the file at `path` in place (seeded
        by plan seed + basename, so replays clobber the same bytes).
        Returns False when the file doesn't exist."""
        try:
            size = os.path.getsize(path)
        except OSError:
            return False
        rng = random.Random(f"{self.plan.seed}:{os.path.basename(path)}")
        garbage = bytes(rng.randrange(256) for _ in range(
            min(64, max(1, size))))
        try:
            with open(path, "r+b") as f:
                f.seek(0)
                f.write(b"\x00CHAOS\x00" + garbage)
                f.truncate(max(len(garbage) + 8, size // 2))
        except OSError:
            return False
        default_metrics().counter(
            "faults_files_corrupted_total",
            "files clobbered by the chaos injector").inc()
        return True

    def stats(self) -> dict:
        with self._lock:
            return {"key": self.plan.key(), "seed": self.plan.seed,
                    "fired": dict(self.counts),
                    "launches": self._launches,
                    "pending": len(self.plan.events) - len(self._fired)}


def _env_injector():
    global _ENV_INJECTOR, _ENV_SPEC_SEEN
    spec = os.environ.get(ENV_VAR)
    if not spec:
        return None
    with _LOCK:
        if _ENV_INJECTOR is None or _ENV_SPEC_SEEN != spec:
            try:
                plan = FaultPlan.parse(spec)
            except (ValueError, TypeError):
                return None        # malformed spec never crashes the host
            _ENV_INJECTOR = FaultInjector(plan)
            _ENV_SPEC_SEEN = spec
        return _ENV_INJECTOR


def active_injector():
    """Innermost explicitly-entered injector, else the env-armed one,
    else None. The common library call sites (GuardedSchedule,
    FaultTolerantLoop) poll this so chaos needs no plumbing."""
    with _LOCK:
        if _STACK:
            return _STACK[-1]
    return _env_injector()
