"""Fault tolerance + straggler mitigation + elastic scaling.

* FaultTolerantLoop — checkpoint/restart driver. Runs `step_fn` repeatedly,
  checkpoints every `ckpt_every` steps (async), and on any step failure
  (preemption, device loss, injected fault) restores the latest checkpoint
  and replays. The data pipeline is pure-in-step, so replay is exact.
* StragglerWatchdog — per-step timing EWMA; a step slower than
  `threshold ×` the EWMA is flagged. In a multi-host deployment the driver
  reacts by excluding the slow host from the next allocation (here: the
  hook records the event and the loop optionally re-meshes).
* elastic_remesh — reshard a host-state pytree onto a new mesh/sharding:
  the checkpoint is device-agnostic (numpy), so scaling from e.g. 512 to
  256 chips is a restore-with-different-shardings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0
    halflife: int = 20
    _ewma: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step straggled."""
        if self._ewma is None:
            self._ewma = dt
            return False
        straggled = dt > self.threshold * self._ewma
        k = 2 ** (-1.0 / self.halflife)
        # slow steps don't poison the baseline
        if not straggled:
            self._ewma = k * self._ewma + (1 - k) * dt
        if straggled:
            self.events.append((step, dt, self._ewma))
        return straggled


class FaultTolerantLoop:
    def __init__(self, step_fn: Callable[[Any, int], Any],
                 state: Any, ckpt: CheckpointManager, *,
                 ckpt_every: int = 50,
                 max_restarts: int = 10,
                 watchdog: StragglerWatchdog | None = None,
                 on_event: Callable[[str, dict], None] | None = None,
                 planner=None,
                 invalidate_on_resume: bool = True):
        self.step_fn = step_fn
        self.state = state
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StragglerWatchdog()
        self.on_event = on_event or (lambda kind, info: None)
        self.restarts = 0
        # Lowered CompiledSchedules and bucket plans are derived from the
        # mesh that existed when they were lowered; a restore may land on
        # different hardware (preemption → new allocation), so by default
        # every resume drops them and the next train step re-lowers
        # against the live axis sizes (core.bucketing, DESIGN.md §9).
        self.planner = planner
        self.invalidate_on_resume = invalidate_on_resume

    def resume_or_init(self) -> int:
        last = self.ckpt.latest_step()
        if last is not None:
            self.state, step = self.ckpt.restore(self.state)
            if self.invalidate_on_resume:
                from repro.core.bucketing import invalidate_schedules
                dropped = invalidate_schedules(self.planner)
                self.on_event("invalidate", {"step": step,
                                             "dropped": dropped})
            self.on_event("resume", {"step": step})
            return step
        return 0

    def run(self, total_steps: int, start_step: int | None = None) -> Any:
        step = self.resume_or_init() if start_step is None else start_step
        while step < total_steps:
            t0 = time.perf_counter()
            try:
                self.state = self.step_fn(self.state, step)
            except Exception as e:           # device loss / preemption
                self.restarts += 1
                self.on_event("failure", {"step": step, "error": repr(e),
                                          "restart": self.restarts})
                if self.restarts > self.max_restarts:
                    raise
                if (self.invalidate_on_resume
                        and self.ckpt.latest_step() is None):
                    # no checkpoint to restore → resume_or_init won't
                    # invalidate, but the failure may still mean a new
                    # allocation: drop stale schedules here too
                    from repro.core.bucketing import invalidate_schedules
                    dropped = invalidate_schedules(self.planner)
                    self.on_event("invalidate", {"step": 0,
                                                 "dropped": dropped})
                step = self.resume_or_init()
                continue
            dt = time.perf_counter() - t0
            if self.watchdog.observe(step, dt):
                self.on_event("straggler", {"step": step, "dt": dt})
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, self.state)
                self.on_event("checkpoint", {"step": step})
        self.ckpt.save(step, self.state)
        self.ckpt.wait()
        return self.state


def elastic_remesh(state: Any, shardings: Any, *, planner=None,
                   invalidate: bool = True) -> Any:
    """Re-place a host (or differently-sharded) pytree onto new shardings.
    `shardings` is a pytree of jax.sharding.Sharding matching `state`.

    A remesh changes axis sizes, so by default every lowered
    CompiledSchedule and bucket plan derived from the planner's cache is
    dropped (stale schedules compiled for the old axis size must not
    survive — they would raise on the new mesh at best). Pass `planner`
    to invalidate a specific service; the default invalidates the
    process-wide service if one exists."""
    if invalidate:
        from repro.core.bucketing import invalidate_schedules
        invalidate_schedules(planner)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings)
