"""Fault tolerance + straggler mitigation + elastic scaling.

* FaultTolerantLoop — checkpoint/restart driver. Runs `step_fn` repeatedly,
  checkpoints every `ckpt_every` steps (async), and on any step failure
  (preemption, device loss, injected fault) restores the latest checkpoint
  and replays. The data pipeline is pure-in-step, so replay is exact.
* StragglerWatchdog — per-step timing over the shared telemetry ring
  (`runtime.telemetry`); a step slower than `threshold ×` the ring's EWMA
  is flagged. Straggler detection and the planner's residual tracking
  consume ONE datapath: the same ring the watchdog reads is the one
  `PlannerService.stats()` reports and the online refit loop draws trend
  context from. In a multi-host deployment the driver reacts by excluding
  the slow host from the next allocation (here: the hook records the
  event and the loop optionally re-meshes).
* elastic_remesh — reshard a host-state pytree onto a new mesh/sharding:
  the checkpoint is device-agnostic (numpy), so scaling from e.g. 512 to
  256 chips is a restore-with-different-shardings.

Straggler, failure-restart and remesh events all open a telemetry
*re-measure window* (`Telemetry.remeasure`): predicted-vs-measured
residuals, online calibration samples and arrival offsets gathered before
the event describe hardware that no longer exists, so the drift detector
restarts from fresh post-event samples instead of refitting against a
ghost cluster.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax

from repro.checkpoint import CheckpointManager

from .metrics import default_metrics
from .telemetry import Telemetry, peek_default_telemetry
from .trace import default_tracer


@dataclasses.dataclass
class StragglerWatchdog:
    """Per-step straggler detector over the shared telemetry ring.

    Contract unchanged: `observe(step, dt) -> bool`, True when the step
    straggled. The EWMA baseline lives in `telemetry.ring(key)` — the
    half-life decay and don't-poison-the-baseline semantics are the
    ring's `baseline=` flag — so the same samples serve straggler
    detection, percentile reporting and drift trend display."""
    threshold: float = 2.0
    halflife: int = 20
    telemetry: Telemetry | None = None
    key: str = "train/step"
    # bounded: a multi-month job with periodic stragglers must not grow
    # an unbounded event list; the deque keeps the freshest max_events
    # (len() / indexing / iteration all behave list-like)
    max_events: int = 256
    events: deque = dataclasses.field(default=None)

    def __post_init__(self):
        if self.telemetry is None:
            self.telemetry = Telemetry()
        if self.events is None:
            self.events = deque(maxlen=self.max_events)

    @property
    def _ring(self):
        return self.telemetry.ring(self.key, halflife=self.halflife)

    @property
    def _ewma(self) -> float | None:
        """Back-compat view of the baseline (now ring-owned)."""
        return self._ring.ewma

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step straggled."""
        ring = self._ring
        ewma = ring.ewma
        if ewma is None:
            ring.add(dt)
            return False
        straggled = dt > self.threshold * ewma
        # slow steps don't poison the baseline (but stay in the window
        # for percentiles)
        ring.add(dt, baseline=not straggled)
        if straggled:
            self.events.append((step, dt, ewma))
            default_tracer().instant("ft/straggler", step=step, dt=dt,
                                     ewma=ewma)
            default_metrics().counter(
                "ft_straggler_events_total",
                "steps flagged slower than threshold x EWMA").inc()
        return straggled


class FaultTolerantLoop:
    def __init__(self, step_fn: Callable[[Any, int], Any],
                 state: Any, ckpt: CheckpointManager, *,
                 ckpt_every: int = 50,
                 max_restarts: int = 10,
                 watchdog: StragglerWatchdog | None = None,
                 on_event: Callable[[str, dict], None] | None = None,
                 planner=None,
                 invalidate_on_resume: bool = True,
                 telemetry: Telemetry | None = None,
                 injector=None,
                 forgive_after: int = 200):
        self.step_fn = step_fn
        self.state = state
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        # one measurement datapath: the loop, its watchdog and (when the
        # planner closes the loop) the refit machinery share a hub —
        # explicit telemetry wins, then the planner's, then the watchdog's
        self.telemetry = telemetry \
            or (planner.telemetry if planner is not None
                and getattr(planner, "telemetry", None) is not None
                else None)
        if watchdog is None:
            watchdog = StragglerWatchdog(telemetry=self.telemetry)
        self.watchdog = watchdog
        if self.telemetry is None:
            self.telemetry = watchdog.telemetry
        self.on_event = on_event or (lambda kind, info: None)
        self.restarts = 0
        # Lowered CompiledSchedules and bucket plans are derived from the
        # mesh that existed when they were lowered; a restore may land on
        # different hardware (preemption → new allocation), so by default
        # every resume drops them and the next train step re-lowers
        # against the live axis sizes (core.bucketing, DESIGN.md §9).
        self.planner = planner
        self.invalidate_on_resume = invalidate_on_resume
        # chaos hooks (DESIGN.md §12): the loop consults the armed fault
        # injector at each step boundary; None defers to the scoped /
        # env-armed injector (`runtime.faults.active_injector`) at run
        # time, so entering a FaultInjector context needs no re-plumb.
        self.injector = injector
        # restart-budget decay: `forgive_after` consecutive successful
        # steps reset `restarts` to 0, so a long job with occasional
        # preemptions never exhausts max_restarts (0 disables).
        self.forgive_after = forgive_after
        self._progress = 0

    def _remeasure(self, reason: str, info: dict) -> None:
        """Open a telemetry re-measure window after an event that may
        change the executing hardware: pre-event residuals, calibration
        samples and arrival offsets are dropped so the online refit loop
        (`PlannerService.observe`) re-converges on post-event data."""
        if self.telemetry is not None:
            self.telemetry.remeasure(reason, info)

    def resume_or_init(self) -> int:
        last = self.ckpt.latest_step()
        if last is not None:
            with default_tracer().span("ft/restore", step=last):
                self.state, step = self.ckpt.restore(self.state)
            default_metrics().counter(
                "ft_resumes_total",
                "checkpoint restores (resume-or-init hits)").inc()
            if self.invalidate_on_resume:
                from repro.core.bucketing import invalidate_schedules
                dropped = invalidate_schedules(self.planner)
                self._remeasure("resume", {"step": step,
                                           "dropped": dropped})
                self.on_event("invalidate", {"step": step,
                                             "dropped": dropped})
            self.on_event("resume", {"step": step})
            return step
        return 0

    def _active_injector(self):
        if self.injector is not None:
            return self.injector
        from .faults import active_injector
        return active_injector()

    def _apply_fault(self, ev, step: int) -> None:
        """Realize one injected step-scoped fault (DESIGN.md §12).
        device_loss raises (the except path restores-and-replays, like a
        real preemption); link faults flow into the planner's health map
        so it replans around the sag; delay slows this step (exercising
        the watchdog); file_corrupt clobbers the newest checkpoint (the
        checksum fallback restores the previous one)."""
        inj = self._active_injector()
        if ev.kind == "device_loss":
            from .faults import InjectedFault
            raise InjectedFault(ev)
        if ev.kind == "delay":
            time.sleep(min(max(ev.magnitude, 0.0), 0.25))
        elif ev.kind in ("link_degrade", "link_restore"):
            planner = self.planner
            if planner is not None and hasattr(planner, "mark_degraded"):
                factor = ev.magnitude if ev.kind == "link_degrade" else 1.0
                dropped = planner.mark_degraded(ev.target or "root_sw",
                                                factor)
                self.on_event("degrade" if factor < 1.0 else "restore",
                              {"step": step, "level": ev.target,
                               "factor": factor, "dropped": dropped})
        elif ev.kind == "file_corrupt" and inj is not None:
            # settle any in-flight async save first, so the fault
            # deterministically clobbers the *completed* newest
            # checkpoint instead of racing its writer
            if hasattr(self.ckpt, "wait"):
                self.ckpt.wait()
            steps = self.ckpt.available_steps() \
                if hasattr(self.ckpt, "available_steps") else []
            if steps:
                import os
                tag = f"step_{steps[0]:08d}"
                inj.corrupt_file(os.path.join(self.ckpt.dir, tag,
                                              "arrays.npz"))
                self.on_event("ckpt_corrupt", {"step": step,
                                               "target": tag})

    def run(self, total_steps: int, start_step: int | None = None) -> Any:
        step = self.resume_or_init() if start_step is None else start_step
        while step < total_steps:
            t0 = time.perf_counter()
            try:
                inj = self._active_injector()
                if inj is not None:
                    for ev in inj.step_events(step):
                        self._apply_fault(ev, step)
                self.state = self.step_fn(self.state, step)
                self._progress += 1
                if self.forgive_after and self.restarts \
                        and self._progress >= self.forgive_after:
                    # sustained progress forgives old restarts: the
                    # budget guards against crash loops, not lifetime
                    # preemption count
                    default_metrics().counter(
                        "ft_restart_budget_resets_total",
                        "restart budgets reset after sustained progress"
                    ).inc()
                    self.on_event("budget_reset",
                                  {"step": step, "restarts": self.restarts})
                    self.restarts = 0
                    self._progress = 0
            except Exception as e:           # device loss / preemption
                self._progress = 0
                self.restarts += 1
                default_tracer().instant("ft/failure", step=step,
                                         restart=self.restarts)
                default_metrics().counter(
                    "ft_restarts_total",
                    "failed steps that triggered restore-and-replay").inc()
                self.on_event("failure", {"step": step, "error": repr(e),
                                          "restart": self.restarts})
                if self.restarts > self.max_restarts:
                    raise
                if (self.invalidate_on_resume
                        and self.ckpt.latest_step() is None):
                    # no checkpoint to restore → resume_or_init won't
                    # invalidate, but the failure may still mean a new
                    # allocation: drop stale schedules here too
                    from repro.core.bucketing import invalidate_schedules
                    dropped = invalidate_schedules(self.planner)
                    self._remeasure("restart", {"step": 0,
                                                "dropped": dropped})
                    self.on_event("invalidate", {"step": 0,
                                                 "dropped": dropped})
                step = self.resume_or_init()
                continue
            dt = time.perf_counter() - t0
            if self.watchdog.observe(step, dt):
                # a straggler distorts every in-flight measurement: the
                # refit loop must not fit the planner against a cluster
                # state the driver is about to mitigate away
                self._remeasure("straggler", {"step": step, "dt": dt})
                self.on_event("straggler", {"step": step, "dt": dt})
            step += 1
            if step % self.ckpt_every == 0:
                with default_tracer().span("ft/checkpoint", step=step):
                    self.ckpt.save(step, self.state)
                default_metrics().counter(
                    "ft_checkpoints_total",
                    "periodic checkpoint saves").inc()
                self.on_event("checkpoint", {"step": step})
        self.ckpt.save(step, self.state)
        self.ckpt.wait()
        return self.state


def elastic_remesh(state: Any, shardings: Any, *, planner=None,
                   invalidate: bool = True,
                   telemetry: Telemetry | None = None) -> Any:
    """Re-place a host (or differently-sharded) pytree onto new shardings.
    `shardings` is a pytree of jax.sharding.Sharding matching `state`.

    A remesh changes axis sizes, so by default every lowered
    CompiledSchedule and bucket plan derived from the planner's cache is
    dropped (stale schedules compiled for the old axis size must not
    survive — they would raise on the new mesh at best), and a telemetry
    re-measure window opens: residuals and arrival offsets measured on
    the old mesh must not steer a refit of the new one. Pass `planner`
    to invalidate a specific service; the default invalidates the
    process-wide service (and clears the process-wide telemetry hub) if
    one exists."""
    with default_tracer().span("ft/remesh", invalidate=invalidate):
        if invalidate:
            from repro.core.bucketing import invalidate_schedules
            dropped = invalidate_schedules(planner)
            tele = telemetry \
                or (getattr(planner, "telemetry", None)
                    if planner is not None
                    else peek_default_telemetry())
            if tele is not None:
                tele.remeasure("remesh", {"dropped": dropped})
        default_metrics().counter(
            "ft_remesh_total", "elastic remesh operations").inc()
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
