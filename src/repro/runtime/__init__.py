from .ft import FaultTolerantLoop, StragglerWatchdog, elastic_remesh  # noqa: F401
