"""Runtime substrate: telemetry hub, span tracer, metrics registry, and
the fault-tolerant loop.

`ft` pulls in jax at import time, so its symbols are exported lazily
(PEP 562): `repro.runtime.trace` / `.metrics` / `.telemetry` stay
importable on a machine with no accelerator stack.
"""
from .faults import (FaultEvent, FaultInjector,  # noqa: F401
                     FaultPlan, InjectedFault, active_injector)
from .metrics import (MetricsRegistry, default_metrics,  # noqa: F401
                      set_default_metrics)
from .telemetry import (ArrivalEstimator, CostLedger,  # noqa: F401
                        LedgerEntry, ResidualTracker, Telemetry,
                        TimingRing, default_telemetry,
                        set_default_telemetry)
from .trace import (Tracer, default_tracer,  # noqa: F401
                    set_default_tracer)

_FT = ("FaultTolerantLoop", "StragglerWatchdog", "elastic_remesh")

__all__ = [
    "ArrivalEstimator", "CostLedger", "LedgerEntry", "ResidualTracker",
    "Telemetry", "TimingRing",
    "default_telemetry", "set_default_telemetry",
    "Tracer", "default_tracer", "set_default_tracer",
    "MetricsRegistry", "default_metrics", "set_default_metrics",
    "FaultEvent", "FaultInjector", "FaultPlan", "InjectedFault",
    "active_injector",
    *_FT,
]


def __getattr__(name):
    if name in _FT:
        from . import ft
        return getattr(ft, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
