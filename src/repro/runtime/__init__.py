from .ft import FaultTolerantLoop, StragglerWatchdog, elastic_remesh  # noqa: F401
from .telemetry import (ArrivalEstimator, ResidualTracker,  # noqa: F401
                        Telemetry, TimingRing, default_telemetry,
                        set_default_telemetry)
