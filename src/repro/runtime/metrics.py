"""Metrics registry: counters, gauges, histograms + two exporters.

Zero-dependency companion to `trace.py` (DESIGN.md §11).  Where spans
answer "where did the time go inside *one* operation", metrics answer
"how often / how much across the run": plan-cache hits and misses, refit
events, plan swaps, schedule invalidations, bucket pipeline occupancy.

Exporters:

* ``registry.export(path)`` — JSON snapshot (machine-readable, ridden
  into ``benchmarks/run.py --json`` artifacts), plus, when ``path`` ends
  in ``.prom`` or a second path is given, the Prometheus text exposition
  format (``# TYPE name counter`` lines) so a scrape-style pipeline can
  ingest it without code.

Naming convention: ``component_noun_unit`` with underscores, e.g.
``plan_cache_hits_total``, ``bucket_pipeline_occupancy``.
"""
from __future__ import annotations

import json
import threading
from bisect import bisect_left


class Counter:
    """Monotonically increasing count (hits, misses, refits, swaps)."""
    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value (occupancy, cache size, params version)."""
    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        return self._value


# Default buckets span microseconds to tens of seconds — wide enough for
# both per-fold spans and whole train steps.
_DEFAULT_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0)


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations <= its upper bound; +Inf bucket == count)."""
    __slots__ = ("name", "help", "bounds", "_counts", "_sum", "_count",
                 "_lock")

    def __init__(self, name: str, help: str = "",
                 buckets: tuple[float, ...] = _DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.bounds = tuple(sorted(buckets))
        self._counts = [0] * (len(self.bounds) + 1)   # last = +Inf
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        # bisect_left: a value equal to a bound lands in that bound's
        # bucket (Prometheus ``le`` is <=, not <)
        idx = bisect_left(self.bounds, value)
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def cumulative(self) -> list[tuple[float, int]]:
        """[(upper_bound, cumulative_count)...] ending with (inf, count)."""
        out, running = [], 0
        with self._lock:
            for bound, c in zip(self.bounds, self._counts):
                running += c
                out.append((bound, running))
            out.append((float("inf"), running + self._counts[-1]))
        return out


class MetricsRegistry:
    """Named metric store; get-or-create accessors keep call sites terse."""

    def __init__(self):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = _DEFAULT_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- export -------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-friendly dump of every metric."""
        out: dict = {}
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            if isinstance(m, Counter):
                out[m.name] = {"type": "counter", "value": m.value}
            elif isinstance(m, Gauge):
                out[m.name] = {"type": "gauge", "value": m.value}
            else:
                out[m.name] = {
                    "type": "histogram",
                    "count": m.count,
                    "sum": m.sum,
                    "buckets": [[b if b != float("inf") else "+Inf", c]
                                for b, c in m.cumulative()],
                }
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: list[str] = []
        with self._lock:
            metrics = list(self._metrics.values())
        for m in sorted(metrics, key=lambda m: m.name):
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {m.name} counter")
                lines.append(f"{m.name} {_fmt(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {m.name} gauge")
                lines.append(f"{m.name} {_fmt(m.value)}")
            else:
                lines.append(f"# TYPE {m.name} histogram")
                for bound, c in m.cumulative():
                    le = "+Inf" if bound == float("inf") else _fmt(bound)
                    lines.append(f'{m.name}_bucket{{le="{le}"}} {c}')
                lines.append(f"{m.name}_sum {_fmt(m.sum)}")
                lines.append(f"{m.name}_count {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def export(self, path: str, prom_path: str | None = None) -> dict:
        """Write the JSON snapshot to ``path`` (and the Prometheus text to
        ``prom_path`` when given, else to ``path`` with a ``.prom``
        suffix).  Returns the snapshot."""
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        if prom_path is None:
            base = path[:-5] if path.endswith(".json") else path
            prom_path = base + ".prom"
        with open(prom_path, "w") as f:
            f.write(self.to_prometheus())
        return snap


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


# ---------------------------------------------------------------------------
# Process-wide default (same pattern as trace.default_tracer)
# ---------------------------------------------------------------------------
_default: MetricsRegistry | None = None
_default_lock = threading.Lock()


def default_metrics() -> MetricsRegistry:
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = MetricsRegistry()
    return _default


def peek_default_metrics() -> MetricsRegistry | None:
    return _default


def set_default_metrics(registry: MetricsRegistry | None
                        ) -> MetricsRegistry | None:
    global _default
    with _default_lock:
        old, _default = _default, registry
    return old
