"""Span-level execution tracing for the planner/runtime stack (DESIGN.md §11).

Zero-dependency (stdlib only) so it imports without jax — the hot paths in
`core/lower.py` and `core/bucketing.py` stay importable on machines with no
accelerator stack.  The tracer records *nested spans* with monotonic clocks
into a bounded ring buffer and exports them in the Chrome trace event
format (``chrome://tracing`` / Perfetto "JSON array" flavor).

Design points, mirrored from the paper's measurement discipline:

* **Disabled by default.**  The default tracer starts disabled; every
  instrumentation site pays one attribute load + one boolean check — the
  same budget as the telemetry hub — so the <2 %% smoke-train-step
  overhead gate (``benchmarks/telemetry_bench.py``) holds with the
  instrumentation compiled in.
* **Monotonic clocks.**  ``time.perf_counter_ns`` only; wall time never
  enters a duration.
* **Thread safety.**  The open-span *stack* is thread-local (spans nest
  per thread); the finished-span ring is shared behind a lock and spans
  carry the originating thread id so exported traces keep one Chrome
  ``tid`` lane per thread.
* **Ring-buffered.**  At most ``capacity`` finished spans are retained
  (oldest dropped), so a long training run cannot grow memory unboundedly.
* **Sampling.**  ``sample_every=k`` keeps every k-th *root* span (children
  of a dropped root are dropped with it) — deterministic, not random, so
  traces are reproducible run to run.

JAX caveat (documented, not hidden): spans placed *inside* ``shard_map`` /
``jit`` bodies fire at **trace time**, when the python function is staged
out, not at device execution time.  They are still exactly what a planner
wants for attributing *structure* (which round, which fold, how many
ppermutes) and for the interpreter paths (``run_numpy``), where durations
are real.  Device-side wall time stays the telemetry hub's job.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class Span:
    """One finished span. ``t0``/``t1`` are perf_counter_ns ticks."""
    name: str
    t0: int
    t1: int
    depth: int
    tid: int
    args: dict | None = None

    @property
    def duration_s(self) -> float:
        return (self.t1 - self.t0) / 1e9


class _OpenSpan:
    """Context manager handed out by ``Tracer.span`` while recording."""
    __slots__ = ("_tracer", "name", "t0", "args", "_stack")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._stack = tracer._local_stack()
        self.t0 = 0

    def __enter__(self) -> "_OpenSpan":
        self._stack.append(self)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter_ns()
        stack = self._stack
        # pop self (exceptions can skip inner __exit__ only via interpreter
        # errors; defensively unwind to self)
        while stack and stack.pop() is not self:
            pass
        self._tracer._finish(self, t1, depth=len(stack))
        return None


class _NullSpan:
    """Shared no-op context manager for the disabled / sampled-out path."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpan()


class Tracer:
    """Nested-span tracer with a bounded buffer and a Chrome exporter."""

    def __init__(self, capacity: int = 65536, enabled: bool = False,
                 sample_every: int = 1):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if sample_every <= 0:
            raise ValueError("sample_every must be positive")
        self.enabled = enabled
        self.sample_every = sample_every
        self._spans: deque[Span] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._root_seen = 0          # root spans observed (for sampling)
        self._dropped = 0            # spans discarded by sampling

    # -- recording ----------------------------------------------------------
    def _local_stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def span(self, name: str, **args):
        """Open a span: ``with tracer.span("plan/lookup", level=lvl): ...``

        Returns a shared null context manager when disabled, or when this
        thread's current *root* span was sampled out.
        """
        if not self.enabled:
            return _NULL_SPAN
        if getattr(self._tls, "skip_depth", 0):
            # inside a sampled-out root: drop the whole subtree
            self._dropped += 1
            return _NULL_SPAN
        stack = self._local_stack()
        if not stack:                 # root span: apply sampling decision
            with self._lock:
                keep = (self._root_seen % self.sample_every) == 0
                self._root_seen += 1
            if not keep:
                self._tls.skip_depth = 1
                self._dropped += 1
                return _SkipSpan(self)
        return _OpenSpan(self, name, args or None)

    def _finish(self, open_span: _OpenSpan, t1: int, depth: int) -> None:
        span = Span(open_span.name, open_span.t0, t1, depth,
                    threading.get_ident(), open_span.args)
        with self._lock:
            self._spans.append(span)

    def instant(self, name: str, **args) -> None:
        """Record a zero-duration marker (refit fired, plan swapped...)."""
        if not self.enabled:
            return
        now = time.perf_counter_ns()
        depth = len(self._local_stack())
        span = Span(name, now, now, depth, threading.get_ident(),
                    args or None)
        with self._lock:
            self._spans.append(span)

    # -- inspection ---------------------------------------------------------
    @property
    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    @property
    def dropped(self) -> int:
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._root_seen = 0
            self._dropped = 0

    # -- export -------------------------------------------------------------
    def to_chrome(self, process_name: str = "repro") -> list[dict]:
        """Chrome trace event list: complete ("X") events in microseconds,
        one pid for the process, one tid lane per recording thread."""
        spans = self.spans
        if not spans:
            return []
        t_base = min(s.t0 for s in spans)
        tids = {}
        events: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": process_name},
        }]
        for s in spans:
            tid = tids.setdefault(s.tid, len(tids))
            ev = {
                "name": s.name,
                "ph": "X",
                "pid": 1,
                "tid": tid,
                "ts": (s.t0 - t_base) / 1e3,
                "dur": (s.t1 - s.t0) / 1e3,
            }
            if s.args:
                ev["args"] = dict(s.args)
            events.append(ev)
        for raw, tid in tids.items():
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": tid, "args": {"name": f"thread-{tid}"}})
        return events

    def export_chrome(self, path: str, process_name: str = "repro") -> int:
        """Write a chrome://tracing-loadable JSON file; returns #events."""
        events = self.to_chrome(process_name)
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return len(events)


class _SkipSpan:
    """Root-span placeholder while its subtree is sampled out."""
    __slots__ = ("_tracer",)

    def __init__(self, tracer: Tracer):
        self._tracer = tracer

    def __enter__(self) -> "_SkipSpan":
        return self

    def __exit__(self, *exc) -> None:
        tls = self._tracer._tls
        tls.skip_depth = max(0, getattr(tls, "skip_depth", 1) - 1)
        return None


# ---------------------------------------------------------------------------
# Process-wide default (same pattern as telemetry.default_telemetry)
# ---------------------------------------------------------------------------
_default: Tracer | None = None
_default_lock = threading.Lock()


def default_tracer() -> Tracer:
    """The process-wide tracer (created disabled on first use)."""
    global _default
    if _default is None:
        with _default_lock:
            if _default is None:
                _default = Tracer(enabled=False)
    return _default


def peek_default_tracer() -> Tracer | None:
    """The default tracer if one exists, without creating it."""
    return _default


def set_default_tracer(tracer: Tracer | None) -> Tracer | None:
    """Swap the process-wide tracer (tests, scoped capture); returns old."""
    global _default
    with _default_lock:
        old, _default = _default, tracer
    return old
