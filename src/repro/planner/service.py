"""PlannerService — the one cached, calibrated entry point for plan lookup.

Every AllReduce in the repo resolves its schedule here (DESIGN.md §5):

  * `get_plan(topo, nbytes, dtype)` — full GenTree plan for a physical
    topology, cache-bucketed by size, optionally re-ranked against the
    global baselines under an arrival-skew model;
  * `get_executable(topo, nbytes, dtype)` / `get_axis_executable(axis, n,
    size_floats)` — the same plan plus its lowered shard_map schedule
    (core.lower, DESIGN.md §8), cached alongside the plan entry;
  * `get_axis_plans(axes, size_floats)` — per-mesh-axis plan selection for
    the training/serving hot paths (launch.train's ZeRO-3 engine,
    core.sync.sync_gradients, core.collectives.allreduce_planned).

Plan generation (GenTree + candidate simulation) costs hundreds of
milliseconds at cluster scale; a warm lookup is a fingerprint hash plus an
LRU probe. With a cache path configured (or $REPRO_PLAN_CACHE), warm plans
persist across restarts.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from dataclasses import dataclass, field
from typing import Mapping, Sequence

from repro.core import gentree as gentree_mod
from repro.core.cost_model import GenModelParams, PAPER_TABLE5
from repro.core.plans import Plan
from repro.core.simulator import Simulator
from repro.core.sync import AxisPlan, plan_axes_gentree
from repro.core.topology import TopoNode

from .cache import PlanCache, plan_from_json, plan_to_json
from .calibrate import CalibrationConfig, CalibrationResult, calibrate_levels
from .fingerprint import axis_key, plan_key
from .skew import SkewModel, expected_time

DTYPE_BYTES = {"float64": 8, "float32": 4, "int32": 4, "bfloat16": 2,
               "bf16": 2, "float16": 2, "int8": 1}


@dataclass
class PlanResponse:
    plan: Plan
    algo: str                        # "gentree" or a baseline name
    predicted_time: float            # synchronized simulator pricing
    decisions: dict = field(default_factory=dict)   # gentree plans only
    # simulator price + arrival-gated skew delta (skew.pick_plan_under_skew)
    expected_skewed_time: float | None = None
    source: str = "cold"             # cold | memory | disk
    key: str = ""
    nbytes_bucket: int = 0
    size_floats: float = 0.0
    # get_executable only: the lowered schedule (core.lower), cached
    # alongside the plan entry under "_exec" (derived artifact — never
    # persisted; recompiled once per placement after a disk-warm restart)
    schedule: object | None = None


def _decisions_to_json(decisions) -> dict:
    return {sw: {"algo": d.algo, "factors": d.factors,
                 "rearrange": {str(k): v for k, v in d.rearrange.items()},
                 "cost": d.cost}
            for sw, d in decisions.items()}


class PlannerService:
    """Thread-safe facade over fingerprint + cache + calibrate + skew."""

    def __init__(self, params: Mapping[str, GenModelParams] | None = None,
                 cache: PlanCache | None = None, *,
                 cache_path: str | None = None, capacity: int = 128,
                 autosave: bool = False,
                 skew: SkewModel | None = None,
                 baseline_kinds: tuple[str, ...] = ("cps", "ring", "rhd"),
                 gentree_kwargs: dict | None = None,
                 engine: str | None = None):
        self.params = dict(params) if params else None
        self.cache = cache or PlanCache(capacity=capacity, path=cache_path,
                                        autosave=autosave)
        self.skew = skew
        self.baseline_kinds = baseline_kinds
        self.gentree_kwargs = dict(gentree_kwargs or {})
        # plan-evaluation engine for cold generation / re-ranking:
        # "fast" (compiled, default) or "reference" (pure-Python oracle)
        self.engine = engine
        self.calibration: CalibrationResult | None = None
        self._lock = threading.RLock()

    # ---- calibration -------------------------------------------------------
    def calibrate(self, source: Mapping[str, GenModelParams] | None = None,
                  cfg: CalibrationConfig | None = None) -> CalibrationResult:
        """Refit GenModelParams from measurements and make the fitted set
        the service's pricing basis. Invalidates nothing explicitly — the
        params fingerprint is part of every cache key, so plans priced
        under the old params simply stop being hit."""
        result = calibrate_levels(source or self.params or PAPER_TABLE5,
                                  cfg)
        with self._lock:
            self.params = dict(result.params)
            self.calibration = result
        return result

    # ---- full-topology plans ----------------------------------------------
    def _effective_params(self) -> dict[str, GenModelParams]:
        return self.params or PAPER_TABLE5

    def get_plan(self, topo: TopoNode, nbytes: int | float,
                 dtype: str = "float32", *,
                 params: Mapping[str, GenModelParams] | None = None
                 ) -> PlanResponse:
        """`params` overrides the service's pricing basis for this request
        only (e.g. SyncConfig.params); the override is part of the cache
        key, so differently-priced requests never share an entry."""
        topo.finalize()
        dsize = DTYPE_BYTES.get(dtype, 4)
        bucket = self.cache.bucket(nbytes)
        size_floats = float(bucket) / dsize
        params = dict(params) if params else self._effective_params()
        extra = (tuple(sorted(self.gentree_kwargs.items())),
                 self.skew.key() if self.skew else None)
        key = plan_key(topo, params, bucket, dtype, extra=extra)

        entry = self.cache.get(key)
        if entry is not None:
            obj = entry.get("_obj")
            source = "memory" if obj is not None else "disk"
            plan = obj if obj is not None else plan_from_json(entry["plan"])
            if obj is None:
                entry["_obj"] = plan
            return PlanResponse(
                plan=plan, algo=entry["algo"],
                predicted_time=entry["predicted_time"],
                decisions=entry.get("decisions", {}),
                expected_skewed_time=entry.get("expected_skewed_time"),
                source=source, key=key, nbytes_bucket=bucket,
                size_floats=size_floats)

        # ---- cold path: generate, (optionally) re-rank under skew --------
        result = gentree_mod.gentree(topo, size_floats, params=params,
                                     engine=self.engine,
                                     **self.gentree_kwargs)
        algo, plan = "gentree", result.plan
        decisions = _decisions_to_json(result.decisions)
        skewed = None
        if self.skew is not None and self.skew.scale > 0.0:
            candidates = [("gentree", result.plan)]
            n = topo.num_servers()
            for kind in self.baseline_kinds:
                if kind == "rhd" and (n & (n - 1)) != 0:
                    continue
                if n < 2:
                    continue
                candidates.append(
                    (kind, gentree_mod.baseline_plan(kind, topo,
                                                     size_floats)))
            from .skew import pick_plan_under_skew
            algo, plan, skewed = pick_plan_under_skew(
                candidates, topo, self.skew, params, unit_bytes=dsize,
                engine=self.engine)
            if algo != "gentree":
                # per-switch decisions describe the discarded GenTree
                # plan, not the baseline that won — don't mis-report them
                decisions = {}
        sim = Simulator(topo, params, unit_bytes=dsize, engine=self.engine)
        predicted = sim.simulate(plan).total

        entry = {"plan": plan_to_json(plan), "algo": algo,
                 "predicted_time": predicted, "decisions": decisions,
                 "expected_skewed_time": skewed,
                 "nbytes_bucket": bucket, "_obj": plan}
        self.cache.put(key, entry)
        return PlanResponse(plan=plan, algo=algo, predicted_time=predicted,
                            decisions=decisions, expected_skewed_time=skewed,
                            source="cold", key=key, nbytes_bucket=bucket,
                            size_floats=size_floats)

    # ---- executable plans (lowered schedules) ------------------------------
    def _config_extra(self) -> tuple:
        return (tuple(sorted(self.gentree_kwargs.items())), self.engine)

    def get_executable(self, topo: TopoNode, nbytes: int | float,
                       dtype: str = "float32", *, placement=None,
                       params: Mapping[str, GenModelParams] | None = None
                       ) -> PlanResponse:
        """`get_plan` + the plan lowered to an executable shard_map
        schedule (core.lower.CompiledSchedule, DESIGN.md §8).

        Cache contract: the schedule is a derived artifact stored on the
        plan's cache entry under `_exec`, keyed by the placement map — it
        shares the entry's lifetime (LRU eviction or recalibration drops
        plan and schedule together) and is never written to disk; a
        disk-warm plan is re-lowered once per placement. Raises
        `core.lower.LoweringError` if the cached plan is structurally
        invalid or predates block annotations.
        """
        from repro.core.lower import lower_plan
        resp = self.get_plan(topo, nbytes, dtype, params=params)
        pkey = ("default" if placement is None
                else tuple(sorted(dict(placement).items()))
                if isinstance(placement, Mapping)
                else tuple(placement))
        with self._lock:
            entry = self.cache.get(resp.key)
            execs = None if entry is None else entry.setdefault("_exec", {})
            sched = None if execs is None else execs.get(pkey)
            if sched is None:
                sched = lower_plan(resp.plan, placement=placement)
                if execs is not None:
                    execs[pkey] = sched
        resp.schedule = sched
        return resp

    def get_axis_executable(self, axis_name: str, n: int,
                            size_floats: float,
                            dtype: str = "float32", *,
                            topo: TopoNode | None = None,
                            level: str = "root_sw",
                            params: Mapping[str, GenModelParams] | None
                            = None) -> PlanResponse:
        """Executable plan for one mesh axis: the axis is modelled as a
        single-switch topology of `n` servers (pass `topo` for the real
        physical tree) and the GenTree plan is lowered with the identity
        placement — mesh position i executes server i's schedule.

        `level` is the axis's Table-5 class (leaf/ICI axis → "root_sw",
        outer/DCI axes → "cross_dc" — `core.sync.axis_level` maps mesh
        positions), and `params` optionally overrides the service's
        pricing basis (SyncConfig.params): the synthesized switch's uplink
        bandwidth realizes that level's β, exactly as
        `plan_axes_gentree` prices the same axis, so the executed plan is
        the one the model actually argues for."""
        eff = dict(params) if params else self.params
        if eff is None:
            from repro.core.cost_model import TPU_V5E
            eff = TPU_V5E
        if topo is None:
            from repro.core.sync import level_switch_topo
            topo = level_switch_topo(int(n), eff, level)
        dsize = DTYPE_BYTES.get(dtype, 4)
        return self.get_executable(topo, max(size_floats, 1.0) * dsize,
                                   dtype, params=eff)

    # ---- per-mesh-axis plans (training/serving hot path) -------------------
    def get_axis_plans(self, axes: Sequence[tuple[str, int]],
                       size_floats: float,
                       params: Mapping[str, GenModelParams] | None = None
                       ) -> list[AxisPlan]:
        axes = [(str(a), int(n)) for a, n in axes]
        eff = params if params is not None else self.params
        bucket = self.cache.bucket(max(size_floats, 1.0) * 4)
        from repro.core.cost_model import TPU_V5E
        key = axis_key(axes, eff if eff is not None else TPU_V5E, bucket,
                       extra=self._config_extra())
        entry = self.cache.get(key)
        if entry is not None:
            obj = entry.get("_obj")
            if obj is None:
                obj = [AxisPlan(a, s, tuple(f) if f else None)
                       for a, s, f in entry["axis_plans"]]
                entry["_obj"] = obj
            return list(obj)
        # Cold pricing honours the service's configured engine and
        # gentree kwargs (once silently dropped here, so an
        # engine="reference" or candidate-restricted service got default
        # axis plans).
        plans = plan_axes_gentree(axes, float(bucket) / 4.0, eff,
                                  engine=self.engine,
                                  gentree_kwargs=self.gentree_kwargs)
        entry = {"axis_plans": [[p.axis, p.strategy,
                                 list(p.factors) if p.factors else None]
                                for p in plans],
                 "_obj": list(plans)}
        self.cache.put(key, entry)
        return list(plans)

    # ---- housekeeping ------------------------------------------------------
    def stats(self) -> dict:
        out = {"cache": self.cache.stats.as_dict(),
               "entries": len(self.cache),
               "calibrated": self.calibration is not None}
        if self.params:
            out["params"] = {lvl: dataclasses.asdict(p)
                             for lvl, p in self.params.items()}
        return out

    def save(self, path: str | None = None) -> None:
        self.cache.save(path)


# ---------------------------------------------------------------------------
# Process-wide default service (what the hot paths use)
# ---------------------------------------------------------------------------
_default: PlannerService | None = None
_default_lock = threading.Lock()


def default_service() -> PlannerService:
    """Lazily-created singleton. $REPRO_PLAN_CACHE, when set, points at the
    JSON persistence file so warm plans survive restarts."""
    global _default
    with _default_lock:
        if _default is None:
            path = os.environ.get("REPRO_PLAN_CACHE") or None
            # autosave so the promise holds without an explicit save():
            # nothing on the train/serve hot paths calls save() for us.
            _default = PlannerService(cache_path=path,
                                      autosave=path is not None)
        return _default


def set_default_service(svc: PlannerService | None) -> None:
    """Swap the process-wide service (tests, custom calibration)."""
    global _default
    with _default_lock:
        _default = svc


def get_plan(topo: TopoNode, nbytes: int | float,
             dtype: str = "float32") -> PlanResponse:
    return default_service().get_plan(topo, nbytes, dtype)


def axis_plans(axes: Sequence[tuple[str, int]],
               size_floats: float) -> list[AxisPlan]:
    return default_service().get_axis_plans(axes, size_floats)
