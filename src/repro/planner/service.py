"""PlannerService — the one cached, calibrated entry point for plan lookup.

Every AllReduce in the repo resolves its schedule here (DESIGN.md §5):

  * `get_plan(topo, nbytes, dtype)` — full GenTree plan for a physical
    topology, cache-bucketed by size, optionally re-ranked against the
    global baselines under an arrival-skew model;
  * `get_executable(topo, nbytes, dtype)` / `get_axis_executable(axis, n,
    size_floats)` — the same plan plus its lowered shard_map schedule
    (core.lower, DESIGN.md §8), cached alongside the plan entry;
  * `get_axis_plans(axes, size_floats)` — per-mesh-axis plan selection for
    the training/serving hot paths (launch.train's ZeRO-3 engine,
    core.sync.sync_gradients, core.collectives.allreduce_planned).

Plan generation (GenTree + candidate simulation) costs hundreds of
milliseconds at cluster scale; a warm lookup is a fingerprint hash plus an
LRU probe. With a cache path configured (or $REPRO_PLAN_CACHE), warm plans
persist across restarts.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Mapping, Sequence

from repro.core import gentree as gentree_mod
from repro.core.cost_model import GenModelParams, PAPER_TABLE5
from repro.core.plans import Plan
from repro.core.simulator import Simulator
from repro.core.sync import AxisPlan, plan_axes_gentree
from repro.core.topology import TopoNode

from repro.runtime.metrics import default_metrics
from repro.runtime.telemetry import (LedgerEntry, LevelSample, Telemetry,
                                     TelemetryEvent)
from repro.runtime.trace import default_tracer

from .cache import PlanCache, plan_from_json, plan_to_json
from .calibrate import (CalibrationConfig, CalibrationResult,
                        TelemetryProvider, calibrate_levels)
from .fingerprint import axis_key, plan_key
from .skew import SkewModel, expected_time

DTYPE_BYTES = {"float64": 8, "float32": 4, "int32": 4, "bfloat16": 2,
               "bf16": 2, "float16": 2, "int8": 1,
               "float8_e4m3fn": 1, "fp8": 1}


@dataclass
class PlanResponse:
    plan: Plan
    algo: str                        # "gentree" or a baseline name
    predicted_time: float            # synchronized simulator pricing
    decisions: dict = field(default_factory=dict)   # gentree plans only
    # simulator price + arrival-gated skew delta (skew.pick_plan_under_skew)
    expected_skewed_time: float | None = None
    source: str = "cold"             # cold | memory | disk
    key: str = ""
    nbytes_bucket: int = 0
    size_floats: float = 0.0
    # get_executable only: the lowered schedule (core.lower), cached
    # alongside the plan entry under "_exec" (derived artifact — never
    # persisted; recompiled once per placement after a disk-warm restart)
    schedule: object | None = None


@dataclass(eq=False)
class BucketPlan:
    """get_bucket_plan's answer: the GenModel-argmin gradient bucket size
    for a mesh-axis list, plus one lowered schedule per axis (DESIGN.md
    §9). `sweep` records every candidate's modeled pipelined/serial time
    so benchmarks (and the perf gate) can verify the argmin.

    The pipeline is priced twice (DESIGN.md §15): `predicted_pipelined`
    keeps the optimistic `max(t_rs, t_ag)` steady state (the lower
    bound), `predicted_contended` charges the overlapped RS/AG rounds
    through the per-link occupancy merge — shared links serialize, a
    summed fan-in can cross w_t — and is what the argmin ranks on.
    `overlap` records the argmin over {sequential, merged} issuance for
    one bucket pair; when "merged" wins on a single-axis plan,
    `merged_schedule` carries the lowered `core.overlap.MergedSchedule`
    (derived artifact — rebuilt, never persisted)."""
    axes: tuple[tuple[str, int], ...]     # live axes (n > 1), leaf first
    bucket_floats: int                    # chosen bucket size, in elements
    bucket_bytes: int                     # same, in bytes of the priced dtype
    num_buckets: int                      # for the quoted total size
    axis_plans: list = field(default_factory=list)   # AxisPlan("plan", …)
    predicted_pipelined: float = 0.0      # optimistic double-buffered total
    predicted_serial: float = 0.0         # same buckets, no overlap
    predicted_contended: float = 0.0      # contention-priced pipeline (§15)
    predicted_per_leaf: float | None = None   # per-leaf baseline (if sized)
    pipeline: bool = True
    sweep: dict = field(default_factory=dict)  # bucket_floats -> model row
    overlap: dict = field(default_factory=dict)  # {mode, t_joint, …}
    merged_schedule: object | None = None  # only when overlap mode=="merged"
    precision: str = "f32"                # chosen wire format (DESIGN.md §13)
    source: str = "cold"
    key: str = ""


# Family spellings accepted by the whole-step entry points: HLO op names
# (launch.hlo_analysis) and plan-IR names (core.plans.FAMILIES) both map
# onto the IR spelling.
FAMILY_ALIASES = {
    "all-reduce": "allreduce", "all_reduce": "allreduce",
    "reduce-scatter": "reduce_scatter",
    "all-gather": "allgather", "all_gather": "allgather",
    "all-to-all": "all_to_all", "alltoall": "all_to_all",
    "collective-permute": "p2p",
}


@dataclass(eq=False)
class StepPlan:
    """get_step_plan's answer: every collective family of a training step
    priced JOINTLY under one GenModel basis (DESIGN.md §14).

    `quotes[family]` records, per family in the mix: the per-call
    GenModel breakdown at the call size, the coalesced quote (ONE launch
    of count·size — α amortized, every linear term unchanged), the
    pipelined alternative (count launches with call k's AllGather
    overlapping call k+1's ReduceScatter — the same
    `core.bucketing.pipelined_time` model `get_bucket_plan` uses), and
    which of the two the argmin chose. `total_joint` = Σ family coalesced
    quotes and equals the sum of the stored per-family term breakdowns
    exactly (the pricing-consistency invariant the tests pin at 1e-9);
    `ratio` = best joint total / naïve per-call total ≤ 1 — the
    BENCH_core.json `step_plan_vs_per_call_ratio` gate."""
    axes: tuple[tuple[str, int], ...]    # live axes (n > 1), leaf first
    quotes: dict = field(default_factory=dict)   # family -> quote row
    total_per_call: float = 0.0          # Σ count · per-call quote
    total_joint: float = 0.0             # Σ coalesced quotes
    total_best: float = 0.0              # Σ min(coalesced, pipelined)
    ratio: float = 1.0                   # total_best / total_per_call
    schedules: dict = field(default_factory=dict)  # family -> leaf schedule
    precision: str = "f32"               # chosen wire format (all families)
    source: str = "cold"
    key: str = ""


@dataclass(frozen=True)
class RefitPolicy:
    """When does observed drift trigger an online refit? (DESIGN.md §10)

    A level class refits when its residual tracker holds at least
    `min_samples` post-(re)fit observations AND the drift statistic
    (median |measured − predicted| / predicted) exceeds
    `drift_threshold`. After a refit, `cooldown` fresh observations must
    accumulate before the same level may refit again — the loop must
    converge on measurements of the *new* params, not chase its own
    transient. `enabled=False` keeps observation/telemetry recording but
    never refits (monitor-only deployments).

    `term_attribution=True` makes each refit event carry a per-term
    diagnosis: the cost-ledger window for the level is solved for the
    per-term drift multipliers (`core.fitting.attribute_term_drift`), so
    the event says *which* GenModel term drifted ("δ drifted 3×, α
    stable") instead of only the blind median drift (DESIGN.md §11)."""
    drift_threshold: float = 0.2
    min_samples: int = 8
    cooldown: int = 32
    enabled: bool = True
    term_attribution: bool = True
    # Refit guardrails (DESIGN.md §12): reject NaN/negative/implausible
    # fitted params (`calibrate.validate_params`), clamp per-refit
    # movement of each term to the guard's max_step_ratio
    # (`calibrate.clamp_params`), and quarantine outlier telemetry
    # samples before fitting (`calibrate.quarantine_outliers`, k =
    # `quarantine_k`; None/0 disables). `guardrails=False` restores the
    # pre-§12 trust-the-fit behaviour.
    guardrails: bool = True
    quarantine_k: float = 4.0


def _decisions_to_json(decisions) -> dict:
    return {sw: {"algo": d.algo, "factors": d.factors,
                 "rearrange": {str(k): v for k, v in d.rearrange.items()},
                 "cost": d.cost}
            for sw, d in decisions.items()}


class PlannerService:
    """Thread-safe facade over fingerprint + cache + calibrate + skew."""

    def __init__(self, params: Mapping[str, GenModelParams] | None = None,
                 cache: PlanCache | None = None, *,
                 cache_path: str | None = None, capacity: int = 128,
                 autosave: bool = False,
                 skew: SkewModel | None = None,
                 baseline_kinds: tuple[str, ...] = ("cps", "ring", "rhd"),
                 gentree_kwargs: dict | None = None,
                 engine: str | None = None,
                 telemetry: Telemetry | None = None,
                 refit_policy: RefitPolicy | None = None):
        self.params = dict(params) if params else None
        # `cache or ...` would discard a caller-supplied EMPTY cache
        # (PlanCache defines __len__, so a cold cache is falsy)
        self.cache = cache if cache is not None \
            else PlanCache(capacity=capacity, path=cache_path,
                           autosave=autosave)
        self.skew = skew
        self.baseline_kinds = baseline_kinds
        self.gentree_kwargs = dict(gentree_kwargs or {})
        # plan-evaluation engine for cold generation / re-ranking:
        # "fast" (compiled, default) or "reference" (pure-Python oracle)
        self.engine = engine
        self.calibration: CalibrationResult | None = None
        # closed-loop controller state (DESIGN.md §10): the shared
        # runtime telemetry hub observations land in, the policy that
        # decides when drift triggers a refit, and the refit audit log
        self.telemetry = telemetry or Telemetry()
        self.refit_policy = refit_policy or RefitPolicy()
        # bounded audit log (stats() serializes it; a drifty multi-year
        # deployment must not accumulate an unbounded history)
        self.refits: deque = deque(maxlen=256)
        self._since_refit: dict[str, int] = {}
        # observe hot-path caches (gated < 1% of a simulated step):
        # merged (γ/δ-from-server) level params, exact-size default
        # predictions, and per-level telemetry handles. Entries are
        # tagged with _params_version — a params swap (calibrate/refit)
        # bumps the version, so a concurrent observer that computed
        # against the old basis can never repopulate the cache with
        # stale params after the swap.
        self._params_version = 0
        self._merged_cache: dict[str, tuple[int, GenModelParams]] = {}
        self._pred_cache: dict[tuple, tuple[int, float]] = {}
        # per-shape GenModel term breakdowns (cost_model.CostBreakdown)
        # feeding the cost ledger — same versioning contract as above
        self._shares_cache: dict[tuple, tuple[int, object]] = {}
        self._obs_handles: dict[str, tuple] = {}
        # degraded-level health map (DESIGN.md §12): level class →
        # bandwidth multiplier in (0, 1). Applied to every pricing basis
        # via _apply_health, so a degraded link reprices (β/factor) and
        # refingerprints (the synthesized switch topology's uplink_bw
        # realizes β) without touching the stored params.
        self._degraded: dict[str, float] = {}
        self._lock = threading.RLock()

    # ---- calibration -------------------------------------------------------
    def calibrate(self, source: Mapping[str, GenModelParams] | None = None,
                  cfg: CalibrationConfig | None = None) -> CalibrationResult:
        """Refit GenModelParams from measurements and make the fitted set
        the service's pricing basis. Invalidates nothing explicitly — the
        params fingerprint is part of every cache key, so plans priced
        under the old params simply stop being hit."""
        result = calibrate_levels(source or self.params or PAPER_TABLE5,
                                  cfg)
        with self._lock:
            self.params = dict(result.params)
            self.calibration = result
            self._params_version += 1
            self._merged_cache.clear()
            self._pred_cache.clear()
            self._shares_cache.clear()
        return result

    # ---- degraded-mode health (DESIGN.md §12) ------------------------------
    def _apply_health(self, eff: Mapping[str, GenModelParams]
                      ) -> dict[str, GenModelParams]:
        """The pricing basis with degraded levels repriced: a level at
        bandwidth multiplier f pays β/f per unit. Every axis pricing and
        execution path flows through this, and β determines the
        synthesized switch topology's uplink bandwidth — so a degrade
        changes both the params fingerprint and the topo fingerprint,
        making every plan priced for the healthy link unreachable."""
        if not self._degraded:
            return dict(eff)
        out = dict(eff)
        for lvl, f in self._degraded.items():
            p = out.get(lvl)
            if p is not None and 0.0 < f < 1.0:
                out[lvl] = dataclasses.replace(p, beta=p.beta / f)
        return out

    def mark_degraded(self, level: str, factor: float) -> int:
        """Declare `level`'s links degraded to `factor` × nominal
        bandwidth (0 < factor < 1; ≥ 1 clears). Bumps the params version,
        clears the pricing caches, drops every derived executable and
        opens a telemetry re-measure window — the planner replans around
        the degraded link on the next lookup, under a new fingerprint.
        Returns the number of derived artifacts dropped."""
        factor = float(factor)
        if factor <= 0.0:
            raise ValueError(f"degrade factor must be > 0: {factor}")
        with self._lock:
            if factor >= 1.0:
                self._degraded.pop(level, None)
            else:
                self._degraded[level] = factor
            self._params_version += 1
            self._merged_cache.clear()
            self._pred_cache.clear()
            self._shares_cache.clear()
        dropped = self.invalidate_executables()
        if factor >= 1.0:
            # health restored: re-arm guard ladders pinned to the flat
            # rung by faults that are now gone (DESIGN.md §12). The
            # link_restore path of runtime.ft lands here (it calls
            # mark_degraded(level, 1.0)), so a transient fault stops
            # permanently demoting every schedule it touched.
            from repro.core.lower import reprobe_guards
            reprobe_guards("link_restore")
        m = default_metrics()
        m.counter("planner_degrade_events_total",
                  "level health transitions (degrade/restore)").inc()
        m.gauge("planner_degraded_levels",
                "level classes currently marked degraded"
                ).set(float(len(self._degraded)))
        default_tracer().instant("planner/degrade", level=level,
                                 factor=factor, dropped=dropped)
        # measurements of the healthy link must not steer a refit of the
        # degraded one (and vice versa on restore)
        self.telemetry.remeasure("degrade", {"level": level,
                                             "factor": factor,
                                             "dropped": dropped})
        return dropped

    def clear_degraded(self, level: str | None = None) -> None:
        """Restore `level` (or every level) to nominal health; reprices
        and invalidates exactly like `mark_degraded`."""
        with self._lock:
            levels = [level] if level is not None \
                else list(self._degraded)
        for lvl in levels:
            self.mark_degraded(lvl, 1.0)

    def degraded(self) -> dict[str, float]:
        with self._lock:
            return dict(self._degraded)

    # ---- the online loop: observe -> drift -> refit -> invalidate ----------
    def _effective_axis_params(self) -> dict[str, GenModelParams]:
        """Pricing basis for mesh-axis requests: the axis paths
        (`get_axis_executable`, `get_bucket_plan`) default to TPU_V5E
        when the service is uncalibrated, and observation/refit must
        price against the same basis those paths quoted. Health-adjusted
        (`_apply_health`): a degraded level prices at its sagged β."""
        if self.params is not None:
            return self._apply_health(self.params)
        from repro.core.cost_model import TPU_V5E
        return self._apply_health(TPU_V5E)

    def _merged_level_params(self, level: str,
                             eff: Mapping[str, GenModelParams]
                             ) -> GenModelParams:
        """The level's pricing params with the compute terms (γ/δ) taken
        from the chip ("server") class — exactly how `plan_axes_gentree`
        and the simulator charge them, so CPS-equivalence factors and
        refit targets price the same model the planner does."""
        srv = eff.get("server", GenModelParams())
        p = eff.get(level, srv)
        return dataclasses.replace(p, gamma=srv.gamma, delta=srv.delta)

    def observe(self, level: str, n: int, size_floats: float,
                measured: float, *, predicted: float | None = None,
                key: str | None = None, dtype: str = "float32",
                precision: str | None = None,
                params: Mapping[str, GenModelParams] | None = None) -> dict:
        """Feed one measured collective back into the loop (DESIGN.md
        §10): an AllReduce of `size_floats` data units over a mesh axis
        of `n` devices at Table-5 class `level` took `measured` seconds.

        Records the predicted-vs-measured residual (keyed by `level` and,
        when given, by the plan fingerprint `key`), files the sample as a
        CPS-equivalent calibration point, and — when the level's drift
        exceeds the refit policy — refits that level's `GenModelParams`
        from the accumulated telemetry through the same `core.fitting`
        path as offline calibration. The params swap flows through the
        fingerprints (stale plans become unreachable) and every derived
        `CompiledSchedule`/bucket plan is dropped, so the next lookup
        lowers a fresh schedule under the refitted model: a hot swap,
        never a stale execution.

        `predicted` defaults to the service's own price for that axis at
        the exact size; pass `precision` (a PRECISIONS name) when the
        measured sync ran a compressed wire, so the default prediction
        and the per-term ledger shares price the same compressed plan
        the devices executed (quant passes in γ/δ, shrunk β/incast —
        DESIGN.md §13). A `params` override records timing rings but is
        excluded from refit — per-request overrides are not the
        service's pricing basis, so they must not steer it.

        Returns {"level", "rel_residual", "drift", "samples", "refit"}.
        """
        override = params is not None
        # version read BEFORE the params: a concurrent swap after this
        # point tags our cache writes with the old version, so they are
        # recomputed (never trusted) by post-swap observers
        ver = self._params_version
        eff = dict(params) if override else self._effective_axis_params()
        n = int(n)
        size_floats = max(float(size_floats), 1.0)
        measured = float(measured)
        prec = None
        if precision is not None and precision != "f32":
            from repro.core.cost_model import PRECISIONS
            prec = PRECISIONS[precision]
        pname = prec.name if prec is not None else "f32"
        if predicted is None:
            # exact-size default pricing, memoized per params version:
            # the probe/serve wiring observes the same shapes repeatedly
            # and the full halves pricing (plan lookup + rescale +
            # simulate) must stay off the hot path
            pk = (level, n, round(size_floats, 6), dtype, pname) \
                if not override else None
            cached = None if pk is None else self._pred_cache.get(pk)
            if cached is not None and cached[0] == ver:
                predicted = cached[1]
            else:
                t_rs, t_ag = self._axis_halves_time(n, level, size_floats,
                                                    dtype, eff,
                                                    precision=prec)
                predicted = t_rs + t_ag
                if pk is not None:
                    self._pred_cache[pk] = (ver, predicted)
        # per-level ring + tracker handles resolved once (hot path)
        handles = self._obs_handles.get(level)
        if handles is None:
            handles = (self.telemetry.ring(f"observe/{level}"),
                       self.telemetry.residuals(f"level/{level}"))
            self._obs_handles[level] = handles
        ring, tracker = handles
        ring.add(measured)
        if override:
            # a per-request override is not the service's pricing basis:
            # its residuals are tracked under the plan fingerprint (and
            # the measured ring above) for monitoring, but must not
            # enter the level tracker that steers the refit trigger
            rel = self.telemetry.residuals(
                key and f"plan/{key}" or f"level/{level}@override"
            ).record(predicted, measured)
            return {"level": level, "predicted": float(predicted),
                    "measured": measured, "rel_residual": rel,
                    "refit": False, "drift": tracker.drift(),
                    "samples": 0}
        rel = tracker.record(predicted, measured)
        if key:
            self.telemetry.residuals(f"plan/{key}").record(predicted,
                                                           measured)
        out = {"level": level, "predicted": float(predicted),
               "measured": measured, "rel_residual": rel, "refit": False}

        entry = self._merged_cache.get(level)
        if entry is not None and entry[0] == ver:
            merged = entry[1]
        else:
            merged = self._merged_level_params(level, eff)
            self._merged_cache[level] = (ver, merged)
        from repro.core.fitting import cps_equivalent_time
        self.telemetry.record_sample(level, LevelSample(
            n=n, size_floats=size_floats, measured=measured,
            cps_equivalent=cps_equivalent_time(n, size_floats, measured,
                                               predicted, merged)))
        # cost ledger (DESIGN.md §11): the quoted prediction decomposed
        # into per-term seconds — proportions from the GenModel walk over
        # the executed plan structure, rescaled so they sum to the quoted
        # prediction exactly — filed next to the measured wall time. The
        # breakdown is memoized per shape under the same params-version
        # contract as the prediction itself.
        sk = (level, n, round(size_floats, 6), dtype, pname)
        sentry = self._shares_cache.get(sk)
        if sentry is not None and sentry[0] == ver:
            breakdown = sentry[1]
        else:
            breakdown = self._axis_term_shares(n, level, size_floats,
                                               dtype, eff, merged,
                                               precision=prec)
            self._shares_cache[sk] = (ver, breakdown)
        self.telemetry.ledger.record(LedgerEntry(
            level=level, n=n, size_floats=size_floats,
            predicted=float(predicted), measured=measured,
            shares=breakdown.scaled_to(float(predicted)).as_dict()))
        default_metrics().counter(
            "planner_observations_total",
            "collectives fed back through PlannerService.observe").inc()
        with self._lock:
            self._since_refit[level] = self._since_refit.get(level, 0) + 1
            since = self._since_refit[level]
        out["drift"] = tracker.drift()
        out["samples"] = self.telemetry.sample_count(level)
        pol = self.refit_policy
        refit_now = False
        if pol.enabled and out["drift"] > pol.drift_threshold \
                and tracker.count >= pol.min_samples \
                and self._sample_diversity(level) >= 2 \
                and level not in self._degraded:
            # a degraded level is *known, repriced* state (DESIGN.md
            # §12): its drift reflects the sag the health map already
            # models, so fitting telemetry from it would bake a
            # transient fault into the calibrated params
            # claim the refit under the lock: concurrent observers must
            # not both fit (the second would find the samples consumed)
            with self._lock:
                refitted_before = any(r["level"] == level
                                      for r in self.refits)
                need = max(pol.cooldown, pol.min_samples) \
                    if refitted_before else pol.min_samples
                if self._since_refit.get(level, 0) >= need:
                    self._since_refit[level] = 0
                    refit_now = True
        if refit_now:
            res = self._refit_level(level, drift=out["drift"],
                                    observations=since)
            out.update(res)
            # a guardrail rejection is not a refit: the pricing basis
            # did not change (DESIGN.md §12)
            out["refit"] = not res.get("rejected")
        return out

    def _sample_diversity(self, level: str) -> int:
        """Distinct (n, size) points among the level's telemetry samples.
        A fit from one repeated point would be rank-deficient (the
        provider refuses it too) — a deployment observing a single shape
        (e.g. serve's fixed decode size) reports drift but never swaps
        in degenerate params."""
        return len({(s.n, round(s.size_floats, 6))
                    for s in self.telemetry.samples(level)})

    def _refit_level(self, level: str, *, drift: float,
                     observations: int) -> dict:
        """Refit one level class from accumulated telemetry and hot-swap:
        new params → new fingerprints (stale plans unreachable) AND every
        derived executable artifact dropped (`PlanCache.drop_derived`
        via `core.bucketing.invalidate_schedules`), so no stale
        `CompiledSchedule` can ever execute after the swap."""
        from repro.core.bucketing import invalidate_schedules

        tracer = default_tracer()
        metrics = default_metrics()
        # diagnose BEFORE the fit consumes the window: solve the level's
        # cost-ledger entries for per-term drift multipliers so the refit
        # event names the drifting term (m_t ≈ 1 → stable; see
        # core.fitting.attribute_term_drift and DESIGN.md §11)
        term_drift = None
        if self.refit_policy.term_attribution:
            entries = self.telemetry.ledger.entries(level)
            if entries:
                from repro.core.fitting import attribute_term_drift
                term_drift = attribute_term_drift(
                    [e.shares for e in entries],
                    [e.measured for e in entries])
        eff = self._effective_axis_params()
        # the fit's Fig.-4 fallback must pin the γ/δ the pricing paths
        # actually charge (the chip class), not the level's own defaults
        source = dict(eff)
        source[level] = self._merged_level_params(level, eff)
        pol = self.refit_policy
        provider = TelemetryProvider(self.telemetry,
                                     min_samples=pol.min_samples,
                                     quarantine_k=(pol.quarantine_k
                                                   if pol.guardrails
                                                   else None))
        with tracer.span("planner/refit", level=level, drift=drift):
            result = calibrate_levels(source,
                                      CalibrationConfig(levels=(level,)),
                                      provider=provider)
            fitted = result.params[level]
            clamped: list[str] = []
            if pol.guardrails:
                # refit guardrails (DESIGN.md §12): a NaN/negative/
                # implausible fit never becomes the fleet's pricing
                # basis, and a plausible one moves each term by at most
                # the guard's step ratio per refit
                from .calibrate import clamp_params, validate_params
                violations = validate_params(fitted)
                if violations:
                    return self._reject_refit(level, drift=drift,
                                              observations=observations,
                                              violations=violations,
                                              term_drift=term_drift)
                # clamp against the merged (γ/δ-from-server) basis the
                # fit targeted and the pricing paths charge — clamping
                # against the raw level row would "correct" the compute
                # terms back toward the level's defaults on every refit
                fitted, clamped = clamp_params(
                    self._merged_level_params(level, eff), fitted)
                if clamped:
                    metrics.counter(
                        "planner_refit_params_clamped_total",
                        "fitted terms clamped to the per-refit movement "
                        "bound").inc(len(clamped))
                result.params[level] = fitted
            with self._lock:
                # swap basis = the RAW stored params, not the health-
                # adjusted eff: a transient degrade must never be baked
                # into the calibrated params it overlays
                if self.params is not None:
                    base = dict(self.params)
                else:
                    from repro.core.cost_model import TPU_V5E
                    base = dict(TPU_V5E)
                base[level] = fitted
                self.params = base
                self.calibration = result
                self._params_version += 1
                self._merged_cache.clear()
                self._pred_cache.clear()
                self._shares_cache.clear()
            dropped = invalidate_schedules(self)
        # post-swap: old residuals, samples and ledger rows were measured
        # against the pre-refit params — drift detection restarts from
        # fresh data
        self.telemetry.clear_samples(level)
        self.telemetry.residuals(f"level/{level}").reset()
        self.telemetry.ledger.clear(level)
        event = {"level": level, "drift": drift,
                 "observations": observations, "dropped": dropped,
                 "term_drift": term_drift, "clamped": clamped,
                 "quarantined": provider.quarantined,
                 "params": dataclasses.asdict(result.params[level])}
        self.refits.append(event)
        self.telemetry.events.append(
            TelemetryEvent("refit", {"level": level, "drift": drift,
                                     "dropped": dropped,
                                     "term_drift": term_drift}))
        metrics.counter("planner_refits_total",
                        "online GenModel refits triggered by drift").inc()
        metrics.gauge("planner_params_version",
                      "pricing-basis version (bumps on calibrate/refit)"
                      ).set(self._params_version)
        return {"dropped": dropped, "term_drift": term_drift}

    def _reject_refit(self, level: str, *, drift: float,
                      observations: int, violations: list,
                      term_drift) -> dict:
        """Guardrail rejection (DESIGN.md §12): the fit produced garbage
        (NaN / negative / implausible terms), so the pricing basis stays
        untouched. The poisoned sample window is discarded — the next
        refit attempt must argue from fresh measurements, and the
        cooldown applies (the rejection is logged in the audit deque the
        trigger consults) so a persistent fault can't hammer the fitter.
        """
        self.telemetry.clear_samples(level)
        self.telemetry.residuals(f"level/{level}").reset()
        self.telemetry.ledger.clear(level)
        event = {"level": level, "drift": drift,
                 "observations": observations, "dropped": 0,
                 "term_drift": term_drift, "rejected": violations}
        self.refits.append(event)
        self.telemetry.events.append(
            TelemetryEvent("refit_rejected",
                           {"level": level, "drift": drift,
                            "violations": violations}))
        default_metrics().counter(
            "planner_refits_rejected_total",
            "refits rejected by the param guardrails").inc()
        default_tracer().instant("planner/refit_rejected", level=level,
                                 violations=len(violations))
        return {"dropped": 0, "term_drift": term_drift,
                "rejected": violations}

    def observe_arrivals(self, arrivals) -> None:
        """Record one collective's per-device arrival times into the
        telemetry arrival estimator (feeds the empirical skew mode)."""
        self.telemetry.record_arrivals(arrivals)

    def adopt_empirical_skew(self, *, draws: int = 8, seed: int = 0,
                             min_collectives: int = 1) -> SkewModel | None:
        """Swap the service's skew model for an *empirical* one built
        from measured per-device arrival offsets (`SkewModel.
        from_offsets`). The skew key is part of every plan fingerprint,
        so plans re-ranked under synthetic (or no) skew stop being hit
        and the next lookup re-prices under the measured arrival
        pattern. Returns the adopted model, or None when telemetry has
        no usable offsets yet."""
        est = self.telemetry.arrivals
        if est.n_devices < 2 or est.count < min_collectives:
            return None
        model = SkewModel.from_offsets(est.offsets(), draws=draws,
                                       seed=seed)
        with self._lock:
            self.skew = model
        return model

    # ---- full-topology plans ----------------------------------------------
    def _effective_params(self) -> dict[str, GenModelParams]:
        return self.params or PAPER_TABLE5

    def get_plan(self, topo: TopoNode, nbytes: int | float,
                 dtype: str = "float32", *,
                 params: Mapping[str, GenModelParams] | None = None
                 ) -> PlanResponse:
        """`params` overrides the service's pricing basis for this request
        only (e.g. SyncConfig.params); the override is part of the cache
        key, so differently-priced requests never share an entry."""
        topo.finalize()
        dsize = DTYPE_BYTES.get(dtype, 4)
        bucket = self.cache.bucket(nbytes)
        size_floats = float(bucket) / dsize
        params = dict(params) if params else self._effective_params()
        extra = (tuple(sorted(self.gentree_kwargs.items())),
                 self.skew.key() if self.skew else None)
        key = plan_key(topo, params, bucket, dtype, extra=extra)

        entry = self.cache.get(key)
        if entry is not None:
            obj = entry.get("_obj")
            source = "memory" if obj is not None else "disk"
            plan = obj if obj is not None else plan_from_json(entry["plan"])
            if obj is None:
                entry["_obj"] = plan
            return PlanResponse(
                plan=plan, algo=entry["algo"],
                predicted_time=entry["predicted_time"],
                decisions=entry.get("decisions", {}),
                expected_skewed_time=entry.get("expected_skewed_time"),
                source=source, key=key, nbytes_bucket=bucket,
                size_floats=size_floats)

        # ---- cold path: generate, (optionally) re-rank under skew --------
        with default_tracer().span("planner/generate_plan",
                                   servers=topo.num_servers(),
                                   bucket=bucket):
            result = gentree_mod.gentree(topo, size_floats, params=params,
                                         engine=self.engine,
                                         **self.gentree_kwargs)
            algo, plan = "gentree", result.plan
            decisions = _decisions_to_json(result.decisions)
            skewed = None
            if self.skew is not None and self.skew.scale > 0.0:
                candidates = [("gentree", result.plan)]
                n = topo.num_servers()
                for kind in self.baseline_kinds:
                    if kind == "rhd" and (n & (n - 1)) != 0:
                        continue
                    if n < 2:
                        continue
                    candidates.append(
                        (kind, gentree_mod.baseline_plan(kind, topo,
                                                         size_floats)))
                from .skew import pick_plan_under_skew
                algo, plan, skewed = pick_plan_under_skew(
                    candidates, topo, self.skew, params, unit_bytes=dsize,
                    engine=self.engine)
                if algo != "gentree":
                    # per-switch decisions describe the discarded GenTree
                    # plan, not the baseline that won — don't mis-report
                    # them
                    decisions = {}
            sim = Simulator(topo, params, unit_bytes=dsize,
                            engine=self.engine)
            predicted = sim.simulate(plan).total

            entry = {"plan": plan_to_json(plan), "algo": algo,
                     "predicted_time": predicted, "decisions": decisions,
                     "expected_skewed_time": skewed,
                     "nbytes_bucket": bucket, "_obj": plan}
            self.cache.put(key, entry)
        return PlanResponse(plan=plan, algo=algo, predicted_time=predicted,
                            decisions=decisions, expected_skewed_time=skewed,
                            source="cold", key=key, nbytes_bucket=bucket,
                            size_floats=size_floats)

    # ---- executable plans (lowered schedules) ------------------------------
    def _config_extra(self) -> tuple:
        return (tuple(sorted(self.gentree_kwargs.items())), self.engine)

    def get_executable(self, topo: TopoNode, nbytes: int | float,
                       dtype: str = "float32", *, placement=None,
                       params: Mapping[str, GenModelParams] | None = None
                       ) -> PlanResponse:
        """`get_plan` + the plan lowered to an executable shard_map
        schedule (core.lower.CompiledSchedule, DESIGN.md §8).

        Cache contract: the schedule is a derived artifact stored on the
        plan's cache entry under `_exec`, keyed by the placement map — it
        shares the entry's lifetime (LRU eviction or recalibration drops
        plan and schedule together) and is never written to disk; a
        disk-warm plan is re-lowered once per placement. Raises
        `core.lower.LoweringError` if the cached plan is structurally
        invalid or predates block annotations.
        """
        from repro.core.lower import lower_plan
        resp = self.get_plan(topo, nbytes, dtype, params=params)
        pkey = ("default" if placement is None
                else tuple(sorted(dict(placement).items()))
                if isinstance(placement, Mapping)
                else tuple(placement))
        with self._lock:
            entry = self.cache.get(resp.key)
            execs = None if entry is None else entry.setdefault("_exec", {})
            sched = None if execs is None else execs.get(pkey)
            if sched is None:
                sched = lower_plan(resp.plan, placement=placement)
                if execs is not None:
                    execs[pkey] = sched
        resp.schedule = sched
        return resp

    def get_axis_executable(self, axis_name: str, n: int,
                            size_floats: float,
                            dtype: str = "float32", *,
                            topo: TopoNode | None = None,
                            level: str = "root_sw",
                            params: Mapping[str, GenModelParams] | None
                            = None) -> PlanResponse:
        """Executable plan for one mesh axis: the axis is modelled as a
        single-switch topology of `n` servers (pass `topo` for the real
        physical tree) and the GenTree plan is lowered with the identity
        placement — mesh position i executes server i's schedule.

        `level` is the axis's Table-5 class (leaf/ICI axis → "root_sw",
        outer/DCI axes → "cross_dc" — `core.sync.axis_level` maps mesh
        positions), and `params` optionally overrides the service's
        pricing basis (SyncConfig.params): the synthesized switch's uplink
        bandwidth realizes that level's β, exactly as
        `plan_axes_gentree` prices the same axis, so the executed plan is
        the one the model actually argues for."""
        eff = dict(params) if params else self.params
        if eff is None:
            from repro.core.cost_model import TPU_V5E
            eff = TPU_V5E
        # health-adjust AFTER the override resolution: a degraded link is
        # a property of the fleet, not of the request, so per-request
        # params overrides still price (and replan) around it
        eff = self._apply_health(eff)
        if topo is None:
            from repro.core.sync import level_switch_topo
            topo = level_switch_topo(int(n), eff, level)
        dsize = DTYPE_BYTES.get(dtype, 4)
        return self.get_executable(topo, max(size_floats, 1.0) * dsize,
                                   dtype, params=eff)

    def get_family_executable(self, family: str, axis_name: str, n: int,
                              size_floats: float, dtype: str = "float32",
                              *, level: str = "root_sw",
                              params: Mapping[str, GenModelParams] | None
                              = None) -> PlanResponse:
        """Executable schedule for ONE collective family on one mesh axis
        (DESIGN.md §14).

        allreduce delegates to `get_axis_executable`. reduce_scatter /
        allgather lower the matching half of the SAME GenTree AllReduce
        plan the axis would run (`plans.family_halves`) — co-planned with
        allreduce by construction, cached on that plan's entry under a
        family-keyed `_exec` slot (same lifetime/invalidation as every
        derived schedule). all_to_all / p2p schedules are structurally
        size-independent (one full-mesh / one shift round whatever the
        payload), so they memoize per (family, n) on the service and are
        dropped by `invalidate_executables` like any executable."""
        from repro.core import plans as plans_mod
        from repro.core.cost_model import evaluate_plan
        from repro.core.lower import lower_plan
        from repro.core.sync import level_switch_topo

        family = FAMILY_ALIASES.get(family, family)
        if family == "allreduce":
            return self.get_axis_executable(axis_name, int(n), size_floats,
                                            dtype, level=level,
                                            params=params)
        eff = dict(params) if params else self.params
        if eff is None:
            from repro.core.cost_model import TPU_V5E
            eff = TPU_V5E
        eff = self._apply_health(eff)
        merged = self._merged_level_params(level, eff)
        size_floats = max(float(size_floats), 1.0)
        n = int(n)

        if family in ("reduce_scatter", "allgather"):
            topo = level_switch_topo(n, eff, level)
            dsize = DTYPE_BYTES.get(dtype, 4)
            resp = self.get_plan(topo, size_floats * dsize, dtype,
                                 params=eff)
            rs_half, ag_half = plans_mod.family_halves(resp.plan)
            half = rs_half if family == "reduce_scatter" else ag_half
            fkey = ("family", family)
            with self._lock:
                entry = self.cache.get(resp.key)
                execs = (None if entry is None
                         else entry.setdefault("_exec", {}))
                sched = None if execs is None else execs.get(fkey)
                if sched is None:
                    sched = lower_plan(half)
                    if execs is not None:
                        execs[fkey] = sched
            out = dataclasses.replace(
                resp, plan=half, algo=f"{resp.algo}:{family}",
                predicted_time=evaluate_plan(half, merged))
            out.schedule = sched
            return out

        if family in ("all_to_all", "p2p"):
            build = (plans_mod.alltoall_plan if family == "all_to_all"
                     else plans_mod.p2p_plan)
            plan = build(n, size_floats)
            skey = (family, n)
            with self._lock:
                scheds = self.__dict__.setdefault("_family_scheds", {})
                sched = scheds.get(skey)
                if sched is None:
                    sched = lower_plan(plan)
                    scheds[skey] = sched
            return PlanResponse(
                plan=plan, algo=family,
                predicted_time=evaluate_plan(plan, merged),
                key=f"family:{family}:{n}", size_floats=size_floats,
                schedule=sched)

        raise ValueError(f"unknown collective family {family!r} "
                         f"(expected one of {plans_mod.FAMILIES})")

    # ---- bucket plans (gradient bucketing + pipelined execution) -----------
    @staticmethod
    def _scaled_plan(plan: Plan, f: float) -> Plan:
        """The same plan structure at f× the data size (every transfer
        and reduce scales linearly; block annotations are size-free)."""
        from repro.core.plans import Step
        steps = []
        for st in plan.steps:
            s = Step()
            s.transfers = [dataclasses.replace(t, size=t.size * f)
                           for t in st.transfers]
            s.reduces = [dataclasses.replace(r, size=r.size * f)
                         for r in st.reduces]
            steps.append(s)
        return Plan(plan.name, plan.n, plan.size * f, steps=steps,
                    servers=plan.servers, num_blocks=plan.num_blocks,
                    family=plan.family)

    def _axis_halves_time(self, n: int, level: str, size_floats: float,
                          dtype: str, eff,
                          precision=None) -> tuple[float, float]:
        """(T_RS, T_AG) of the axis's GenTree plan at `size_floats`: the
        per-step simulator costs split at the ReduceScatter boundary (the
        last folding step — the same boundary `core.lower` executes).

        The plan *structure* comes from the size-bucketed cache entry,
        rescaled to the exact requested size before simulation — so the
        per-leaf baseline is priced at true leaf sizes instead of being
        inflated by geometric-bucket snapping (the power-of-two sweep
        candidates snap to themselves, factor 1).

        `precision` (a `cost_model.Precision`) reprices the same plan for
        a compressed wire via `compressed_plan`: β/ε shrink with the wire
        bytes, γ/δ pick up the quant passes (DESIGN.md §13)."""
        from repro.core.sync import level_switch_topo
        topo = level_switch_topo(int(n), eff, level)
        dsize = DTYPE_BYTES.get(dtype, 4)
        size_floats = max(size_floats, 1.0)
        resp = self.get_plan(topo, size_floats * dsize, dtype, params=eff)
        plan = resp.plan
        factor = size_floats / resp.size_floats if resp.size_floats \
            else 1.0
        if abs(factor - 1.0) > 1e-12:
            plan = self._scaled_plan(plan, factor)
        if precision is not None and precision.name != "f32":
            from repro.core.cost_model import compressed_plan
            plan = compressed_plan(plan, precision)
        res = Simulator(topo, eff, unit_bytes=dsize,
                        engine=self.engine).simulate(plan)
        folds = [i for i, st in enumerate(plan.steps) if st.reduces]
        split = folds[-1] if folds else len(plan.steps) - 1
        return (float(sum(res.per_step[:split + 1])),
                float(sum(res.per_step[split + 1:])))

    def _axis_contended_time(self, n: int, level: str,
                             size_floats: float, dtype: str, eff,
                             precision=None) -> float:
        """Joint time of the axis plan's RS half run CONCURRENTLY with
        its AG half, paired round-by-round under the per-link occupancy
        merge (DESIGN.md §15) — the steady-state cost of bucket k's
        ReduceScatter overlapping bucket k−1's AllGather. Shared links
        serialize their β/ε and the summed receive fan-in prices through
        one `_incast` call, so the result sits in
        [max(T_RS, T_AG), T_RS + T_AG] — and an above-threshold summed
        fan-in pushes it toward (or past) the sequential sum, which is
        exactly the signal the {sequential, merged} argmin keys on.

        Same plan fetch / rescale / wire-compression path as
        `_axis_halves_time`; the engine choice mirrors `Simulator`
        (reference walks `cost_model.contended_pair_time`, anything else
        the vectorized `FastEngine.contended_halves_total` — the two
        agree ≤ 1e-9, pinned by tests/test_overlap.py)."""
        from repro.core import plans as plans_mod
        from repro.core.sync import level_switch_topo
        topo = level_switch_topo(int(n), eff, level)
        dsize = DTYPE_BYTES.get(dtype, 4)
        size_floats = max(size_floats, 1.0)
        resp = self.get_plan(topo, size_floats * dsize, dtype, params=eff)
        plan = resp.plan
        factor = size_floats / resp.size_floats if resp.size_floats \
            else 1.0
        if abs(factor - 1.0) > 1e-12:
            plan = self._scaled_plan(plan, factor)
        if precision is not None and precision.name != "f32":
            from repro.core.cost_model import compressed_plan
            plan = compressed_plan(plan, precision)
        if plan.family != "allreduce" or not plan.steps:
            res = Simulator(topo, eff, unit_bytes=dsize,
                            engine=self.engine).simulate(plan)
            return float(sum(res.per_step))
        rs_half, ag_half = plans_mod.family_halves(plan)
        if self.engine == "reference":
            from repro.core.cost_model import contended_pair_time
            t = contended_pair_time(topo, rs_half, ag_half, eff,
                                    unit_bytes=dsize)
        else:
            from repro.core.simfast import FastEngine
            t = FastEngine(topo, eff, unit_bytes=dsize
                           ).contended_halves_total(rs_half, ag_half)
        # which links serialized: surfaced as a gauge + span attributes so
        # a Chrome trace of the sweep shows the contention hot spot
        if rs_half.steps and ag_half.steps:
            from repro.core.overlap import occupancy_summary
            summ = occupancy_summary(topo, rs_half.steps[0],
                                     ag_half.steps[0], unit_bytes=dsize)
            default_metrics().gauge(
                "planner_contended_busiest_link_units",
                "traffic units on the busiest link when RS and AG "
                "rounds of adjacent buckets overlap").set(
                float(summ["busiest_link_units"]))
            with default_tracer().span(
                    "planner/contended_price", n=int(n), level=level,
                    links_shared=int(summ["links_shared"]),
                    busiest_link=int(summ["busiest_link"]),
                    busiest_link_units=float(summ["busiest_link_units"])):
                pass
        return float(t)

    def _axis_term_shares(self, n: int, level: str, size_floats: float,
                          dtype: str, eff, merged: GenModelParams,
                          precision=None):
        """GenModel per-term breakdown (`cost_model.CostBreakdown`) of the
        axis's plan at the exact size — the *proportions* side of the cost
        ledger. Same plan fetch + rescale as `_axis_halves_time`, but
        priced by the single-switch term walk (`evaluate_plan_terms`)
        under the merged (γ/δ-from-server) level params, so each term is
        attributed the way the planner charges it. With a `precision` the
        quant passes land in γ/δ and the shrunk wire in β/ε, keeping the
        per-term drift attribution honest on compressed syncs. The caller
        rescales the breakdown to the quoted prediction (`scaled_to`)."""
        from repro.core.cost_model import evaluate_plan_terms
        from repro.core.sync import level_switch_topo
        topo = level_switch_topo(int(n), eff, level)
        dsize = DTYPE_BYTES.get(dtype, 4)
        size_floats = max(size_floats, 1.0)
        resp = self.get_plan(topo, size_floats * dsize, dtype, params=eff)
        plan = resp.plan
        factor = size_floats / resp.size_floats if resp.size_floats \
            else 1.0
        if abs(factor - 1.0) > 1e-12:
            plan = self._scaled_plan(plan, factor)
        return evaluate_plan_terms(plan, merged, precision=precision)

    def get_bucket_plan(self, axes: Sequence[tuple[str, int]],
                        total_floats: float, dtype: str = "float32", *,
                        params: Mapping[str, GenModelParams] | None = None,
                        config=None,
                        leaf_sizes: Sequence[int] | None = None
                        ) -> BucketPlan:
        """GenModel-argmin gradient bucket size for a DP-axis list, with
        one lowered `CompiledSchedule` per axis (DESIGN.md §9).

        Sweeps powers-of-two bucket sizes (plus the monolithic
        single-bucket candidate) JOINTLY with the wire precision
        (DESIGN.md §13): each (bucket, precision) candidate is priced per
        axis with the configured engine — per-bucket α, the γ/δ
        memory-access terms (including the quant/dequant passes), the
        compressed β and incast all come from GenModel itself — and the
        double-buffered pipeline is modeled
        (`core.bucketing.pipelined_time`: bucket k's AllGather overlaps
        bucket k+1's ReduceScatter). The schedules are resolved via
        `get_axis_executable` for the chosen size only (bound to the
        chosen wire via `CompiledSchedule.with_wire`), so they live on
        that size class's plan entry — lowered once, never re-lowered per
        step. Pass `leaf_sizes` to also model the per-leaf (unbucketed)
        baseline for comparison.

        `config.bucket_bytes` pins the bucket size (the sweep collapses
        to that single candidate, still priced); `config.precision` pins
        the wire format and `config.tolerance` is the error-budget guard
        — with no tolerance the sweep stays lossless, and a pinned
        precision whose budget exceeds the tolerance clamps to f32
        (`cost_model.resolve_precision`). Axes with n == 1 are skipped
        but keep their mesh level, exactly as
        `core.sync.resolve_axis_plans` enumerates.
        """
        import math

        from repro.core.bucketing import (BucketConfig,
                                          contended_pipelined_time,
                                          pipelined_time, serial_time)
        from repro.core.cost_model import (PRECISIONS, allowed_precisions,
                                           resolve_precision)
        from repro.core.sync import AxisPlan, axis_level

        cfg = config or BucketConfig()
        if cfg.precision is not None:
            prec_cands = [resolve_precision(cfg.precision, cfg.tolerance)]
        else:
            prec_cands = allowed_precisions(cfg.tolerance) \
                or [PRECISIONS["f32"]]
        axes = tuple((str(a), int(n)) for a, n in axes)
        live = [(i, a, n) for i, (a, n) in enumerate(axes) if n > 1]
        eff = dict(params) if params else self.params
        if eff is None:
            from repro.core.cost_model import TPU_V5E
            eff = TPU_V5E
        eff = self._apply_health(eff)
        dsize = DTYPE_BYTES.get(dtype, 4)
        total = max(float(total_floats), 1.0)
        leaf_key = (tuple(int(s) for s in leaf_sizes)
                    if leaf_sizes is not None else None)
        key = axis_key(axes, eff, self.cache.bucket(total * dsize),
                       extra=self._config_extra()
                       + ("bucket_plan", cfg.key(), dtype, leaf_key,
                          self.skew.key() if self.skew else None))

        def resolve_axis_plans(bucket_floats: int, prec_name: str = "f32"):
            # hierarchical sizes: the RS chain runs the leaf axis first,
            # so axis k's schedule only ever sees bucket / prod(earlier
            # n) elements — resolve (and price) each axis at the size it
            # actually executes
            wire = PRECISIONS[prec_name] if prec_name != "f32" else None
            out, shard = [], float(bucket_floats)
            for i, a, n in live:
                sched = self.get_axis_executable(
                    a, n, shard, dtype, level=axis_level(i),
                    params=eff).schedule
                if wire is not None:
                    # wire-bound copy lives on the returned BucketPlan (not
                    # the shared size-class entry), so the guard ladder's
                    # per-wire demotion state persists across steps without
                    # leaking into full-precision users of the same plan
                    sched = sched.with_wire(wire)
                out.append(AxisPlan(a, "plan", schedule=sched))
                shard /= n
            return out

        def resolve_merged(plans_list, overlap_info):
            # The merged executable interleaves bucket k's RS rounds with
            # bucket k-1's AG rounds of the SAME axis schedule
            # (core.overlap.merge_schedules memoizes on the schedule, so
            # warm hits share the wrapper). Only built when the contended
            # price beat sequential AND the chain is a single live axis —
            # multi-axis chains keep sequential issuance (the hierarchical
            # handoff already serializes at the axis boundary).
            if overlap_info.get("mode") != "merged" or len(plans_list) != 1:
                return None
            from repro.core.lower import LoweringError
            from repro.core.overlap import merge_schedules
            try:
                sched = plans_list[0].schedule
                return merge_schedules(sched, sched)
            except LoweringError:
                return None

        # one sweep per key: concurrent cold traces against a shared service
        # must not each run the full pricing sweep and race on the schedules
        with self._lock:
            entry = self.cache.get(key)
            if entry is not None:
                obj = entry.get("_obj")
                if obj is not None:
                    return dataclasses.replace(obj, source="memory")
                # disk-warm (or schedule-invalidated) entry: the choice is
                # recorded; only the schedules need re-resolving
                prec_name = str(entry.get("precision", "f32"))
                # pre-§15 snapshots carry no contended quote / overlap
                # verdict: fall back to the optimistic pipeline time and
                # sequential issuance rather than invalidating the entry
                ov = dict(entry.get("overlap") or {})
                plans_list = resolve_axis_plans(
                    int(entry["bucket_floats"]), prec_name)
                obj = BucketPlan(
                    axes=tuple((a, n) for _, a, n in live),
                    bucket_floats=int(entry["bucket_floats"]),
                    bucket_bytes=int(entry["bucket_floats"]) * dsize,
                    num_buckets=int(entry["num_buckets"]),
                    axis_plans=plans_list,
                    predicted_pipelined=entry["pipelined"],
                    predicted_serial=entry["serial"],
                    predicted_contended=float(
                        entry.get("contended", entry["pipelined"])),
                    predicted_per_leaf=entry.get("per_leaf"),
                    pipeline=bool(entry.get("pipeline", True)),
                    sweep={int(b): row for b, row in entry["sweep"].items()},
                    overlap=ov,
                    merged_schedule=resolve_merged(plans_list, ov),
                    precision=prec_name, source="disk", key=key)
                entry["_obj"] = obj
                return obj

            if not live:
                obj = BucketPlan(axes=(), bucket_floats=int(total),
                                 bucket_bytes=int(total) * dsize,
                                 num_buckets=0, pipeline=cfg.pipeline,
                                 source="cold", key=key)
                self.cache.put(key, {"kind": "bucket_plan",
                                     "bucket_floats": int(total),
                                     "num_buckets": 0, "pipelined": 0.0,
                                     "serial": 0.0, "per_leaf": None,
                                     "pipeline": cfg.pipeline, "sweep": {},
                                     "_obj": obj})
                return obj

            # ---- candidate sweep (all pricing through the plan cache) --------
            halves_memo: dict[tuple, tuple[float, float]] = {}
            joint_memo: dict[tuple, float] = {}

            def halves(i: int, n: int, size_floats: float, prec=None):
                lvl = axis_level(i)
                pname = prec.name if prec is not None else "f32"
                mk = (lvl, n, round(max(float(size_floats), 1.0), 6), pname)
                if mk not in halves_memo:
                    halves_memo[mk] = self._axis_halves_time(
                        n, lvl, float(size_floats), dtype, eff,
                        precision=prec)
                return halves_memo[mk]

            def joint(i: int, n: int, size_floats: float, prec=None):
                lvl = axis_level(i)
                pname = prec.name if prec is not None else "f32"
                mk = (lvl, n, round(max(float(size_floats), 1.0), 6), pname)
                if mk not in joint_memo:
                    joint_memo[mk] = self._axis_contended_time(
                        n, lvl, float(size_floats), dtype, eff,
                        precision=prec)
                return joint_memo[mk]

            if cfg.bucket_bytes:
                cands = [max(1, int(cfg.bucket_bytes) // dsize)]
            else:
                cands, nbytes = [], max(cfg.min_bucket_bytes, 4096)
                while nbytes < total * dsize and nbytes <= cfg.max_bucket_bytes:
                    cands.append(max(1, nbytes // dsize))
                    nbytes *= 2
                cands.append(int(math.ceil(total)))    # monolithic: K = 1

            # the honest rank: the contended pipeline estimate (per-link
            # occupancy merge, DESIGN.md §15) replaces the optimistic
            # max(t_rs, t_ag) steady state; the naive "pipelined" row
            # rides along as the lower bound + drift metric
            # (overlap_bench's contended_vs_naive_pipeline_error)
            rank = "contended" if cfg.pipeline else "serial"
            sweep: dict[int, dict] = {}
            with default_tracer().span("planner/bucket_sweep",
                                       candidates=len(cands)
                                       * len(prec_cands)):
                for bf in cands:
                    k = max(1, math.ceil(total / bf))
                    best = None
                    for prec in prec_cands:
                        t_rs = t_ag = t_joint = 0.0
                        shard = float(bf)
                        for i, _a, n in live:
                            rs, ag = halves(i, n, shard, prec)
                            t_rs += rs
                            t_ag += ag
                            if k > 1:
                                t_joint += joint(i, n, shard, prec)
                            shard /= n  # outer axes see inner axes' shard
                        row = {
                            "num_buckets": k, "t_rs": t_rs, "t_ag": t_ag,
                            "t_joint": t_joint,
                            "pipelined": pipelined_time(t_rs, t_ag, k),
                            "contended": contended_pipelined_time(
                                t_rs, t_ag, k,
                                t_joint if k > 1 else None),
                            "serial": serial_time(t_rs, t_ag, k),
                            "precision": prec.name,
                        }
                        # ties break toward fewer bits dropped (f32 first
                        # in allowed_precisions order)
                        if best is None or row[rank] < best[rank]:
                            best = row
                    # t_rs/t_ag/t_joint ride along so consumers
                    # (bucket_bench's CI gate) can recompute the pipeline
                    # model independently instead of tautologically
                    # re-minimizing the stored totals; rows stay keyed by
                    # bucket size, each holding its own argmin over wire
                    # precisions
                    sweep[bf] = best
            chosen = min(sweep, key=lambda b: (sweep[b][rank], b))
            prec_name = str(sweep[chosen].get("precision", "f32"))
            crow = sweep[chosen]
            # per-pair issuance argmin: merge bucket k's RS with bucket
            # k-1's AG only when the contended concurrent price strictly
            # beats running the pair back-to-back — the planner can prove
            # it never selects a losing merge (tests/test_overlap.py)
            t_pair_seq = float(crow["t_rs"] + crow["t_ag"])
            merged_wins = bool(cfg.pipeline
                               and int(crow["num_buckets"]) > 1
                               and crow["t_joint"] > 0.0
                               and crow["t_joint"] < t_pair_seq)
            overlap = {
                "mode": "merged" if merged_wins else "sequential",
                "t_joint": float(crow["t_joint"]),
                "t_pair_sequential": t_pair_seq,
                "t_pair_naive": float(max(crow["t_rs"], crow["t_ag"])),
            }

            per_leaf = None
            if leaf_sizes is not None:
                per_leaf = 0.0
                for s in leaf_sizes:
                    if s <= 0:
                        continue
                    shard = float(s)
                    for i, _a, n in live:
                        rs, ag = halves(i, n, shard)
                        per_leaf += rs + ag
                        shard /= n

            plans_list = resolve_axis_plans(int(chosen), prec_name)
            obj = BucketPlan(
                axes=tuple((a, n) for _, a, n in live),
                bucket_floats=int(chosen), bucket_bytes=int(chosen) * dsize,
                num_buckets=int(crow["num_buckets"]),
                axis_plans=plans_list,
                predicted_pipelined=crow["pipelined"],
                predicted_serial=crow["serial"],
                predicted_contended=crow["contended"],
                predicted_per_leaf=per_leaf, pipeline=cfg.pipeline,
                sweep=sweep, overlap=overlap,
                merged_schedule=resolve_merged(plans_list, overlap),
                precision=prec_name, source="cold", key=key)
            self.cache.put(key, {
                "kind": "bucket_plan", "bucket_floats": int(chosen),
                "num_buckets": int(crow["num_buckets"]),
                "pipelined": crow["pipelined"],
                "contended": crow["contended"],
                "serial": crow["serial"], "per_leaf": per_leaf,
                "pipeline": cfg.pipeline, "precision": prec_name,
                "overlap": overlap,
                "sweep": {str(b): row for b, row in sweep.items()},
                "_obj": obj})
            return obj

    # ---- whole-step co-planning (every collective family) ------------------
    def _family_axis_terms(self, family: str, i: int, n: int,
                           size_floats: float, dtype: str, eff,
                           precision=None):
        """GenModel per-term breakdown of one family call on one axis.
        allreduce / reduce_scatter / allgather price the axis's cached
        GenTree plan (resp. its `family_halves`) rescaled to the exact
        size — the same co-planned structure `get_family_executable`
        lowers; all_to_all / p2p price their flat builders."""
        from repro.core import plans as plans_mod
        from repro.core.cost_model import evaluate_plan_terms
        from repro.core.sync import axis_level, level_switch_topo

        lvl = axis_level(i)
        merged = self._merged_level_params(lvl, eff)
        size_floats = max(float(size_floats), 1.0)
        if family in ("allreduce", "reduce_scatter", "allgather"):
            topo = level_switch_topo(int(n), eff, lvl)
            dsize = DTYPE_BYTES.get(dtype, 4)
            resp = self.get_plan(topo, size_floats * dsize, dtype,
                                 params=eff)
            plan = resp.plan
            factor = size_floats / resp.size_floats if resp.size_floats \
                else 1.0
            if abs(factor - 1.0) > 1e-12:
                plan = self._scaled_plan(plan, factor)
            if family != "allreduce":
                rs_half, ag_half = plans_mod.family_halves(plan)
                plan = rs_half if family == "reduce_scatter" else ag_half
        elif family == "all_to_all":
            plan = plans_mod.alltoall_plan(int(n), size_floats)
        elif family == "p2p":
            plan = plans_mod.p2p_plan(int(n), size_floats)
        else:
            raise ValueError(f"unknown collective family {family!r}")
        return evaluate_plan_terms(plan, merged, precision=precision)

    @staticmethod
    def _normalize_mix(mix) -> dict[str, tuple[int, float]]:
        """Mix spec → {family: (count, per_call_size_floats)}. Accepts a
        `launch.hlo_analysis.ModuleStats` (the per-family payload/count
        ledger `analyze_hlo` extracts) or an explicit mapping of family →
        (count, size_floats) / {"count": …, "size_floats": …}."""
        if hasattr(mix, "coll_counts") and hasattr(mix, "coll_by_kind"):
            from repro.launch.hlo_analysis import mix_from_stats
            mix = mix_from_stats(mix)
        out: dict[str, tuple[int, float]] = {}
        for fam, v in dict(mix).items():
            fam = FAMILY_ALIASES.get(fam, fam)
            if isinstance(v, Mapping):
                cnt = int(v.get("count", 1))
                sz = float(v.get("size_floats", 0.0))
            else:
                cnt, sz = int(v[0]), float(v[1])
            if cnt > 0 and sz > 0:
                prev = out.get(fam)
                if prev:  # merge duplicate spellings: total size preserved
                    tot = prev[0] * prev[1] + cnt * sz
                    cnt += prev[0]
                    sz = tot / cnt
                out[fam] = (cnt, sz)
        return out

    def get_step_plan(self, axes: Sequence[tuple[str, int]], mix,
                      dtype: str = "float32", *,
                      params: Mapping[str, GenModelParams] | None = None,
                      precision: str | None = None,
                      tolerance: float | None = None) -> StepPlan:
        """Price a training step's whole collective mix jointly under
        GenModel (DESIGN.md §14) and hand back one leaf-axis executable
        per family.

        `mix` is the step's collective census — a `ModuleStats` from
        `launch.hlo_analysis.analyze_hlo` or an explicit
        {family: (count, size_floats)} spec. Per family the sweep prices
        three regimes under each allowed wire precision:

          * per-call — count independent launches at the call size (the
            naïve baseline a per-collective planner would quote);
          * coalesced — ONE launch of count·size: α amortizes across
            calls, every linear term (β/γ/δ/ε) is unchanged, so the
            coalesced quote can never exceed count × per-call;
          * pipelined — count launches with call k's AllGather
            overlapping call k+1's ReduceScatter, the
            `core.bucketing.pipelined_time` model `get_bucket_plan`
            applies to buckets (folding families only).

        The argmin picks regime × precision jointly; AllReduce and its
        RS/AG halves price the axis chain hierarchically (leaf first,
        outer axes see the shard), AllToAll/P2P price the leaf axis they
        execute on (expert-parallel dispatch). Answers are cached under
        an axis_key fingerprint — mix, dtype, precision consent and the
        health-adjusted params all reach the key."""
        import math as _math

        from repro.core.bucketing import (contended_pipelined_time,
                                          pipelined_time)
        from repro.core.cost_model import (PRECISIONS, allowed_precisions,
                                           resolve_precision)
        from repro.core.optimality import overlap_certificate
        from repro.core.sync import axis_level

        axes = tuple((str(a), int(n)) for a, n in axes)
        live = [(i, a, n) for i, (a, n) in enumerate(axes) if n > 1]
        norm = self._normalize_mix(mix)
        eff = dict(params) if params else self.params
        if eff is None:
            from repro.core.cost_model import TPU_V5E
            eff = TPU_V5E
        eff = self._apply_health(eff)
        dsize = DTYPE_BYTES.get(dtype, 4)
        if precision is not None:
            prec_cands = [resolve_precision(precision, tolerance)]
        else:
            prec_cands = allowed_precisions(tolerance) \
                or [PRECISIONS["f32"]]
        mix_key = tuple(sorted((f, c, round(s, 6))
                               for f, (c, s) in norm.items()))
        total_floats = sum(c * s for c, s in norm.values()) or 1.0
        key = axis_key(axes, eff, self.cache.bucket(total_floats * dsize),
                       extra=self._config_extra()
                       + ("step_plan", mix_key, dtype, precision,
                          tolerance))

        def resolve_schedules(prec_name: str) -> dict:
            wire = PRECISIONS[prec_name] if prec_name != "f32" else None
            out = {}
            if not live:
                return out
            li, la, ln = live[0]
            for fam, (_c, s) in norm.items():
                sched = self.get_family_executable(
                    fam, la, ln, s, dtype, level=axis_level(li),
                    params=eff).schedule
                if wire is not None:
                    sched = sched.with_wire(wire)
                out[fam] = sched
            return out

        with self._lock:
            entry = self.cache.get(key)
            if entry is not None:
                obj = entry.get("_obj")
                if obj is not None:
                    return dataclasses.replace(obj, source="memory")
                prec_name = str(entry.get("precision", "f32"))
                obj = StepPlan(
                    axes=tuple((a, n) for _, a, n in live),
                    quotes={f: dict(q)
                            for f, q in entry["quotes"].items()},
                    total_per_call=float(entry["per_call"]),
                    total_joint=float(entry["joint"]),
                    total_best=float(entry["best"]),
                    ratio=float(entry["ratio"]),
                    schedules=resolve_schedules(prec_name),
                    precision=prec_name, source="disk", key=key)
                entry["_obj"] = obj
                return obj

            if not live or not norm:
                obj = StepPlan(axes=tuple((a, n) for _, a, n in live),
                               source="cold", key=key)
                self.cache.put(key, {
                    "kind": "step_plan", "quotes": {}, "per_call": 0.0,
                    "joint": 0.0, "best": 0.0, "ratio": 1.0,
                    "precision": "f32", "_obj": obj})
                return obj

            def chain_terms(fam: str, s: float, prec):
                """Breakdown summed over the axes the family traverses:
                the folding families run the hierarchical chain (outer
                axes see the inner shard); a2a/p2p run the leaf only."""
                if fam in ("all_to_all", "p2p"):
                    i, _a, n = live[0]
                    return [self._family_axis_terms(fam, i, n, s, dtype,
                                                    eff, precision=prec)]
                shard, out = float(s), []
                for i, _a, n in live:
                    out.append(self._family_axis_terms(
                        fam, i, n, shard, dtype, eff, precision=prec))
                    shard /= n
                return out

            def halves_time(fam: str, s: float, prec):
                """(T_RS, T_AG) for the pipelined regime — only
                meaningful for families with a fold boundary."""
                t_rs = t_ag = 0.0
                shard = float(s)
                for i, _a, n in live:
                    rs, ag = self._axis_halves_time(
                        n, axis_level(i), shard, dtype, eff,
                        precision=prec)
                    if fam == "reduce_scatter":
                        ag = 0.0
                    elif fam == "allgather":
                        rs = 0.0
                    t_rs += rs
                    t_ag += ag
                    shard /= n
                return t_rs, t_ag

            def joint_time(s: float, prec):
                """Contended steady-state round (call k's RS with call
                k-1's AG through the per-link occupancy merge, §15),
                summed over the hierarchical chain. Only allreduce has
                both halves live — single-half families pipeline with a
                degenerate joint (== the live half), which
                `contended_pipelined_time` recovers from t_joint=None."""
                t = 0.0
                shard = float(s)
                for i, _a, n in live:
                    t += self._axis_contended_time(
                        n, axis_level(i), shard, dtype, eff,
                        precision=prec)
                    shard /= n
                return t

            best_pick = None
            with default_tracer().span("planner/step_sweep",
                                       families=len(norm),
                                       precisions=len(prec_cands)):
                for prec in prec_cands:
                    pw = None if prec.name == "f32" else prec
                    quotes: dict[str, dict] = {}
                    tot_call = tot_joint = tot_best = 0.0
                    for fam, (cnt, s) in sorted(norm.items()):
                        call_bds = chain_terms(fam, s, pw)
                        call_t = sum(b.total for b in call_bds)
                        joint_bds = chain_terms(fam, cnt * s, pw)
                        joint = {
                            t: sum(getattr(b, t) for b in joint_bds)
                            for t in call_bds[0].TERMS}
                        joint_t = sum(joint.values())
                        cert = None
                        if cnt > 1 and fam in ("allreduce",
                                               "reduce_scatter",
                                               "allgather"):
                            t_rs, t_ag = halves_time(fam, s, pw)
                            naive = pipelined_time(t_rs, t_ag, cnt)
                            tj = joint_time(s, pw) \
                                if fam == "allreduce" else None
                            piped = contended_pipelined_time(
                                t_rs, t_ag, cnt, tj)
                            # the certificate proves the contended quote
                            # sits between the overlap-adjusted lower
                            # bound (naive pipeline) and sequential
                            cert = overlap_certificate(t_rs, t_ag, cnt,
                                                       piped)
                        else:
                            piped = naive = cnt * call_t
                        # per-call stays a candidate regime (the pipelined
                        # estimate comes from the simulator and the other
                        # two from the term walk — the argmin must never
                        # pick something worse than the naïve baseline)
                        cands = {"coalesced": joint_t, "pipelined": piped,
                                 "per_call": cnt * call_t}
                        mode = min(cands, key=lambda m: (cands[m], m))
                        best_t = cands[mode]
                        quotes[fam] = {
                            "count": cnt, "size_floats": s,
                            "per_call_total": call_t,
                            "joint": joint, "joint_total": joint_t,
                            "pipelined": naive, "contended": piped,
                            "certificate": cert, "mode": mode,
                            "best_total": best_t,
                            "precision": prec.name,
                        }
                        tot_call += cnt * call_t
                        tot_joint += joint_t
                        tot_best += best_t
                    if best_pick is None or tot_best < best_pick[1]:
                        best_pick = (prec.name, tot_best, tot_joint,
                                     tot_call, quotes)

            prec_name, tot_best, tot_joint, tot_call, quotes = best_pick
            ratio = tot_best / tot_call if tot_call > 0 else 1.0
            obj = StepPlan(
                axes=tuple((a, n) for _, a, n in live), quotes=quotes,
                total_per_call=tot_call, total_joint=tot_joint,
                total_best=tot_best, ratio=ratio,
                schedules=resolve_schedules(prec_name),
                precision=prec_name, source="cold", key=key)
            self.cache.put(key, {
                "kind": "step_plan",
                "quotes": {f: {k: v for k, v in q.items()}
                           for f, q in quotes.items()},
                "per_call": tot_call, "joint": tot_joint,
                "best": tot_best, "ratio": ratio,
                "precision": prec_name, "_obj": obj})
            return obj

    # ---- per-mesh-axis plans (training/serving hot path) -------------------
    def get_axis_plans(self, axes: Sequence[tuple[str, int]],
                       size_floats: float,
                       params: Mapping[str, GenModelParams] | None = None
                       ) -> list[AxisPlan]:
        axes = [(str(a), int(n)) for a, n in axes]
        eff = params if params is not None else self.params
        bucket = self.cache.bucket(max(size_floats, 1.0) * 4)
        from repro.core.cost_model import TPU_V5E
        eff = self._apply_health(eff if eff is not None else TPU_V5E)
        key = axis_key(axes, eff, bucket, extra=self._config_extra())
        entry = self.cache.get(key)
        if entry is not None:
            obj = entry.get("_obj")
            if obj is None:
                # 4-element rows carry the modeled cost; 3-element rows
                # (pre-telemetry snapshots) load with predicted=None
                obj = [AxisPlan(row[0], row[1],
                                tuple(row[2]) if row[2] else None,
                                predicted=(float(row[3])
                                           if len(row) > 3
                                           and row[3] is not None
                                           else None))
                       for row in entry["axis_plans"]]
                entry["_obj"] = obj
            return list(obj)
        # Cold pricing honours the service's configured engine and
        # gentree kwargs (once silently dropped here, so an
        # engine="reference" or candidate-restricted service got default
        # axis plans).
        plans = plan_axes_gentree(axes, float(bucket) / 4.0, eff,
                                  engine=self.engine,
                                  gentree_kwargs=self.gentree_kwargs)
        entry = {"axis_plans": [[p.axis, p.strategy,
                                 list(p.factors) if p.factors else None,
                                 p.predicted]
                                for p in plans],
                 "_obj": list(plans)}
        self.cache.put(key, entry)
        return list(plans)

    # ---- housekeeping ------------------------------------------------------
    def invalidate_executables(self) -> int:
        """Drop every derived executable artifact — lowered
        `CompiledSchedule`s (the per-entry `_exec` maps) and bucket-plan
        entries — while keeping the priced plans. The next
        `get_executable`/`get_bucket_plan` re-lowers against the current
        mesh. Called via `core.bucketing.invalidate_schedules` after an
        elastic remesh or a fault-tolerant resume."""
        with self._lock:
            dropped = self.cache.drop_derived()
            fam = self.__dict__.get("_family_scheds")
            if fam:
                dropped += len(fam)
                fam.clear()
        m = default_metrics()
        m.counter("planner_schedule_invalidations_total",
                  "invalidate_executables calls (remesh/resume/refit)"
                  ).inc()
        m.counter("planner_executables_dropped_total",
                  "derived schedules + bucket plans dropped").inc(dropped)
        return dropped

    def executable_count(self) -> int:
        """Derived executable artifacts currently cached (schedules +
        bucket plans) — what `invalidate_executables` would drop."""
        with self._lock:
            return self.cache.derived_count()

    def stats(self) -> dict:
        out = {"cache": self.cache.stats.as_dict(),
               "entries": len(self.cache),
               "calibrated": self.calibration is not None,
               "refits": list(self.refits),
               "degraded": dict(self._degraded),
               "telemetry": self.telemetry.stats()}
        if self.params:
            out["params"] = {lvl: dataclasses.asdict(p)
                             for lvl, p in self.params.items()}
        return out

    def save(self, path: str | None = None) -> None:
        self.cache.save(path)


# ---------------------------------------------------------------------------
# Process-wide default service (what the hot paths use)
# ---------------------------------------------------------------------------
_default: PlannerService | None = None
_default_lock = threading.Lock()


def default_service() -> PlannerService:
    """Lazily-created singleton. $REPRO_PLAN_CACHE, when set, points at the
    JSON persistence file so warm plans survive restarts."""
    global _default
    with _default_lock:
        if _default is None:
            from repro.runtime.telemetry import default_telemetry
            path = os.environ.get("REPRO_PLAN_CACHE") or None
            # autosave so the promise holds without an explicit save():
            # nothing on the train/serve hot paths calls save() for us.
            # The process-wide service observes through the process-wide
            # telemetry hub, so the launchers and the watchdog share one
            # measurement datapath.
            _default = PlannerService(cache_path=path,
                                      autosave=path is not None,
                                      telemetry=default_telemetry())
        return _default


def peek_default_service() -> PlannerService | None:
    """The process-wide service if one exists, WITHOUT creating it —
    invalidation paths (remesh/resume) must not instantiate a service
    just to empty it."""
    with _default_lock:
        return _default


def set_default_service(svc: PlannerService | None) -> None:
    """Swap the process-wide service (tests, custom calibration)."""
    global _default
    with _default_lock:
        _default = svc


def get_plan(topo: TopoNode, nbytes: int | float,
             dtype: str = "float32") -> PlanResponse:
    return default_service().get_plan(topo, nbytes, dtype)


def axis_plans(axes: Sequence[tuple[str, int]],
               size_floats: float) -> list[AxisPlan]:
    return default_service().get_axis_plans(axes, size_floats)
