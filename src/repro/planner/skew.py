"""Arrival-skew pricing: expected AllReduce cost under imbalanced arrivals.

GenModel (and the synchronized simulator) assume every server enters the
collective at t=0. Real training steps don't: stragglers, imbalanced
process-arrival patterns (Proficz; Faraj/Patarasuk/Yuan) and multi-job
interference stagger the start times, and the *ranking* of plan types
changes — heavily pipelined or high-fan-in plans lose their edge when the
cost after the last arrival is what matters.

Model: an arrival-gated per-server dataflow over the Plan IR. Each server
carries a clock that starts at its arrival offset; a step's transfers
leave when the sender's clock allows, and a receiver's reduce completes
only when the slowest input has arrived. Two effects fall out naturally:

  * work not depending on a late server overlaps the wait, so few-round
    plans (CPS) recover faster than long pipelines once skew dominates;
  * incast is charged only on flows that arrive *simultaneously* (within
    one launch latency α of the last one) — staggered arrivals drain
    buffers instead of overflowing them, so the ε penalty that made CPS
    lose under synchronized starts fades as skew grows.

Pricing is NIC-granularity (per-server uplinks, γ/δ compute, per-level α
and ε) and intentionally ignores shared upper-link contention: it is a
*comparative* model, not a replacement for core.simulator. Plan selection
therefore anchors on the simulator: each candidate is priced as its
synchronized simulator cost plus the *arrival-gated delta* (expected gated
time under the skew draws minus gated time at zero offsets), so at zero
skew the ranking is exactly the synchronized simulator's, and only the
skew-induced difference comes from this model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import GenModelParams, PAPER_TABLE5
from repro.core.plans import Plan
from repro.core.topology import TopoNode


SKEW_DISTS = ("exponential", "uniform", "none", "empirical")


@dataclass(frozen=True)
class SkewModel:
    """Distribution of per-server arrival offsets (seconds).

    dist: "exponential" | "uniform" | "none" | "empirical"; `frac` is
    the fraction of servers that are skewed at all (the rest arrive at
    t=0); `draws` Monte-Carlo draws from a fixed seed keep pricing
    deterministic.

    The *empirical* mode prices measured arrival patterns instead of
    synthetic draws: `offsets` holds per-device arrival offsets observed
    by the runtime telemetry (`runtime.telemetry.ArrivalEstimator`), and
    each draw bootstrap-resamples that pool onto the topology's servers
    — build one with `SkewModel.from_offsets(...)` or let
    `PlannerService.adopt_empirical_skew()` do it from live telemetry.

    The distribution is validated eagerly at construction — an unknown
    `dist` (or an empirical model without offsets) fails here, not deep
    inside the pricing draw loop.
    """
    dist: str = "exponential"
    scale: float = 0.0
    frac: float = 1.0
    draws: int = 8
    seed: int = 0
    offsets: tuple[float, ...] | None = None    # empirical mode only

    def __post_init__(self):
        if self.dist not in SKEW_DISTS:
            raise ValueError(f"unknown skew dist {self.dist!r}; "
                             f"expected one of {SKEW_DISTS}")
        if self.dist == "empirical" and not self.offsets:
            raise ValueError("empirical skew needs measured offsets; "
                             "use SkewModel.from_offsets(...)")

    @classmethod
    def from_offsets(cls, offsets, draws: int = 8, seed: int = 0,
                     frac: float = 1.0) -> "SkewModel":
        """Empirical model from measured per-device arrival offsets
        (seconds; normalized so the earliest arrival is 0). `scale` is
        set to the worst observed offset so zero-skew fast paths (`scale
        > 0` gates in the service) behave correctly."""
        offs = tuple(sorted(max(float(o), 0.0) for o in offsets))
        if not offs:
            raise ValueError("empirical skew needs at least one offset")
        base = min(offs)
        offs = tuple(o - base for o in offs)
        return cls(dist="empirical", scale=max(offs), frac=frac,
                   draws=draws, seed=seed, offsets=offs)

    def key(self) -> tuple:
        return (self.dist, "%.9g" % self.scale, "%.9g" % self.frac,
                self.draws, self.seed,
                None if self.offsets is None
                else tuple("%.9g" % o for o in self.offsets))


def draw_offsets(model: SkewModel, n: int) -> np.ndarray:
    """(draws, n) matrix of non-negative arrival offsets."""
    if model.dist == "none" or model.scale <= 0.0:
        return np.zeros((1, n))
    rng = np.random.default_rng(model.seed)
    out = np.zeros((model.draws, n))
    k = max(1, int(round(model.frac * n)))
    pool = None if model.offsets is None else np.asarray(model.offsets)
    for d in range(model.draws):
        idx = rng.permutation(n)[:k]
        if model.dist == "exponential":
            out[d, idx] = rng.exponential(model.scale, size=k)
        elif model.dist == "uniform":
            out[d, idx] = rng.uniform(0.0, model.scale, size=k)
        elif model.dist == "empirical":
            # bootstrap-resample the measured pool onto the skewed
            # servers: topology sizes need not match the measured device
            # count, and resampling keeps pricing a *distribution* (with
            # the fixed seed keeping it deterministic)
            out[d, idx] = pool[rng.integers(0, len(pool), size=k)]
        else:                       # unreachable: validated eagerly
            raise ValueError(f"unknown skew dist {model.dist!r}")
    return out


def arrival_gated_time(plan: Plan, topo: TopoNode,
                       params: Mapping[str, GenModelParams] | None = None,
                       offsets: Sequence[float] | None = None,
                       unit_bytes: int = 4) -> float:
    """Completion time of `plan` on `topo` with per-server arrival offsets
    (indexed by server id; missing/None = all zero)."""
    params = params or PAPER_TABLE5
    psrv = params.get("server", GenModelParams())

    def _p(level: str) -> GenModelParams:
        return params.get(level, psrv)

    srv = {s._sid: s for s in topo.servers()}
    scale = unit_bytes / 4.0
    clock = {sid: 0.0 for sid in srv}
    if offsets is not None:
        for i, sid in enumerate(sorted(srv)):
            if i < len(offsets):
                clock[sid] = float(offsets[i])

    for st in plan.steps:
        send_units: dict[int, float] = {}
        senders_to: dict[int, list[int]] = {}
        for t in st.transfers:
            send_units[t.src] = send_units.get(t.src, 0.0) + t.size
            senders_to.setdefault(t.dst, []).append(t.src)
        recv_units = st.recv_bytes_by_dst()
        comp: dict[int, float] = {}
        for r in st.reduces:
            comp[r.server] = comp.get(r.server, 0.0) + (
                r.adds * psrv.gamma + r.mem_ops * psrv.delta) * scale

        participants = set(send_units) | set(recv_units) | set(comp)
        if not participants:
            continue

        start: dict[int, float] = {}
        send_done: dict[int, float] = {}
        for s in participants:
            node = srv[s]
            lvl = node.parent.level if node.parent is not None else "server"
            start[s] = clock[s] + max(_p(lvl).alpha, psrv.alpha)
        for s, units in send_units.items():
            node = srv[s]
            bw = node.uplink_bw
            t_send = units * unit_bytes / bw if bw else 0.0
            send_done[s] = start[s] + t_send + node.uplink_latency

        new_clock = dict(clock)
        for s in participants:
            t = start[s]
            if s in send_done:
                t = max(t, send_done[s])
            if s in recv_units:
                node = srv[s]
                plvl = _p(node.parent.level if node.parent else "root_sw")
                arrivals = [send_done[src] for src in senders_to[s]]
                last = max(arrivals)
                # incast: only flows landing within one round latency of
                # the last one overflow buffers together (+1 for self)
                w = sum(1 for a in arrivals if a >= last - plvl.alpha) + 1
                extra = max(w - plvl.w_t, 0) * recv_units[s] * scale \
                    * plvl.epsilon
                bw = node.uplink_bw
                t_recv = recv_units[s] * unit_bytes / bw if bw else 0.0
                t = max(t, last + t_recv + extra)
            t += comp.get(s, 0.0)
            new_clock[s] = t
        clock = new_clock
    return max(clock.values()) if clock else 0.0


# ---------------------------------------------------------------------------
# Batched arrival-gated pricing (DESIGN.md §7): the same dataflow as
# `arrival_gated_time`, but the per-step quantities are precompiled into
# arrays once per plan and every Monte-Carlo draw advances in lockstep as a
# row of a (draws, servers) clock matrix. `arrival_gated_time` above stays
# the reference oracle (equivalence asserted in tests/test_simfast.py).
# ---------------------------------------------------------------------------
class _GatedPlan:
    """Per-step static arrays for the arrival-gated dataflow."""

    def __init__(self, plan: Plan, topo: TopoNode,
                 params: Mapping[str, GenModelParams] | None,
                 unit_bytes: int):
        params = params or PAPER_TABLE5
        psrv = params.get("server", GenModelParams())

        def _p(level: str) -> GenModelParams:
            return params.get(level, psrv)

        srv = {s._sid: s for s in topo.servers()}
        # arrays are indexed by _sid; for a subtree of a larger finalized
        # tree the ids are a sparse subset, so size by the largest id
        self.sids = np.array(sorted(srv), dtype=np.int64)
        self.n = int(self.sids[-1]) + 1 if len(srv) else 0
        n = self.n
        scale = unit_bytes / 4.0
        # static per-server tables
        alpha_start = np.zeros(n)
        bw = np.zeros(n)
        lat = np.zeros(n)
        r_eps = np.zeros(n)
        r_wt = np.zeros(n)
        r_alpha = np.zeros(n)
        for sid, node in srv.items():
            lvl = node.parent.level if node.parent is not None else "server"
            alpha_start[sid] = max(_p(lvl).alpha, psrv.alpha)
            bw[sid] = node.uplink_bw
            lat[sid] = node.uplink_latency
            plvl = _p(node.parent.level if node.parent else "root_sw")
            r_eps[sid], r_wt[sid] = plvl.epsilon, float(plvl.w_t)
            r_alpha[sid] = plvl.alpha
        self.alpha_start, self.lat = alpha_start, lat
        self.r_eps, self.r_wt, self.r_alpha = r_eps, r_wt, r_alpha

        self.steps = []
        for st in plan.steps:
            src = np.fromiter((t.src for t in st.transfers), np.int64,
                              len(st.transfers))
            dst = np.fromiter((t.dst for t in st.transfers), np.int64,
                              len(st.transfers))
            size = np.fromiter((t.size for t in st.transfers), float,
                               len(st.transfers))
            rsrv = np.fromiter((r.server for r in st.reduces), np.int64,
                               len(st.reduces))
            cval = np.fromiter(
                ((r.adds * psrv.gamma + r.mem_ops * psrv.delta) * scale
                 for r in st.reduces), float, len(st.reduces))
            send_units = np.bincount(src, weights=size, minlength=n)
            recv_units = np.bincount(dst, weights=size, minlength=n)
            senders = np.nonzero(np.bincount(src, minlength=n))[0]
            rdst = np.nonzero(np.bincount(dst, minlength=n))[0]
            comp = np.bincount(rsrv, weights=cval, minlength=n)
            csrv = np.nonzero(np.bincount(rsrv, minlength=n))[0]
            part = np.union1d(np.union1d(senders, rdst), csrv)
            if part.size == 0:
                continue
            sbw = np.where(bw[senders] != 0.0, bw[senders], 1.0)
            t_send = np.where(bw[senders] != 0.0,
                              send_units[senders] * unit_bytes / sbw, 0.0)
            rbw = np.where(bw[rdst] != 0.0, bw[rdst], 1.0)
            t_recv = np.where(bw[rdst] != 0.0,
                              recv_units[rdst] * unit_bytes / rbw, 0.0)
            self.steps.append({
                "part": part, "senders": senders, "t_send": t_send,
                "pairs_src": src, "pairs_dst": dst,
                "rdst": rdst, "t_recv": t_recv,
                "recv_units": recv_units[rdst] * scale,
                "csrv": csrv, "comp": comp[csrv]})

    def times(self, offsets: np.ndarray) -> np.ndarray:
        """Completion time per draw; offsets rows map positionally onto
        the sorted server ids (extra columns ignored, missing ones
        zero-filled), as in the reference."""
        offsets = np.asarray(offsets, dtype=float)
        if offsets.ndim == 1:
            offsets = offsets[None, :]
        nd, n = offsets.shape[0], self.n
        clock = np.zeros((nd, n))
        k = min(len(self.sids), offsets.shape[1])
        clock[:, self.sids[:k]] = offsets[:, :k]
        rows = np.arange(nd)[:, None]
        neg = np.finfo(float).min
        for sp in self.steps:
            part, senders, rdst = sp["part"], sp["senders"], sp["rdst"]
            start = clock + self.alpha_start[None, :]
            send_done = np.full((nd, n), neg)
            send_done[:, senders] = (start[:, senders] + sp["t_send"]
                                     + self.lat[senders])
            t = start.copy()
            t[:, senders] = np.maximum(t[:, senders], send_done[:, senders])
            if rdst.size:
                psrc, pdst = sp["pairs_src"], sp["pairs_dst"]
                last = np.full((nd, n), neg)
                np.maximum.at(last, (rows, pdst[None, :]),
                              send_done[:, psrc])
                cnt = np.zeros((nd, n))
                np.add.at(cnt, (rows, pdst[None, :]),
                          (send_done[:, psrc]
                           >= last[:, pdst] - self.r_alpha[pdst]))
                w = cnt[:, rdst] + 1.0
                extra = (np.maximum(w - self.r_wt[rdst], 0.0)
                         * sp["recv_units"] * self.r_eps[rdst])
                t[:, rdst] = np.maximum(
                    t[:, rdst], last[:, rdst] + sp["t_recv"] + extra)
            if sp["csrv"].size:
                t[:, sp["csrv"]] += sp["comp"]
            clock[:, part] = t[:, part]
        if not len(self.sids):
            return np.zeros(nd)
        return clock[:, self.sids].max(axis=1)


def gated_times(plan: Plan, topo: TopoNode,
                params: Mapping[str, GenModelParams] | None = None,
                offsets: np.ndarray | None = None,
                unit_bytes: int = 4) -> np.ndarray:
    """Batched `arrival_gated_time`: one row of `offsets` per draw."""
    gp = _GatedPlan(plan, topo, params, unit_bytes)
    if offsets is None:
        offsets = np.zeros((1, gp.n))
    return gp.times(offsets)


def expected_time(plan: Plan, topo: TopoNode, model: SkewModel,
                  params: Mapping[str, GenModelParams] | None = None,
                  unit_bytes: int = 4) -> float:
    """Mean arrival-gated completion time over the model's draws."""
    offs = draw_offsets(model, topo.num_servers())
    return float(np.mean(gated_times(plan, topo, params, offs, unit_bytes)))


def pick_plan_under_skew(candidates: Sequence[tuple[str, Plan]],
                         topo: TopoNode, model: SkewModel,
                         params: Mapping[str, GenModelParams] | None = None,
                         unit_bytes: int = 4, engine: str | None = None
                         ) -> tuple[str, Plan, float]:
    """argmin of simulator cost + arrival-gated skew delta (see module
    docstring); deterministic tie-break on name. The gated model only
    contributes the *difference* skew makes, so at zero skew this reduces
    to the synchronized simulator ranking. Each candidate is compiled once
    (`_GatedPlan`) and priced over all draws plus the zero-offset baseline
    in a single batched pass; `engine` selects the synchronized-cost
    evaluator (fast compiled engine by default)."""
    from repro.core.simulator import Simulator

    if not candidates:
        raise ValueError("no candidate plans")
    sim = Simulator(topo, dict(params) if params else None,
                    unit_bytes=unit_bytes, engine=engine)
    n = topo.num_servers()
    offs = draw_offsets(model, n)
    priced = []
    for name, p in candidates:
        sync = sim.simulate(p).total
        gp = _GatedPlan(p, topo, params, unit_bytes)
        # draws + one zero-offset row, one batched evaluation per plan
        ts = gp.times(np.vstack([offs, np.zeros((1, n))]))
        delta = float(np.mean(ts[:-1])) - float(ts[-1])
        priced.append((sync + max(delta, 0.0), name, p))
    priced.sort(key=lambda x: (x[0], x[1]))
    cost, name, plan = priced[0]
    return name, plan, cost
