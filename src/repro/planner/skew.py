"""Arrival-skew pricing: expected AllReduce cost under imbalanced arrivals.

GenModel (and the synchronized simulator) assume every server enters the
collective at t=0. Real training steps don't: stragglers, imbalanced
process-arrival patterns (Proficz; Faraj/Patarasuk/Yuan) and multi-job
interference stagger the start times, and the *ranking* of plan types
changes — heavily pipelined or high-fan-in plans lose their edge when the
cost after the last arrival is what matters.

Model: an arrival-gated per-server dataflow over the Plan IR. Each server
carries a clock that starts at its arrival offset; a step's transfers
leave when the sender's clock allows, and a receiver's reduce completes
only when the slowest input has arrived. Two effects fall out naturally:

  * work not depending on a late server overlaps the wait, so few-round
    plans (CPS) recover faster than long pipelines once skew dominates;
  * incast is charged only on flows that arrive *simultaneously* (within
    one launch latency α of the last one) — staggered arrivals drain
    buffers instead of overflowing them, so the ε penalty that made CPS
    lose under synchronized starts fades as skew grows.

Pricing is NIC-granularity (per-server uplinks, γ/δ compute, per-level α
and ε) and intentionally ignores shared upper-link contention: it is a
*comparative* model, not a replacement for core.simulator. Plan selection
therefore anchors on the simulator: each candidate is priced as its
synchronized simulator cost plus the *arrival-gated delta* (expected gated
time under the skew draws minus gated time at zero offsets), so at zero
skew the ranking is exactly the synchronized simulator's, and only the
skew-induced difference comes from this model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import GenModelParams, PAPER_TABLE5
from repro.core.plans import Plan
from repro.core.topology import TopoNode


@dataclass(frozen=True)
class SkewModel:
    """Distribution of per-server arrival offsets (seconds).

    dist: "exponential" | "uniform" | "none"; `frac` is the fraction of
    servers that are skewed at all (the rest arrive at t=0); `draws`
    Monte-Carlo draws from a fixed seed keep pricing deterministic.
    """
    dist: str = "exponential"
    scale: float = 0.0
    frac: float = 1.0
    draws: int = 8
    seed: int = 0

    def key(self) -> tuple:
        return (self.dist, "%.9g" % self.scale, "%.9g" % self.frac,
                self.draws, self.seed)


def draw_offsets(model: SkewModel, n: int) -> np.ndarray:
    """(draws, n) matrix of non-negative arrival offsets."""
    if model.dist == "none" or model.scale <= 0.0:
        return np.zeros((1, n))
    rng = np.random.default_rng(model.seed)
    out = np.zeros((model.draws, n))
    k = max(1, int(round(model.frac * n)))
    for d in range(model.draws):
        idx = rng.permutation(n)[:k]
        if model.dist == "exponential":
            out[d, idx] = rng.exponential(model.scale, size=k)
        elif model.dist == "uniform":
            out[d, idx] = rng.uniform(0.0, model.scale, size=k)
        else:
            raise ValueError(f"unknown skew dist {model.dist!r}")
    return out


def arrival_gated_time(plan: Plan, topo: TopoNode,
                       params: Mapping[str, GenModelParams] | None = None,
                       offsets: Sequence[float] | None = None,
                       unit_bytes: int = 4) -> float:
    """Completion time of `plan` on `topo` with per-server arrival offsets
    (indexed by server id; missing/None = all zero)."""
    params = params or PAPER_TABLE5
    psrv = params.get("server", GenModelParams())

    def _p(level: str) -> GenModelParams:
        return params.get(level, psrv)

    srv = {s._sid: s for s in topo.servers()}
    scale = unit_bytes / 4.0
    clock = {sid: 0.0 for sid in srv}
    if offsets is not None:
        for i, sid in enumerate(sorted(srv)):
            if i < len(offsets):
                clock[sid] = float(offsets[i])

    for st in plan.steps:
        send_units: dict[int, float] = {}
        senders_to: dict[int, list[int]] = {}
        for t in st.transfers:
            send_units[t.src] = send_units.get(t.src, 0.0) + t.size
            senders_to.setdefault(t.dst, []).append(t.src)
        recv_units = st.recv_bytes_by_dst()
        comp: dict[int, float] = {}
        for r in st.reduces:
            comp[r.server] = comp.get(r.server, 0.0) + (
                r.adds * psrv.gamma + r.mem_ops * psrv.delta) * scale

        participants = set(send_units) | set(recv_units) | set(comp)
        if not participants:
            continue

        start: dict[int, float] = {}
        send_done: dict[int, float] = {}
        for s in participants:
            node = srv[s]
            lvl = node.parent.level if node.parent is not None else "server"
            start[s] = clock[s] + max(_p(lvl).alpha, psrv.alpha)
        for s, units in send_units.items():
            node = srv[s]
            bw = node.uplink_bw
            t_send = units * unit_bytes / bw if bw else 0.0
            send_done[s] = start[s] + t_send + node.uplink_latency

        new_clock = dict(clock)
        for s in participants:
            t = start[s]
            if s in send_done:
                t = max(t, send_done[s])
            if s in recv_units:
                node = srv[s]
                plvl = _p(node.parent.level if node.parent else "root_sw")
                arrivals = [send_done[src] for src in senders_to[s]]
                last = max(arrivals)
                # incast: only flows landing within one round latency of
                # the last one overflow buffers together (+1 for self)
                w = sum(1 for a in arrivals if a >= last - plvl.alpha) + 1
                extra = max(w - plvl.w_t, 0) * recv_units[s] * scale \
                    * plvl.epsilon
                bw = node.uplink_bw
                t_recv = recv_units[s] * unit_bytes / bw if bw else 0.0
                t = max(t, last + t_recv + extra)
            t += comp.get(s, 0.0)
            new_clock[s] = t
        clock = new_clock
    return max(clock.values()) if clock else 0.0


def expected_time(plan: Plan, topo: TopoNode, model: SkewModel,
                  params: Mapping[str, GenModelParams] | None = None,
                  unit_bytes: int = 4) -> float:
    """Mean arrival-gated completion time over the model's draws."""
    offs = draw_offsets(model, topo.num_servers())
    return float(np.mean([
        arrival_gated_time(plan, topo, params, o, unit_bytes)
        for o in offs]))


def pick_plan_under_skew(candidates: Sequence[tuple[str, Plan]],
                         topo: TopoNode, model: SkewModel,
                         params: Mapping[str, GenModelParams] | None = None,
                         unit_bytes: int = 4
                         ) -> tuple[str, Plan, float]:
    """argmin of simulator cost + arrival-gated skew delta (see module
    docstring); deterministic tie-break on name. The gated model only
    contributes the *difference* skew makes, so at zero skew this reduces
    to the synchronized simulator ranking."""
    from repro.core.simulator import Simulator

    if not candidates:
        raise ValueError("no candidate plans")
    sim = Simulator(topo, dict(params) if params else None,
                    unit_bytes=unit_bytes)
    priced = []
    for name, p in candidates:
        sync = sim.simulate(p).total
        delta = (expected_time(p, topo, model, params, unit_bytes)
                 - arrival_gated_time(p, topo, params, None, unit_bytes))
        priced.append((sync + max(delta, 0.0), name, p))
    priced.sort(key=lambda x: (x[0], x[1]))
    cost, name, plan = priced[0]
    return name, plan, cost
