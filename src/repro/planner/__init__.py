"""Planner subsystem — cached, measurement-calibrated AllReduce plan service.

The paper's workflow (measure → fit GenModel → GenTree-generate → execute)
productionized as one entry point (DESIGN.md §5):

  * fingerprint — canonical hashing of topologies + GenModel params so
    isomorphic trees share cache entries;
  * cache       — size-bucketed, thread-safe LRU plan cache with disk
    persistence (warm plans survive restarts);
  * calibrate   — microbench harness that refits GenModelParams from
    measured (size, time) curves per level class;
  * skew        — arrival-skew (process-arrival-pattern) re-pricing of
    candidate plans under imbalance;
  * service     — the PlannerService facade: `get_plan(topo, nbytes)` and
    `get_axis_plans(axes, size_floats)`, wired into core.collectives,
    core.sync, launch.train and launch.serve.
"""
from . import cache, calibrate, fingerprint, service, skew  # noqa: F401
from .cache import PlanCache  # noqa: F401
from .calibrate import (CalibrationConfig, MeasurementProvider,  # noqa: F401
                        TelemetryProvider, calibrate_levels)
from .fingerprint import fingerprint_topo, plan_key  # noqa: F401
from .service import (PlannerService, RefitPolicy,  # noqa: F401
                      default_service, get_plan)
from .skew import SkewModel, expected_time, pick_plan_under_skew  # noqa: F401
