"""Calibration harness: refit GenModelParams from measured curves (§3.4).

Replaces the frozen PAPER_TABLE5 / TPU_V5E presets with *fitted* instances.
Per level class a `MeasurementProvider` produces the paper's two
microbench curves and the resulting (size, time) samples feed core.fitting
— every provider, offline or online, flows through the SAME least-squares
path (`fit_level`); there is no second fitting codepath:

  * the co-located-PS curve over (N, S) — identifies α, 2β+γ, δ, ε, w_t
    (Table-2 CPS design matrix, w_t by residual grid search);
  * the Fig.-4 fan-in microbench — separates δ from γ, which the CPS curve
    alone cannot (only 2β+γ is identifiable there).

Providers (``cfg.backend`` selects one; pass `provider=` for a custom
instance):

  * "simulator"   — drive core.simulator over a single-switch topology of
    the level class (the default; deterministic, runs anywhere);
  * "closed_form" — sample the Table-2 closed forms directly (exact
    round-trip, used by the calibration tests);
  * "lax"         — time real `lax` collectives on the local mesh; only
    available with ≥2 JAX devices and kept behind an explicit opt-in so
    headless CI never touches the accelerator runtime;
  * `TelemetryProvider` — the online loop (DESIGN.md §10): runtime
    telemetry samples (`runtime.telemetry`), recorded by
    `PlannerService.observe` as CPS-equivalent (n, S, time) points,
    replayed as the CPS curve. The Fig.-4 curve falls back to the closed
    form at the *current* params: arrival timings cannot separate δ from
    γ online, so the memory-term split is carried over while the
    measured combination 2β+γ (and α, ε) is refit from live data.

Recorded samples are kept on the result so they can be persisted/inspected
(the service exposes them through its stats).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core import plans as plans_mod
from repro.core.cost_model import GenModelParams, PAPER_TABLE5, cost_cps
from repro.core.fitting import fit_delta_gamma, fit_from_cps_benchmarks
from repro.core.simulator import Simulator
from repro.core.topology import single_switch


@dataclass(frozen=True)
class CalibrationConfig:
    ns: tuple[int, ...] = tuple(range(2, 17))
    sizes: tuple[float, ...] = (1e6, 4e6, 1.6e7)     # data units (floats)
    fig4_xs: tuple[int, ...] = tuple(range(2, 17))   # fan-in degrees
    fig4_size: float = 1e6
    backend: str = "simulator"    # simulator | closed_form | lax
    unit_bytes: int = 4
    levels: tuple[str, ...] = ("cross_dc", "root_sw", "middle_sw", "server")
    # plan-evaluation engine for the simulator backend's sweeps: "fast"
    # (compiled, default) or "reference" (pure-Python oracle); None defers
    # to $REPRO_SIM_ENGINE / the Simulator default.
    engine: str | None = None


@dataclass
class LevelSamples:
    """Raw measurement record for one level class."""
    level: str
    ns: np.ndarray
    sizes: np.ndarray
    times: np.ndarray
    fig4_xs: np.ndarray
    fig4_size: float
    fig4_times: np.ndarray

    def as_dict(self) -> dict:
        return {"level": self.level, "ns": self.ns.tolist(),
                "sizes": self.sizes.tolist(), "times": self.times.tolist(),
                "fig4_xs": self.fig4_xs.tolist(),
                "fig4_size": self.fig4_size,
                "fig4_times": self.fig4_times.tolist()}


@dataclass
class CalibrationResult:
    params: dict[str, GenModelParams]
    samples: dict[str, LevelSamples] = field(default_factory=dict)
    backend: str = "simulator"

    def as_dict(self) -> dict:
        return {"backend": self.backend,
                "params": {lvl: dataclasses.asdict(p)
                           for lvl, p in self.params.items()},
                "samples": {lvl: s.as_dict()
                            for lvl, s in self.samples.items()}}


# ---------------------------------------------------------------------------
# Measurement providers — ONE interface for offline microbenches and the
# online telemetry loop; everything downstream is the same fitting path.
# ---------------------------------------------------------------------------
def _level_topo(level: str, n: int, p: GenModelParams, unit_bytes: int):
    """Single-switch stand-in for a level class: link bandwidth chosen so
    the simulator's bytes/bw pricing equals the level's per-unit β."""
    bw = unit_bytes / p.beta if p.beta > 0 else 1e18
    return single_switch(n, bw=bw, lat=0.0, level=level)


def _closed_form_fig4(source: GenModelParams, cfg: "CalibrationConfig"
                      ) -> tuple[np.ndarray, np.ndarray]:
    """The Fig.-4 fan-in curve sampled from the closed form
    T(x) = (x+1)·S·δ + (x−1)·S·γ — the one synthesis shared by the
    closed-form backend and the online provider's δ/γ carry-over."""
    xs = np.array(cfg.fig4_xs, dtype=float)
    s = cfg.fig4_size
    times = (xs + 1) * s * source.delta + (xs - 1) * s * source.gamma
    return xs, times


# ---------------------------------------------------------------------------
# Refit guardrails (DESIGN.md §12)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamGuard:
    """Plausibility envelope for fitted GenModelParams. The caps are
    deliberately loose — ~1000× the largest Table-5 value — because the
    guard exists to stop *garbage* (NaN from a degenerate design matrix,
    negative per-unit costs, a β implying sub-kB/s links), not to
    second-guess a legitimate fit. `max_step_ratio` bounds per-refit
    movement of any single term: one fault-distorted sample window can
    move the fleet's model by at most that factor per refit."""
    max_alpha: float = 10.0       # seconds of launch overhead per round
    max_beta: float = 1e-3        # s per 4-byte unit (≈4 kB/s links)
    max_gamma: float = 1e-3
    max_delta: float = 1e-3
    max_epsilon: float = 1e-3
    min_w_t: int = 1
    max_w_t: int = 1 << 20
    max_step_ratio: float = 8.0


DEFAULT_GUARD = ParamGuard()

_TERM_CAPS = (("alpha", "max_alpha"), ("beta", "max_beta"),
              ("gamma", "max_gamma"), ("delta", "max_delta"),
              ("epsilon", "max_epsilon"))


def validate_params(p: GenModelParams,
                    guard: ParamGuard | None = None) -> list[str]:
    """Violation strings for an implausible fit (empty list = sane).
    Checks every cost term for NaN/inf, negativity and the guard's
    plausibility cap, and w_t for range."""
    guard = guard or DEFAULT_GUARD
    bad = []
    for term, cap in _TERM_CAPS:
        v = float(getattr(p, term))
        if not np.isfinite(v):
            bad.append(f"{term} is not finite ({v})")
        elif v < 0.0:
            bad.append(f"{term} is negative ({v:.3g})")
        elif v > getattr(guard, cap):
            bad.append(f"{term} {v:.3g} exceeds plausibility cap "
                       f"{getattr(guard, cap):.3g}")
    w = int(p.w_t)
    if not guard.min_w_t <= w <= guard.max_w_t:
        bad.append(f"w_t {w} outside [{guard.min_w_t}, {guard.max_w_t}]")
    return bad


def clamp_params(old: GenModelParams, new: GenModelParams,
                 guard: ParamGuard | None = None
                 ) -> tuple[GenModelParams, list[str]]:
    """Clamp each fitted term into [old/r, old·r] of its previous value
    (r = guard.max_step_ratio) so one refit cannot swing the model by
    more than a bounded factor. Terms whose previous value is 0 are
    capped at the guard's plausibility limit instead (no ratio basis).
    Returns (clamped params, names of clamped terms)."""
    guard = guard or DEFAULT_GUARD
    r = float(guard.max_step_ratio)
    updates, clamped = {}, []
    for term, cap in _TERM_CAPS:
        ov, nv = float(getattr(old, term)), float(getattr(new, term))
        if ov > 0.0:
            lo, hi = ov / r, ov * r
        else:
            lo, hi = 0.0, float(getattr(guard, cap))
        cv = min(max(nv, lo), hi)
        if cv != nv:
            clamped.append(term)
            updates[term] = cv
    w = int(new.w_t)
    cw = min(max(w, guard.min_w_t), guard.max_w_t)
    if cw != w:
        clamped.append("w_t")
        updates["w_t"] = cw
    return (replace(new, **updates) if updates else new), clamped


def quarantine_outliers(samples, k: float = 4.0) -> tuple[list, list]:
    """Split telemetry samples into (kept, quarantined). A sample is
    quarantined when its cps_equivalent time sits more than `k`× (or
    below 1/k×) the *median* of its own (n, size) group — a fault-window
    measurement (straggler, degraded link mid-flight, retry storm) that
    would otherwise drag the least squares. Groups smaller than 3 have
    no robust center and are kept whole."""
    groups: dict[tuple, list] = {}
    for s in samples:
        groups.setdefault((int(s.n), round(float(s.size_floats), 6)),
                          []).append(s)
    kept, quarantined = [], []
    for grp in groups.values():
        if len(grp) < 3:
            kept.extend(grp)
            continue
        med = float(np.median([float(s.cps_equivalent) for s in grp]))
        if med <= 0.0:
            kept.extend(grp)
            continue
        for s in grp:
            ratio = float(s.cps_equivalent) / med
            (quarantined if (ratio > k or ratio < 1.0 / k)
             else kept).append(s)
    return kept, quarantined


class MeasurementProvider:
    """A source of the two microbench curves `fit_level` consumes.

    `cps_curve` returns (ns, sizes, times) of co-located-PS AllReduce
    runs; `fig4_curve` returns (xs, times) of the fan-in fold
    microbench. Subclasses measure (simulator / closed form / real lax
    collectives / runtime telemetry); the fit never knows which.
    """

    name = "base"

    def cps_curve(self, level: str, source: GenModelParams,
                  cfg: "CalibrationConfig") -> tuple[np.ndarray, ...]:
        raise NotImplementedError

    def fig4_curve(self, level: str, source: GenModelParams,
                   cfg: "CalibrationConfig"
                   ) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def pin_w_t(self, level: str, source: GenModelParams) -> int | None:
        """Incast threshold to pin during the CPS fit, or None to
        grid-search it from the curve (the offline default: dense
        (N, S) sweeps identify w_t robustly)."""
        return None


class SimulatorProvider(MeasurementProvider):
    """Drive core.simulator over a single-switch stand-in topology (the
    default backend; deterministic, runs anywhere)."""

    name = "simulator"

    def cps_curve(self, level, source, cfg):
        ns, sizes, times = [], [], []
        for n in cfg.ns:
            topo = _level_topo(level, n, source, cfg.unit_bytes)
            sim = Simulator(topo, {level: source, "server": source},
                            unit_bytes=cfg.unit_bytes, engine=cfg.engine)
            for s in cfg.sizes:
                ns.append(float(n))
                sizes.append(float(s))
                times.append(sim.simulate(plans_mod.cps(n, s)).total)
        return np.array(ns), np.array(sizes), np.array(times)

    def fig4_curve(self, level, source, cfg):
        """Fan-in microbench: fold x blocks of S units on one server.
        T(x) = (x+1)·S·δ + (x−1)·S·γ — purely local, no communication, so
        the simulator backend subtracts the per-round launch α it
        charges."""
        xs = np.array(cfg.fig4_xs, dtype=float)
        s = cfg.fig4_size
        times = []
        for x in cfg.fig4_xs:
            topo = _level_topo(level, 2, source, cfg.unit_bytes)
            sim = Simulator(topo, {level: source, "server": source},
                            unit_bytes=cfg.unit_bytes, engine=cfg.engine)
            p = plans_mod.Plan("fig4", 2, s)
            st = plans_mod.Step()
            st.reduces.append(plans_mod.ReduceOp(0, int(x), s))
            p.steps.append(st)
            times.append(sim.simulate(p).total - source.alpha)
        return xs, np.array(times)


class ClosedFormProvider(MeasurementProvider):
    """Sample the Table-2 closed forms directly (exact round-trip; the
    calibration tests pin parameter recovery against this)."""

    name = "closed_form"

    def cps_curve(self, level, source, cfg):
        ns, sizes, times = [], [], []
        for n in cfg.ns:
            for s in cfg.sizes:
                ns.append(float(n))
                sizes.append(float(s))
                times.append(cost_cps(n, s, source))
        return np.array(ns), np.array(sizes), np.array(times)

    def fig4_curve(self, level, source, cfg):
        return _closed_form_fig4(source, cfg)


class LaxProvider(MeasurementProvider):
    """Time real `lax` collectives on the local mesh (≥2 JAX devices).
    The local devices can't distinguish level classes, so every level
    gets the same curve."""

    name = "lax"

    def cps_curve(self, level, source, cfg):
        return measure_lax_cps(cfg.ns, cfg.sizes)

    def fig4_curve(self, level, source, cfg):
        xs = np.array(cfg.fig4_xs, dtype=float)
        return xs, _measure_host_fold(cfg.fig4_xs, cfg.fig4_size)


class TelemetryProvider(MeasurementProvider):
    """Replay runtime telemetry as the CPS curve — the online half of the
    measure→fit loop (DESIGN.md §10).

    `PlannerService.observe` normalizes every measured collective into a
    CPS-equivalent sample (`core.fitting.cps_equivalent_time`) and files
    it under the axis's level class in `runtime.telemetry.Telemetry`.
    This provider hands those samples to the exact same Table-2 least
    squares the offline microbenches use. The Fig.-4 memory curve is not
    measurable online (arrival timings cannot separate δ from γ), so it
    is synthesized from the *current* params: the δ/γ split carries
    over, while α, ε, w_t and the measured combination 2β+γ refit from
    live data — the terms that actually drift with contention, failed
    links and thermal throttling.
    """

    name = "telemetry"

    def __init__(self, telemetry, min_samples: int = 4,
                 quarantine_k: float | None = 4.0):
        self.telemetry = telemetry
        self.min_samples = int(min_samples)
        self.quarantine_k = quarantine_k
        self.quarantined = 0          # samples dropped by the last curve

    def cps_curve(self, level, source, cfg):
        samples = self.telemetry.samples(level)
        if self.quarantine_k:
            # robust-filter fault-window outliers BEFORE the diversity /
            # min-sample checks: a poisoned window must not both distort
            # the fit and count toward its sample quorum (DESIGN.md §12)
            kept, dropped = quarantine_outliers(samples,
                                                k=self.quarantine_k)
            self.quarantined = len(dropped)
            if dropped:
                from repro.runtime.metrics import default_metrics
                default_metrics().counter(
                    "planner_quarantined_samples_total",
                    "telemetry samples excluded from refits as outliers"
                ).inc(len(dropped))
                samples = kept
        if len(samples) < self.min_samples:
            raise ValueError(
                f"telemetry has {len(samples)} samples for level "
                f"{level!r}; need >= {self.min_samples}")
        # many copies of ONE (n, S) point make the Table-2 design matrix
        # rank-1: the lstsq minimum-norm solution would be degenerate
        # (α collapses into the size-proportional columns) and the
        # swapped-in params would misprice every OTHER point. Refuse —
        # the refit trigger (`PlannerService.observe`) checks the same
        # diversity before claiming a refit.
        points = {(s.n, round(float(s.size_floats), 6)) for s in samples}
        if len(points) < 2:
            raise ValueError(
                f"telemetry samples for level {level!r} cover a single "
                f"(n, size) point; need >= 2 distinct points to fit")
        ns = np.array([float(s.n) for s in samples])
        sizes = np.array([float(s.size_floats) for s in samples])
        times = np.array([float(s.cps_equivalent) for s in samples])
        return ns, sizes, times

    def fig4_curve(self, level, source, cfg):
        return _closed_form_fig4(source, cfg)

    def pin_w_t(self, level, source):
        """Online samples are sparse (a handful of (n, S) points from
        whatever axes the mesh happens to have), so the w_t grid search
        would let the incast column absorb β drift. The threshold is a
        switch-buffer property, not a contention effect — carry the
        current value over and let α/β/ε refit from live data."""
        return int(source.w_t)


_PROVIDERS = {p.name: p for p in (SimulatorProvider, ClosedFormProvider,
                                  LaxProvider)}


def provider_for(cfg: CalibrationConfig) -> MeasurementProvider:
    cls = _PROVIDERS.get(cfg.backend)
    if cls is None:
        raise ValueError(f"unknown backend {cfg.backend!r}")
    return cls()


def measure_cps_curve(level: str, source: GenModelParams,
                      cfg: CalibrationConfig) -> tuple[np.ndarray, ...]:
    return provider_for(cfg).cps_curve(level, source, cfg)


def measure_fig4_curve(level: str, source: GenModelParams,
                       cfg: CalibrationConfig) -> tuple[np.ndarray, np.ndarray]:
    return provider_for(cfg).fig4_curve(level, source, cfg)


def _measure_host_fold(fan_ins, s: float, repeats: int = 5) -> np.ndarray:
    """Real Fig.-4 measurement: time folding x blocks of S floats into an
    accumulator on this host. Follows T(x) = (x+1)·S·δ + (x−1)·S·γ with the
    host's actual memory/add throughput."""
    import time

    times = []
    for x in fan_ins:
        blocks = [np.ones(int(s), np.float32) for _ in range(int(x))]
        acc = np.empty(int(s), np.float32)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            np.copyto(acc, blocks[0])
            for b in blocks[1:]:
                np.add(acc, b, out=acc)
            ts.append(time.perf_counter() - t0)
        times.append(sorted(ts)[len(ts) // 2])
    return np.array(times)


def measure_lax_cps(ns, sizes, axis_name: str = "cal", repeats: int = 3):
    """Optional: time real CPS AllReduce on local JAX devices. Returns the
    same (ns, sizes, times) triple as the synthetic backends. Requires ≥2
    devices; raises RuntimeError otherwise (callers gate on it)."""
    import time

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import collectives
    from repro.core.compat import shard_map

    devs = jax.devices()
    out_ns, out_sizes, out_times = [], [], []
    for n in ns:
        if n > len(devs):
            continue
        mesh = Mesh(np.array(devs[:n]), (axis_name,))
        for s in sizes:
            x = jnp.ones((n, int(s)), jnp.float32)
            fn = jax.jit(shard_map(
                lambda v: collectives.allreduce(v, axis_name, "cps"),
                mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name)))
            fn(x).block_until_ready()           # compile
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn(x).block_until_ready()
                ts.append(time.perf_counter() - t0)
            out_ns.append(float(n))
            out_sizes.append(float(s))
            out_times.append(sorted(ts)[len(ts) // 2])
    if not out_ns:
        raise RuntimeError("lax backend needs >= 2 local JAX devices")
    return np.array(out_ns), np.array(out_sizes), np.array(out_times)


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------
def fit_level(samples: LevelSamples,
              w_t: int | None = None) -> GenModelParams:
    """Combine the two microbench fits into one GenModelParams:
    α/ε/w_t and the combined 2β+γ from the CPS curve, δ/γ from Fig. 4,
    then β = (2β+γ)/2 − γ/2 once γ is known. `w_t` pins the incast
    threshold instead of grid-searching it (see
    `MeasurementProvider.pin_w_t`)."""
    cps_fit = fit_from_cps_benchmarks(samples.ns, samples.sizes,
                                      samples.times, w_t=w_t)
    delta, gamma = fit_delta_gamma(samples.fig4_xs, samples.fig4_times,
                                   samples.fig4_size)
    delta, gamma = max(delta, 0.0), max(gamma, 0.0)
    bg = cps_fit.beta + cps_fit.gamma / 2.0      # = β + γ/2 (identifiable)
    beta = max(bg - gamma / 2.0, 0.0)
    return replace(cps_fit, beta=beta, gamma=gamma, delta=delta)


def calibrate_levels(source: dict[str, GenModelParams] | None = None,
                     cfg: CalibrationConfig | None = None, *,
                     provider: MeasurementProvider | None = None
                     ) -> CalibrationResult:
    """Measure + refit every level class. `source` is the measurement
    target: the params dict the synthetic backends treat as ground truth
    (on a real cluster the lax backend replaces it with actual timings).

    `provider` overrides the backend lookup with a custom
    `MeasurementProvider` instance — notably `TelemetryProvider`, which
    replays online runtime samples through this very path so offline and
    online calibration share one fitting codepath."""
    source = source or PAPER_TABLE5
    cfg = cfg or CalibrationConfig()
    provider = provider or provider_for(cfg)
    params: dict[str, GenModelParams] = {}
    samples: dict[str, LevelSamples] = {}
    for level in cfg.levels:
        src = source.get(level, source.get("server", GenModelParams()))
        ns, sizes, times = provider.cps_curve(level, src, cfg)
        xs, f4times = provider.fig4_curve(level, src, cfg)
        ls = LevelSamples(level=level, ns=ns, sizes=sizes, times=times,
                          fig4_xs=xs, fig4_size=cfg.fig4_size,
                          fig4_times=f4times)
        samples[level] = ls
        params[level] = fit_level(ls, w_t=provider.pin_w_t(level, src))
    return CalibrationResult(params=params, samples=samples,
                             backend=provider.name)
