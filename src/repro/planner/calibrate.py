"""Calibration harness: refit GenModelParams from measured curves (§3.4).

Replaces the frozen PAPER_TABLE5 / TPU_V5E presets with *fitted* instances.
Per level class we run the paper's two microbenches and feed the resulting
(size, time) samples to core.fitting:

  * the co-located-PS curve over (N, S) — identifies α, 2β+γ, δ, ε, w_t
    (Table-2 CPS design matrix, w_t by residual grid search);
  * the Fig.-4 fan-in microbench — separates δ from γ, which the CPS curve
    alone cannot (only 2β+γ is identifiable there).

Backends:

  * "simulator"   — drive core.simulator over a single-switch topology of
    the level class (the default; deterministic, runs anywhere);
  * "closed_form" — sample the Table-2 closed forms directly (exact
    round-trip, used by the calibration tests);
  * "lax"         — time real `lax` collectives on the local mesh; only
    available with ≥2 JAX devices and kept behind an explicit opt-in so
    headless CI never touches the accelerator runtime.

Recorded samples are kept on the result so they can be persisted/inspected
(the service exposes them through its stats).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core import plans as plans_mod
from repro.core.cost_model import GenModelParams, PAPER_TABLE5, cost_cps
from repro.core.fitting import fit_delta_gamma, fit_from_cps_benchmarks
from repro.core.simulator import Simulator
from repro.core.topology import single_switch


@dataclass(frozen=True)
class CalibrationConfig:
    ns: tuple[int, ...] = tuple(range(2, 17))
    sizes: tuple[float, ...] = (1e6, 4e6, 1.6e7)     # data units (floats)
    fig4_xs: tuple[int, ...] = tuple(range(2, 17))   # fan-in degrees
    fig4_size: float = 1e6
    backend: str = "simulator"    # simulator | closed_form | lax
    unit_bytes: int = 4
    levels: tuple[str, ...] = ("cross_dc", "root_sw", "middle_sw", "server")
    # plan-evaluation engine for the simulator backend's sweeps: "fast"
    # (compiled, default) or "reference" (pure-Python oracle); None defers
    # to $REPRO_SIM_ENGINE / the Simulator default.
    engine: str | None = None


@dataclass
class LevelSamples:
    """Raw measurement record for one level class."""
    level: str
    ns: np.ndarray
    sizes: np.ndarray
    times: np.ndarray
    fig4_xs: np.ndarray
    fig4_size: float
    fig4_times: np.ndarray

    def as_dict(self) -> dict:
        return {"level": self.level, "ns": self.ns.tolist(),
                "sizes": self.sizes.tolist(), "times": self.times.tolist(),
                "fig4_xs": self.fig4_xs.tolist(),
                "fig4_size": self.fig4_size,
                "fig4_times": self.fig4_times.tolist()}


@dataclass
class CalibrationResult:
    params: dict[str, GenModelParams]
    samples: dict[str, LevelSamples] = field(default_factory=dict)
    backend: str = "simulator"

    def as_dict(self) -> dict:
        return {"backend": self.backend,
                "params": {lvl: dataclasses.asdict(p)
                           for lvl, p in self.params.items()},
                "samples": {lvl: s.as_dict()
                            for lvl, s in self.samples.items()}}


# ---------------------------------------------------------------------------
# Sample generation
# ---------------------------------------------------------------------------
def _level_topo(level: str, n: int, p: GenModelParams, unit_bytes: int):
    """Single-switch stand-in for a level class: link bandwidth chosen so
    the simulator's bytes/bw pricing equals the level's per-unit β."""
    bw = unit_bytes / p.beta if p.beta > 0 else 1e18
    return single_switch(n, bw=bw, lat=0.0, level=level)


def measure_cps_curve(level: str, source: GenModelParams,
                      cfg: CalibrationConfig) -> tuple[np.ndarray, ...]:
    if cfg.backend == "lax":
        # Real collectives on the local mesh. The local devices can't
        # distinguish level classes, so every level gets the same curve.
        return measure_lax_cps(cfg.ns, cfg.sizes)
    ns, sizes, times = [], [], []
    for n in cfg.ns:
        topo = None
        sim = None
        if cfg.backend == "simulator":
            topo = _level_topo(level, n, source, cfg.unit_bytes)
            sim = Simulator(topo, {level: source, "server": source},
                            unit_bytes=cfg.unit_bytes, engine=cfg.engine)
        for s in cfg.sizes:
            ns.append(float(n))
            sizes.append(float(s))
            if cfg.backend == "closed_form":
                times.append(cost_cps(n, s, source))
            elif cfg.backend == "simulator":
                times.append(sim.simulate(plans_mod.cps(n, s)).total)
            else:
                raise ValueError(f"unknown backend {cfg.backend!r}")
    return np.array(ns), np.array(sizes), np.array(times)


def measure_fig4_curve(level: str, source: GenModelParams,
                       cfg: CalibrationConfig) -> tuple[np.ndarray, np.ndarray]:
    """Fan-in microbench: fold x blocks of S units on one server.
    T(x) = (x+1)·S·δ + (x−1)·S·γ — purely local, no communication, so the
    simulator backend subtracts the per-round launch α it charges."""
    xs = np.array(cfg.fig4_xs, dtype=float)
    s = cfg.fig4_size
    if cfg.backend == "closed_form":
        times = (xs + 1) * s * source.delta + (xs - 1) * s * source.gamma
        return xs, times
    if cfg.backend == "lax":
        return xs, _measure_host_fold(cfg.fig4_xs, s)
    if cfg.backend != "simulator":
        raise ValueError(f"unknown backend {cfg.backend!r}")
    times = []
    for x in cfg.fig4_xs:
        topo = _level_topo(level, 2, source, cfg.unit_bytes)
        sim = Simulator(topo, {level: source, "server": source},
                        unit_bytes=cfg.unit_bytes, engine=cfg.engine)
        p = plans_mod.Plan("fig4", 2, s)
        st = plans_mod.Step()
        st.reduces.append(plans_mod.ReduceOp(0, int(x), s))
        p.steps.append(st)
        times.append(sim.simulate(p).total - source.alpha)
    return xs, np.array(times)


def _measure_host_fold(fan_ins, s: float, repeats: int = 5) -> np.ndarray:
    """Real Fig.-4 measurement: time folding x blocks of S floats into an
    accumulator on this host. Follows T(x) = (x+1)·S·δ + (x−1)·S·γ with the
    host's actual memory/add throughput."""
    import time

    times = []
    for x in fan_ins:
        blocks = [np.ones(int(s), np.float32) for _ in range(int(x))]
        acc = np.empty(int(s), np.float32)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            np.copyto(acc, blocks[0])
            for b in blocks[1:]:
                np.add(acc, b, out=acc)
            ts.append(time.perf_counter() - t0)
        times.append(sorted(ts)[len(ts) // 2])
    return np.array(times)


def measure_lax_cps(ns, sizes, axis_name: str = "cal", repeats: int = 3):
    """Optional: time real CPS AllReduce on local JAX devices. Returns the
    same (ns, sizes, times) triple as the synthetic backends. Requires ≥2
    devices; raises RuntimeError otherwise (callers gate on it)."""
    import time

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import collectives
    from repro.core.compat import shard_map

    devs = jax.devices()
    out_ns, out_sizes, out_times = [], [], []
    for n in ns:
        if n > len(devs):
            continue
        mesh = Mesh(np.array(devs[:n]), (axis_name,))
        for s in sizes:
            x = jnp.ones((n, int(s)), jnp.float32)
            fn = jax.jit(shard_map(
                lambda v: collectives.allreduce(v, axis_name, "cps"),
                mesh=mesh, in_specs=P(axis_name), out_specs=P(axis_name)))
            fn(x).block_until_ready()           # compile
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                fn(x).block_until_ready()
                ts.append(time.perf_counter() - t0)
            out_ns.append(float(n))
            out_sizes.append(float(s))
            out_times.append(sorted(ts)[len(ts) // 2])
    if not out_ns:
        raise RuntimeError("lax backend needs >= 2 local JAX devices")
    return np.array(out_ns), np.array(out_sizes), np.array(out_times)


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------
def fit_level(samples: LevelSamples) -> GenModelParams:
    """Combine the two microbench fits into one GenModelParams:
    α/ε/w_t and the combined 2β+γ from the CPS curve, δ/γ from Fig. 4,
    then β = (2β+γ)/2 − γ/2 once γ is known."""
    cps_fit = fit_from_cps_benchmarks(samples.ns, samples.sizes,
                                      samples.times)
    delta, gamma = fit_delta_gamma(samples.fig4_xs, samples.fig4_times,
                                   samples.fig4_size)
    delta, gamma = max(delta, 0.0), max(gamma, 0.0)
    bg = cps_fit.beta + cps_fit.gamma / 2.0      # = β + γ/2 (identifiable)
    beta = max(bg - gamma / 2.0, 0.0)
    return replace(cps_fit, beta=beta, gamma=gamma, delta=delta)


def calibrate_levels(source: dict[str, GenModelParams] | None = None,
                     cfg: CalibrationConfig | None = None
                     ) -> CalibrationResult:
    """Measure + refit every level class. `source` is the measurement
    target: the params dict the synthetic backends treat as ground truth
    (on a real cluster the lax backend replaces it with actual timings)."""
    source = source or PAPER_TABLE5
    cfg = cfg or CalibrationConfig()
    params: dict[str, GenModelParams] = {}
    samples: dict[str, LevelSamples] = {}
    for level in cfg.levels:
        src = source.get(level, source.get("server", GenModelParams()))
        ns, sizes, times = measure_cps_curve(level, src, cfg)
        xs, f4times = measure_fig4_curve(level, src, cfg)
        ls = LevelSamples(level=level, ns=ns, sizes=sizes, times=times,
                          fig4_xs=xs, fig4_size=cfg.fig4_size,
                          fig4_times=f4times)
        samples[level] = ls
        params[level] = fit_level(ls)
    return CalibrationResult(params=params, samples=samples,
                             backend=cfg.backend)
