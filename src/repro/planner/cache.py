"""Size-bucketed, thread-safe LRU plan cache with JSON disk persistence.

Message sizes are continuous but plans are not size-sensitive within a
small factor, so requests are snapped to *geometric buckets*: bucket k
covers (base·g^(k-1), base·g^k] and is represented by its upper bound.
Every request inside a bucket shares one cached plan, which keeps the
cache small (log-many buckets across the whole useful size range) while
bounding the pricing error a shared plan can introduce.

Entries are JSON-serializable dicts (see plan_to_json/plan_from_json), so
`save()`/`load()` round-trip through disk and warm plans survive process
restarts.
"""
from __future__ import annotations

import json
import math
import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field

from repro.core.plans import Plan, ReduceOp, Step, Transfer
from repro.runtime.metrics import default_metrics


# ---------------------------------------------------------------------------
# Plan IR <-> JSON
# ---------------------------------------------------------------------------
def _blk(b) -> tuple[int, ...] | None:
    return None if b is None else tuple(int(x) for x in b)


def plan_to_json(plan: Plan) -> dict:
    """Serialize the plan, block annotations included — a disk-warm plan
    must stay lowerable (`core.lower`) after the round-trip. The 4-tuple
    rows stay readable by pre-block-IR entries (3-tuples load as
    unannotated)."""
    return {
        "name": plan.name, "n": plan.n, "size": plan.size,
        "servers": plan.servers, "num_blocks": plan.num_blocks,
        "family": plan.family,
        "steps": [{
            "transfers": [[t.src, t.dst, t.size,
                           None if t.blocks is None else list(t.blocks)]
                          for t in st.transfers],
            "reduces": [[r.server, r.fan_in, r.size,
                         None if r.blocks is None else list(r.blocks)]
                        for r in st.reduces],
        } for st in plan.steps],
    }


def plan_from_json(d: dict) -> Plan:
    steps = []
    for sd in d["steps"]:
        st = Step()
        st.transfers = [Transfer(int(row[0]), int(row[1]), float(row[2]),
                                 blocks=_blk(row[3]) if len(row) > 3
                                 else None)
                        for row in sd["transfers"]]
        st.reduces = [ReduceOp(int(row[0]), int(row[1]), float(row[2]),
                               blocks=_blk(row[3]) if len(row) > 3
                               else None)
                      for row in sd["reduces"]]
        steps.append(st)
    nb = d.get("num_blocks")
    return Plan(d["name"], int(d["n"]), float(d["size"]), steps=steps,
                servers=d.get("servers"),
                num_blocks=None if nb is None else int(nb),
                family=str(d.get("family", "allreduce")))


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------
@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    disk_loads: int = 0
    puts: int = 0
    load_errors: int = 0     # corrupt entries/files skipped by load()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    COUNTERS = ("hits", "misses", "evictions", "disk_loads", "puts",
                "load_errors")

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "disk_loads": self.disk_loads,
                "puts": self.puts, "load_errors": self.load_errors,
                "hit_rate": self.hit_rate}

    def absorb(self, d: dict) -> None:
        """Accumulate persisted counters (a restored snapshot's lifetime
        stats) into this instance; unknown/derived keys (hit_rate) are
        ignored."""
        for k in self.COUNTERS:
            v = d.get(k)
            if isinstance(v, (int, float)):
                setattr(self, k, getattr(self, k) + int(v))


class PlanCache:
    """LRU over canonical plan keys. Values are JSON-serializable dicts;
    callers attach deserialized objects under the `_obj` key (kept out of
    the persisted form) to avoid re-parsing on every warm hit."""

    def __init__(self, capacity: int = 128, *, bucket_base: int = 4096,
                 bucket_growth: float = 2.0, path: str | None = None,
                 autosave: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if bucket_growth <= 1.0:
            raise ValueError("bucket_growth must be > 1")
        self.capacity = capacity
        self.bucket_base = int(bucket_base)
        self.bucket_growth = float(bucket_growth)
        self.path = path
        self.autosave = autosave
        self.stats = CacheStats()
        self._lock = threading.RLock()
        self._entries: OrderedDict[str, dict] = OrderedDict()
        if path and os.path.exists(path):
            self.load(path)

    # ---- size bucketing ----------------------------------------------------
    def bucket(self, nbytes: int | float) -> int:
        """Snap a request size to its geometric bucket's representative
        (upper-bound) size. bucket(base) == base; bucket(base+1) == the
        next bucket up."""
        nbytes = float(nbytes)
        if nbytes <= self.bucket_base:
            return self.bucket_base
        k = math.ceil(round(
            math.log(nbytes / self.bucket_base)
            / math.log(self.bucket_growth), 12))
        return int(round(self.bucket_base * self.bucket_growth ** k))

    # ---- core ops ----------------------------------------------------------
    def get(self, key: str) -> dict | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.stats.misses += 1
                default_metrics().counter(
                    "plan_cache_misses_total",
                    "plan-cache lookups that missed").inc()
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            default_metrics().counter(
                "plan_cache_hits_total",
                "plan-cache lookups served warm").inc()
            return entry

    def put(self, key: str, entry: dict) -> None:
        snapshot = None
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            self.stats.puts += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
            if self.autosave and self.path:
                snapshot = self._snapshot_locked()
        m = default_metrics()
        m.counter("plan_cache_puts_total", "plan-cache inserts").inc()
        m.gauge("plan_cache_entries", "entries currently cached"
                ).set(len(self))
        # Serialize + write outside the lock: an autosave (whole-file JSON
        # rewrite) must not block concurrent get()s on the hot path.
        # Concurrent writers each replace atomically; last one wins.
        if snapshot is not None:
            self._write(self.path, *snapshot)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def drop_derived(self, kinds: tuple[str, ...] = ("bucket_plan",)) -> int:
        """Invalidate derived *executable* artifacts while keeping the
        priced plans: pops every entry's `_exec` map (lowered
        `CompiledSchedule`s, keyed by placement) and evicts whole entries
        whose `kind` is in `kinds` (bucket plans — their chosen size is a
        function of the axis sizes they were priced for). Returns the
        number of artifacts dropped. Used by
        `core.bucketing.invalidate_schedules` after a remesh/resume."""
        dropped = 0
        with self._lock:
            for key in list(self._entries):
                entry = self._entries[key]
                if entry.get("kind") in kinds:
                    del self._entries[key]
                    dropped += 1
                    continue
                execs = entry.pop("_exec", None)
                if execs:
                    dropped += len(execs)
        return dropped

    def derived_count(self, kinds: tuple[str, ...] = ("bucket_plan",)) -> int:
        """Number of derived executable artifacts currently cached
        (lowered schedules + bucket-plan entries) — the set
        `drop_derived` would remove."""
        with self._lock:
            count = 0
            for entry in self._entries.values():
                if entry.get("kind") in kinds:
                    count += 1
                else:
                    count += len(entry.get("_exec") or ())
            return count

    # ---- persistence -------------------------------------------------------
    def _snapshot_locked(self) -> tuple[dict, dict]:
        """(entries, stats) under the lock — the stats block rides in the
        snapshot so a restart reports true lifetime hit rates instead of
        starting the counters over."""
        entries = {k: {kk: vv for kk, vv in v.items()
                       if not kk.startswith("_")}
                   for k, v in self._entries.items()}
        stats = {k: getattr(self.stats, k) for k in CacheStats.COUNTERS}
        return entries, stats

    @staticmethod
    def _write(path: str, payload: dict, stats: dict | None = None) -> None:
        tmp = f"{path}.tmp{os.getpid()}-{threading.get_ident()}"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": payload,
                       "stats": stats or {}}, f)
        os.replace(tmp, path)

    def save(self, path: str | None = None) -> None:
        path = path or self.path
        if not path:
            raise ValueError("no persistence path configured")
        with self._lock:
            payload, stats = self._snapshot_locked()
        self._write(path, payload, stats)

    @staticmethod
    def _entry_valid(v) -> bool:
        """Structural validation of one persisted entry: a decodable file
        can still carry truncated/bit-flipped entries (DESIGN.md §12).
        Plan entries must round-trip `plan_from_json`; axis-plan and
        bucket-plan entries must carry their row lists. Never raises."""
        if not isinstance(v, dict):
            return False
        try:
            if "plan" in v:
                plan_from_json(v["plan"])
                return "algo" in v and "predicted_time" in v
            if "axis_plans" in v:
                return all(isinstance(row, (list, tuple)) and len(row) >= 3
                           for row in v["axis_plans"])
            if "bucket_floats" in v:     # bucket-plan sweep entry
                return "num_buckets" in v
        except Exception:
            return False
        return True    # unknown entry shape: let the reader decide

    def _count_load_error(self, n: int = 1) -> None:
        self.stats.load_errors += n
        default_metrics().counter(
            "planner_cache_load_errors_total",
            "corrupt plan-cache files/entries skipped at load").inc(n)

    def load(self, path: str | None = None) -> int:
        path = path or self.path
        if not path:
            raise ValueError("no persistence path configured")
        try:
            with open(path) as f:
                payload = json.load(f)
        except FileNotFoundError:
            return 0
        except (OSError, ValueError):
            # truncated/corrupt persistence file: startup proceeds with a
            # cold cache instead of crashing the service (DESIGN.md §12)
            with self._lock:
                self._count_load_error()
            return 0
        if not isinstance(payload, dict):
            with self._lock:
                self._count_load_error()
            return 0
        entries = payload.get("entries", {})
        if not isinstance(entries, dict):
            with self._lock:
                self._count_load_error()
            return 0
        bad = [k for k, v in entries.items() if not self._entry_valid(v)]
        for k in bad:
            entries.pop(k)
        with self._lock:
            if bad:
                self._count_load_error(len(bad))
            # restore lifetime counters BEFORE counting this load's disk
            # hits, so the persisted history and the fresh activity both
            # land exactly once
            stats = payload.get("stats")
            if isinstance(stats, dict):
                self.stats.absorb(stats)
            for k, v in entries.items():
                if k not in self._entries:
                    self._entries[k] = v
                    self.stats.disk_loads += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
        return len(entries)
