"""Canonical fingerprints for topologies, GenModel params and plan requests.

Two topologies that differ only in node names or child ordering produce the
same AllReduce plan (GenTree only looks at structure, level classes and
link capacities), so they must share a cache entry.  We hash a *canonical
form*: each node is reduced to (level, uplink_bw, uplink_latency, sorted
child forms); server names and ids never enter the hash.

Floats are formatted with `%.9g` before hashing so that values which
round-trip through JSON (disk persistence) keep the same fingerprint.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping, Sequence

from repro.core.cost_model import GenModelParams
from repro.core.topology import TopoNode


def _f(x: float) -> str:
    return "%.9g" % float(x)


def topo_canonical(node: TopoNode) -> tuple:
    """Order-invariant canonical form of a topology subtree. Health state
    is part of the form (DESIGN.md §12): a degraded link already hashes
    differently through its reduced uplink_bw, but a dead node with
    unchanged capacities must not alias its healthy twin — plans built
    before a failure would otherwise stay reachable after it."""
    children = tuple(sorted(topo_canonical(c) for c in node.children))
    return (node.level, _f(node.uplink_bw), _f(node.uplink_latency),
            getattr(node, "health", "ok"), children)


def params_canonical(params: Mapping[str, GenModelParams] | None) -> tuple:
    if not params:
        return ()
    out = []
    for level in sorted(params):
        p = params[level]
        out.append((level,) + tuple(
            _f(getattr(p, f.name)) for f in dataclasses.fields(p)))
    return tuple(out)


def _digest(obj: Any) -> str:
    payload = json.dumps(obj, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()


def fingerprint_topo(topo: TopoNode) -> str:
    """Stable hex digest; equal for isomorphic trees."""
    return _digest(topo_canonical(topo))


def fingerprint_params(params: Mapping[str, GenModelParams] | None) -> str:
    return _digest(params_canonical(params))


def plan_key(topo: TopoNode, params: Mapping[str, GenModelParams] | None,
             nbytes_bucket: int, dtype: str = "float32",
             extra: tuple = ()) -> str:
    """Cache key for a full GenTree plan request."""
    return _digest([topo_canonical(topo), params_canonical(params),
                    int(nbytes_bucket), dtype, list(extra)])


def axis_key(axes: Sequence[tuple[str, int]],
             params: Mapping[str, GenModelParams] | None,
             size_bucket: int, extra: tuple = ()) -> str:
    """Cache key for a per-mesh-axis plan request (launch.train hot path).

    The axis *names* matter (they name mesh levels with different param
    classes), the sizes matter, and so do the params. `extra` carries
    service configuration that changes the answer (pricing engine,
    gentree kwargs) so differently-configured services never share an
    axis-plan entry.
    """
    return _digest([[list(a) for a in axes], params_canonical(params),
                    int(size_bucket), list(extra)])
