"""δ-optimal fused N-ary reduction — the paper's memory-access insight as a
Pallas TPU kernel.

Paper §3.1: a chain of pairwise adds over x blocks costs 3(x−1)·S memory
ops (re-reading the accumulator from HBM every step); a single fused x-ary
add costs (x+1)·S — up to 66.7 % less memory traffic. On TPU the same
economics hold for HBM→VMEM movement: this kernel streams all x operand
tiles into VMEM once per output tile and writes the sum once, accumulating
in a VREG-resident f32 register block.

`grouped_reduce` additionally exposes the paper's HCPS compute pattern: the
x operands are folded with a bounded fan-in f per pass (fan-in trade-off of
Theorem 2), which is what a hierarchical plan's per-stage reduction does.

Block layout: operands (x, L) are tiled along L with TILE_L lanes
(128-aligned for the VPU); the x axis is delivered whole per tile so the
reduction is a single VMEM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_L = 4096  # lanes per tile; 4096·x·4B ≤ VMEM budget for x ≤ ~256


def pad_lanes(arr: jax.Array, multiple: int, axis: int = -1) -> jax.Array:
    """Zero-pad `arr` along `axis` to a multiple — the ONE pad used by
    every lane-tiled kernel here and in `kernels.quant`, applied exactly
    once before the single `pallas_call` (never by re-entering the caller,
    which would trace a second kernel per non-aligned size)."""
    axis = axis % arr.ndim
    pad = (-arr.shape[axis]) % multiple
    if not pad:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths)


def _fused_reduce_kernel(parts_ref, out_ref):
    # parts_ref: (x, TILE_L) in VMEM; single pass, f32 accumulation.
    acc = parts_ref[...].astype(jnp.float32).sum(axis=0)
    out_ref[...] = acc.astype(out_ref.dtype)


def fused_reduce(parts: jax.Array, *, tile_l: int = DEFAULT_TILE_L,
                 interpret: bool = False) -> jax.Array:
    """Sum x blocks: (x, L) → (L,), one memory pass ((x+1)·L touches)."""
    x, L = parts.shape
    tile = min(tile_l, L)
    parts = pad_lanes(parts, tile)   # once; sliced back after the call
    grid = (parts.shape[1] // tile,)
    out = pl.pallas_call(
        _fused_reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((x, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((parts.shape[1],), parts.dtype),
        interpret=interpret,
    )(parts)
    return out[:L] if out.shape[0] != L else out


def _grouped_reduce_kernel(parts_ref, out_ref, *, fan_in: int):
    # Fold with bounded fan-in per pass (HCPS-style): tree of f-ary adds.
    vals = parts_ref[...].astype(jnp.float32)
    while vals.shape[0] > 1:
        x = vals.shape[0]
        pad = (-x) % fan_in
        if pad:
            vals = jnp.concatenate(
                [vals, jnp.zeros((pad,) + vals.shape[1:], vals.dtype)], axis=0)
        vals = vals.reshape(-1, fan_in, vals.shape[-1]).sum(axis=1)
    out_ref[...] = vals[0].astype(out_ref.dtype)


def grouped_reduce(parts: jax.Array, fan_in: int, *,
                   tile_l: int = DEFAULT_TILE_L,
                   interpret: bool = False) -> jax.Array:
    """Sum x blocks with bounded fan-in f per folding pass: (x, L) → (L,).

    fan_in=2 reproduces the Ring/RHD chained-compute pattern; fan_in=x is
    `fused_reduce`. In-VMEM the intermediate writes are free (VREGs), but
    the schedule mirrors the plan's per-stage reduction structure.
    """
    x, L = parts.shape
    tile = min(tile_l, L)
    parts = pad_lanes(parts, tile)
    grid = (parts.shape[1] // tile,)
    out = pl.pallas_call(
        functools.partial(_grouped_reduce_kernel, fan_in=fan_in),
        grid=grid,
        in_specs=[pl.BlockSpec((x, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((parts.shape[1],), parts.dtype),
        interpret=interpret,
    )(parts)
    return out[:L] if out.shape[0] != L else out
