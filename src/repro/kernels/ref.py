"""Pure-jnp oracles for every Pallas kernel (allclose-tested in CI)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_reduce_ref(parts: jax.Array) -> jax.Array:
    """(x, L) → (L,), f32 accumulation."""
    return parts.astype(jnp.float32).sum(axis=0).astype(parts.dtype)


def chained_reduce_ref(parts: jax.Array) -> jax.Array:
    """The δ-suboptimal pairwise chain (Ring compute pattern), as a
    numerical oracle for grouped_reduce(fan_in=2)."""
    acc = parts[0].astype(jnp.float32)
    for i in range(1, parts.shape[0]):
        acc = acc + parts[i].astype(jnp.float32)
    return acc.astype(parts.dtype)


def _quant_tiles(x: jax.Array, tile: int) -> jax.Array:
    from .fused_reduce import pad_lanes
    x = pad_lanes(x.astype(jnp.float32), tile)
    return x.reshape(x.shape[0], x.shape[1] // tile, tile)


def quantize_ref(x: jax.Array, wire: str = "float8_e4m3fn", tile: int = 128
                 ) -> tuple[jax.Array, jax.Array]:
    """(W, L) → (q (W, Lp) wire, scales (W, nt)); same math as the kernel."""
    from .quant import WIRE_QMAX
    t = _quant_tiles(x, tile)
    qmax = WIRE_QMAX[wire]
    amax = jnp.max(jnp.abs(t), axis=-1)
    scale = amax / qmax
    safe = jnp.where(scale > 0.0, scale, 1.0)
    y = t / safe[..., None]
    if wire == "int8":
        y = jnp.clip(jnp.round(y), -qmax, qmax)
    else:
        y = jnp.clip(y, -qmax, qmax)
    q = y.astype(jnp.dtype(wire)).reshape(t.shape[0], -1)
    return q, jnp.where(amax > 0.0, scale, 0.0)


def dequantize_ref(q: jax.Array, scales: jax.Array, tile: int = 128,
                   out_len: int | None = None) -> jax.Array:
    W, Lp = q.shape
    t = q.reshape(W, Lp // tile, tile).astype(jnp.float32)
    out = (t * scales[..., None]).reshape(W, Lp)
    return out if out_len is None or out_len == Lp else out[:, :out_len]


def quant_reduce_ref(q: jax.Array, scales: jax.Array,
                     own: jax.Array | None = None, tile: int = 128,
                     out_len: int | None = None) -> jax.Array:
    out = dequantize_ref(q, scales, tile).sum(axis=0)
    if own is not None:
        from .fused_reduce import pad_lanes
        out = out + pad_lanes(own.astype(jnp.float32), tile)
    return out if out_len is None or out_len == out.shape[0] else out[:out_len]


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * w.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: float | None = None
                  ) -> jax.Array:
    """Dense oracle: q (B,Hq,Tq,D), k/v (B,Hkv,Tk,D) with GQA repeat.

    window > 0 limits attention to the last `window` keys (sliding window);
    softcap > 0 applies gemma-style logit soft-capping."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = (scale if scale is not None else D ** -0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * s
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(Tq)[:, None] + (Tk - Tq)   # right-aligned positions
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def wkv_ref(r, k, v, logw, u, s0, chunk: int = 32):
    """Chunked-parallel RWKV6 WKV oracle (same math as the Pallas kernel;
    shared with models/recurrence)."""
    from repro.models.recurrence import _wkv_chunk
    T = k.shape[2]
    c = min(chunk, T)
    while T % c:
        c -= 1
    return _wkv_chunk(r.astype(jnp.float32), k.astype(jnp.float32),
                      v.astype(jnp.float32), logw.astype(jnp.float32),
                      u.astype(jnp.float32), s0.astype(jnp.float32), c)


def ssm_scan_ref(u, dt, b, c, log_a, s0):
    """Sequential selective-SSM oracle: s_t = exp(dt⊙logA)s + (dt·u)⊗b;
    y_t = s·c. u/dt: (B,T,Di); b/c: (B,T,N); log_a: (Di,N); s0: (B,Di,N)."""
    import jax.lax as lax

    def step(s, xs):
        u_t, dt_t, b_t, c_t = xs
        decay = jnp.exp(dt_t[:, :, None] * log_a[None])
        s = decay * s + (dt_t * u_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", s, c_t)
        return s, y

    xs = (u.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    s_fin, ys = lax.scan(step, s0.astype(jnp.float32),
                         jax.tree.map(lambda a: a.astype(jnp.float32), xs))
    return ys.transpose(1, 0, 2).astype(u.dtype), s_fin
