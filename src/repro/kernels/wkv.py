"""Chunked RWKV6 WKV recurrence as a Pallas TPU kernel.

The XLA lowering of the chunked WKV (models/recurrence._wkv_chunk)
round-trips the (B, H, K, V) state and the (C, C, K) pair tensor through
HBM every chunk — the §Roofline analysis shows this makes the SSM family
memory-bound (rwkv train t_mem 54 s vs t_comp 0.24 s). This kernel keeps
the state AND the pair tile resident in VMEM across the whole sequence:
HBM traffic collapses to the r/k/v/w inputs + the output, one pass.

Layout: grid (B·H, T/C); the chunk axis is innermost so the VMEM scratch
state carries across chunks of the same (b, h) slice (standard Mosaic
accumulator pattern). Math is identical to the oracle `wkv_ref` (the
exponent form exp(Λ_t − Λ_s) keeps every exponent ≤ 0 — unconditionally
stable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref,
                s_scr, *, nc: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    rc = r_ref[0].astype(jnp.float32)           # (C, K)
    kc = k_ref[0].astype(jnp.float32)
    vc = v_ref[0].astype(jnp.float32)           # (C, V)
    lw = lw_ref[0].astype(jnp.float32)          # (C, K) ≤ 0
    u = u_ref[0].astype(jnp.float32)            # (1, K) broadcast row

    linc = jnp.cumsum(lw, axis=0)               # inclusive Λ
    lexc = linc - lw                            # exclusive
    s = s_scr[...]                              # (K, V)

    # state contribution: r_t decayed by Λ_{<t}
    o1 = (rc * jnp.exp(lexc)) @ s               # (C, V)
    # intra-chunk pairs s < t: exponent lexc_t − linc_s ≤ 0
    expo = lexc[:, None, :] - linc[None, :, :]  # (C, C, K)
    c = rc.shape[0]
    tmask = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    pair = jnp.where(tmask[:, :, None], jnp.exp(expo), 0.0)
    att = jnp.einsum("tk,sk,tsk->ts", rc, kc, pair)
    o2 = att @ vc                               # (C, V)
    # bonus (current token)
    bonus = jnp.sum(rc * kc * u, axis=-1, keepdims=True)
    o3 = bonus * vc
    o_ref[0] = (o1 + o2 + o3).astype(o_ref.dtype)

    # state update: decay by the whole chunk, add k_t (decayed to end) v_t
    ltot = linc[-1:, :]                         # (1, K)
    s_scr[...] = jnp.exp(ltot).T * s + \
        (kc * jnp.exp(ltot - linc)).T @ vc

    @pl.when(ci == nc - 1)
    def _fini():
        sT_ref[0] = s_scr[...].astype(sT_ref.dtype)


def wkv(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
        u: jax.Array, s0: jax.Array, *, chunk: int = 32,
        interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """r/k/logw: (B, H, T, K); v: (B, H, T, V); u: (H, K);
    s0: (B, H, K, V). Returns (out (B, H, T, V), s_final (B, H, K, V))."""
    B, H, T, K = k.shape
    V = v.shape[-1]
    C = min(chunk, T)
    while T % C:
        C -= 1
    nc = T // C
    bh = B * H

    def flat(a):
        return a.reshape((bh,) + a.shape[2:])

    rf, kf, vf, lwf = map(flat, (r, k, v, logw))
    uf = jnp.broadcast_to(u[None, :, None, :], (B, H, 1, K)).reshape(
        bh, 1, K)
    s0f = s0.reshape(bh, K, V)

    kernel = functools.partial(_wkv_kernel, nc=nc, chunk=C)
    out, s_fin = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, K), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, T, V), r.dtype),
            jax.ShapeDtypeStruct((bh, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf, s0f)
    return out.reshape(B, H, T, V), s_fin.reshape(B, H, K, V)
