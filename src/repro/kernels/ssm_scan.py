"""Selective-SSM (Mamba-style) chunked scan as a Pallas TPU kernel.

The XLA lowering round-trips the (B, Di, N) state through HBM on every
token (hymba's §Roofline memory term). This kernel keeps a (BD, N) state
tile in VMEM for the whole sequence and unrolls the C steps of each chunk
in-register:

    s_t = exp(dt_t ⊙ log_a) ⊙ s_{t-1} + (dt_t·u_t) ⊗ b_t
    y_t = s_t · c_t                                   (contract N)

Grid: (B, Di/BD, T/C) with the chunk axis innermost (VMEM scratch carries
state across chunks of the same (batch, channel-block) slice). Oracle:
`ref.ssm_scan_ref` (the same recurrence models/recurrence.mamba_ssm runs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, la_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, nc: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)        # (C, BD)
    dt = dt_ref[0].astype(jnp.float32)      # (C, BD)
    b = b_ref[0].astype(jnp.float32)        # (C, N)
    c = c_ref[0].astype(jnp.float32)        # (C, N)
    la = la_ref[...].astype(jnp.float32)    # (BD, N)

    s = s_scr[...]                          # (BD, N)
    ys = []
    for t in range(chunk):                  # unrolled; state stays in VREGs
        decay = jnp.exp(dt[t][:, None] * la)            # (BD, N)
        s = decay * s + (dt[t] * u[t])[:, None] * b[t][None, :]
        ys.append(jnp.sum(s * c[t][None, :], axis=-1))  # (BD,)
    y_ref[0] = jnp.stack(ys, axis=0).astype(y_ref.dtype)
    s_scr[...] = s

    @pl.when(ci == nc - 1)
    def _fini():
        sT_ref[0] = s_scr[...].astype(sT_ref.dtype)


def ssm_scan(u: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
             log_a: jax.Array, s0: jax.Array, *, chunk: int = 16,
             block_d: int = 512, interpret: bool = False
             ) -> tuple[jax.Array, jax.Array]:
    """u/dt: (B, T, Di); b/c: (B, T, N); log_a: (Di, N); s0: (B, Di, N).
    Returns (y (B, T, Di), s_final (B, Di, N))."""
    B, T, Di = u.shape
    N = b.shape[-1]
    C = min(chunk, T)
    while T % C:
        C -= 1
    nc = T // C
    bd = min(block_d, Di)
    while Di % bd:
        bd -= 1
    nd = Di // bd

    kernel = functools.partial(_ssm_kernel, nc=nc, chunk=C)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, C, bd), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, C, bd), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, C, N), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, C, N), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((bd, N), lambda bi, di, ci: (di, 0)),
            pl.BlockSpec((1, bd, N), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, bd), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, bd, N), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, Di), u.dtype),
            jax.ShapeDtypeStruct((B, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, b, c, log_a, s0)
    return y, s_fin
