"""Wire-format quantization kernels for compressed collectives.

The paper prices compression honestly: quant/dequant are extra γ/δ memory
passes (Eq. 11's C and D terms), while β·S and the incast term shrink with
the wire payload. These kernels are that trade's execution side — per-tile
symmetric quantization to fp8 (e4m3) or int8 with one f32 abs-max scale per
QUANT_TILE lanes, plus a fused *compressed* N-ary reduce that dequantizes
all x operand tiles in VMEM, accumulates in f32, and (optionally)
requantizes the output, all in a single memory pass — the δ-optimal shape
of `kernels.fused_reduce` carried over to the compressed domain.

Layouts mirror `fused_reduce`: payloads are (W, L) with L tiled along the
lane axis; scales are (W, nt) with nt = ceil(L / tile). A scale of 0 marks
an all-zero (or masked) tile — dequantization multiplies by the scale, so
such tiles decode to exactly 0 regardless of payload bits; the schedule
executor uses this to neutralize masked ppermute rows for free.

Interpret-mode fallback keeps CPU CI running the same code path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_reduce import pad_lanes

QUANT_TILE = 128  # lanes per f32 scale; matches Precision.scale_block

# Symmetric full-scale magnitude per wire dtype.
WIRE_QMAX = {
    "float8_e4m3fn": 448.0,   # finfo(float8_e4m3fn).max
    "int8": 127.0,
}


def wire_dtype(wire: str) -> jnp.dtype:
    if wire not in WIRE_QMAX:
        raise ValueError(f"unsupported wire dtype {wire!r}; "
                         f"one of {sorted(WIRE_QMAX)}")
    return jnp.dtype(wire)


def _encode(vals: jax.Array, wire: str) -> tuple[jax.Array, jax.Array]:
    """vals (..., tile) f32 → (q (..., tile) wire, scale (..., 1) f32).

    Shared by the Pallas kernels (per-block) and the jnp oracle (reshaped).
    scale = amax/qmax, stored as 0 for all-zero tiles so dequant is exact 0.
    """
    qmax = WIRE_QMAX[wire]
    amax = jnp.max(jnp.abs(vals), axis=-1, keepdims=True)
    scale = amax / qmax
    safe = jnp.where(scale > 0.0, scale, 1.0)
    y = vals / safe
    if wire == "int8":
        y = jnp.clip(jnp.round(y), -qmax, qmax)
    else:
        y = jnp.clip(y, -qmax, qmax)
    return y.astype(jnp.dtype(wire)), jnp.where(amax > 0.0, scale, 0.0)


def _quantize_kernel(x_ref, q_ref, s_ref, *, wire: str):
    q, s = _encode(x_ref[...].astype(jnp.float32), wire)
    q_ref[...] = q
    s_ref[...] = s


def quantize(x: jax.Array, wire: str = "float8_e4m3fn", *,
             tile: int = QUANT_TILE, interpret: bool = False
             ) -> tuple[jax.Array, jax.Array]:
    """(W, L) f32 → (q (W, Lp) wire, scales (W, nt) f32), Lp = tile-padded L.

    One memory pass: each (W, tile) block is read once, its abs-max scale
    and encoded payload written once.
    """
    W, L = x.shape
    x = pad_lanes(x.astype(jnp.float32), tile)
    nt = x.shape[1] // tile
    return pl.pallas_call(
        functools.partial(_quantize_kernel, wire=wire),
        grid=(nt,),
        in_specs=[pl.BlockSpec((W, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((W, tile), lambda i: (0, i)),
                   pl.BlockSpec((W, 1), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((W, nt * tile), wire_dtype(wire)),
                   jax.ShapeDtypeStruct((W, nt), jnp.float32)],
        interpret=interpret,
    )(x)


def _dequantize_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def dequantize(q: jax.Array, scales: jax.Array, *,
               tile: int = QUANT_TILE, out_len: int | None = None,
               interpret: bool = False) -> jax.Array:
    """(q (W, Lp) wire, scales (W, nt)) → (W, out_len or Lp) f32."""
    W, Lp = q.shape
    nt = Lp // tile
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((W, tile), lambda i: (0, i)),
                  pl.BlockSpec((W, 1), lambda i: (0, i))],
        out_specs=pl.BlockSpec((W, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((W, Lp), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return out if out_len is None or out_len == Lp else out[:, :out_len]


def _quant_reduce_kernel(q_ref, s_ref, out_ref):
    # q (K, tile) wire, s (K, 1) f32: dequant + x-ary add, one VMEM pass.
    out_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).sum(axis=0)


def _quant_reduce_own_kernel(q_ref, s_ref, own_ref, out_ref):
    acc = (q_ref[...].astype(jnp.float32) * s_ref[...]).sum(axis=0)
    out_ref[...] = acc + own_ref[...].astype(jnp.float32)


def quant_reduce(q: jax.Array, scales: jax.Array,
                 own: jax.Array | None = None, *,
                 tile: int = QUANT_TILE, out_len: int | None = None,
                 interpret: bool = False) -> jax.Array:
    """Fused compressed reduce: (K, Lp) wire + (K, nt) scales [+ own (Lp,)
    f32 resident partial] → (out_len or Lp,) f32.

    Dequantizes the K operand tiles in VMEM and accumulates in f32 without
    materializing any decompressed operand in HBM — (K+1)·S memory touches
    at *wire* width for the operands, exactly what the δ ledger charges.
    """
    K, Lp = q.shape
    nt = Lp // tile
    common = dict(
        grid=(nt,),
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Lp,), jnp.float32),
        interpret=interpret,
    )
    q_spec = pl.BlockSpec((K, tile), lambda i: (0, i))
    s_spec = pl.BlockSpec((K, 1), lambda i: (0, i))
    if own is None:
        out = pl.pallas_call(_quant_reduce_kernel,
                             in_specs=[q_spec, s_spec], **common)(q, scales)
    else:
        own = pad_lanes(own.astype(jnp.float32), tile)
        out = pl.pallas_call(
            _quant_reduce_own_kernel,
            in_specs=[q_spec, s_spec, pl.BlockSpec((tile,), lambda i: (i,))],
            **common)(q, scales, own)
    return out if out_len is None or out_len == Lp else out[:out_len]


def _quant_reduce_requant_kernel(q_ref, s_ref, qout_ref, sout_ref, *,
                                 wire: str):
    acc = (q_ref[...].astype(jnp.float32) * s_ref[...]).sum(axis=0)
    qo, so = _encode(acc[None, :], wire)
    qout_ref[...] = qo[0]
    sout_ref[...] = so[0]


def quant_reduce_requant(q: jax.Array, scales: jax.Array,
                         wire: str = "float8_e4m3fn", *,
                         tile: int = QUANT_TILE, interpret: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """Compressed reduce that stays on the wire: (K, Lp) + (K, nt) scales →
    (q (Lp,) wire, scales (nt,) f32), dequant→accumulate→requantize in a
    single memory pass (for schedules that chain folds without a full-
    precision resident partial)."""
    K, Lp = q.shape
    nt = Lp // tile
    return pl.pallas_call(
        functools.partial(_quant_reduce_requant_kernel, wire=wire),
        grid=(nt,),
        in_specs=[pl.BlockSpec((K, tile), lambda i: (0, i)),
                  pl.BlockSpec((K, 1), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((Lp,), wire_dtype(wire)),
                   jax.ShapeDtypeStruct((nt,), jnp.float32)],
        interpret=interpret,
    )(q, scales)
