"""Fused RMSNorm Pallas kernel: one HBM pass per row block (read x, write y)
instead of XLA's potential two (mean-of-squares reduce + scale). Rows are
tiled (BR, D) in VMEM; f32 math, input-dtype output."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * rms * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., D); w: (D,). Flattens leading dims into row blocks."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(x2.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
