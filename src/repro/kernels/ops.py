"""Jit'd public wrappers for the Pallas kernels.

On TPU the Mosaic kernels run natively; elsewhere (this CPU container) the
wrappers either run interpret-mode Pallas (tests) or fall back to the
pure-jnp oracle (production CPU path, keeps dry-run HLO clean). Select with
`impl`: "auto" | "pallas" | "interpret" | "ref".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .fused_reduce import fused_reduce as _fused_reduce, grouped_reduce as _grouped
from .quant import (dequantize as _dequantize, quant_reduce as _quant_reduce,
                    quantize as _quantize)
from .rmsnorm import rmsnorm as _rmsnorm
from .wkv import wkv as _wkv


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if _on_tpu() else "ref"


@functools.partial(jax.jit, static_argnames=("impl",))
def fused_reduce(parts: jax.Array, impl: str = "auto") -> jax.Array:
    """(x, L) → (L,): δ-optimal single-pass N-ary sum."""
    mode = _resolve(impl)
    if mode == "ref":
        return ref.fused_reduce_ref(parts)
    return _fused_reduce(parts, interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("fan_in", "impl"))
def grouped_reduce(parts: jax.Array, fan_in: int, impl: str = "auto"
                   ) -> jax.Array:
    mode = _resolve(impl)
    if mode == "ref":
        return ref.fused_reduce_ref(parts)
    return _grouped(parts, fan_in, interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("wire", "tile", "impl"))
def quantize(x: jax.Array, wire: str = "float8_e4m3fn", tile: int = 128,
             impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """(W, L) → (payload (W, Lp) wire, per-tile f32 scales (W, nt))."""
    mode = _resolve(impl)
    if mode == "ref":
        return ref.quantize_ref(x, wire, tile)
    return _quantize(x, wire, tile=tile, interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("tile", "out_len", "impl"))
def dequantize(q: jax.Array, scales: jax.Array, tile: int = 128,
               out_len: int | None = None, impl: str = "auto") -> jax.Array:
    mode = _resolve(impl)
    if mode == "ref":
        return ref.dequantize_ref(q, scales, tile, out_len)
    return _dequantize(q, scales, tile=tile, out_len=out_len,
                       interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("tile", "out_len", "impl"))
def quant_reduce(q: jax.Array, scales: jax.Array,
                 own: jax.Array | None = None, tile: int = 128,
                 out_len: int | None = None, impl: str = "auto") -> jax.Array:
    """Fused compressed N-ary reduce: dequant in VMEM, accumulate f32."""
    mode = _resolve(impl)
    if mode == "ref":
        return ref.quant_reduce_ref(q, scales, own, tile, out_len)
    return _quant_reduce(q, scales, own, tile=tile, out_len=out_len,
                         interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "impl"))
def attention(q, k, v, causal: bool = True, window: int = 0,
              softcap: float = 0.0, scale: float | None = None,
              impl: str = "auto") -> jax.Array:
    mode = _resolve(impl)
    if mode == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale)
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm(x, w, eps: float = 1e-6, impl: str = "auto") -> jax.Array:
    mode = _resolve(impl)
    if mode == "ref":
        return ref.rmsnorm_ref(x, w, eps)
    return _rmsnorm(x, w, eps=eps, interpret=(mode == "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def wkv(r, k, v, logw, u, s0, chunk: int = 32, impl: str = "auto"):
    """Chunked RWKV6 recurrence: state + pair tile stay in VMEM on TPU."""
    mode = _resolve(impl)
    if mode == "ref":
        return ref.wkv_ref(r, k, v, logw, u, s0, chunk=chunk)
    return _wkv(r, k, v, logw, u, s0, chunk=chunk,
                interpret=(mode == "interpret"))
