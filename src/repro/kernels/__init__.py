"""Pallas TPU kernels for the framework's compute hot-spots.

fused_reduce — the paper's δ-optimal N-ary reduction (core contribution's
compute half); flash_attention — long-context attention; wkv — the RWKV6
chunked recurrence (SSM-family memory bottleneck); rmsnorm — fused
normalization. Each has a pure-jnp oracle in ref.py and a jit'd wrapper in
ops.py; interpret=True validates kernel bodies on CPU.
"""
from . import ops, ref  # noqa: F401
