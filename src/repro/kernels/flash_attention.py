"""Flash attention (TPU Pallas) — causal / sliding-window / softcap / GQA.

Target: TPU MXU. Online-softmax with VMEM scratch accumulators; the KV loop
is the innermost grid dimension so each (batch, head, q-block) revisits its
output block across KV blocks (standard Mosaic pattern). Block shapes are
128-aligned for the MXU; fully-masked KV blocks are skipped via pl.when
(the sliding-window case prunes to O(T·W) work — this is what makes the
`long_500k` shapes tractable for local-attention architectures).

Validated on CPU with interpret=True against kernels.ref.attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, tq: int, tk: int, nk: int):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    off = tk - tq  # right-aligned query positions
    q_lo = qi * bq + off
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    k_hi = k_lo + bk - 1
    needed = jnp.bool_(True)
    if causal:
        needed &= k_lo <= q_hi
    if window > 0:
        needed &= k_hi > q_lo - window

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_scr[...] = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fini():
        o_ref[0, 0] = (acc_scr[...] /
                       (l_scr[...] + 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D) with Hq % Hkv == 0."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, bq, Tk, bk)
    nq, nk = Tq // bq, Tk // bk
    s = scale if scale is not None else D ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=s, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, tq=Tq, tk=Tk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
