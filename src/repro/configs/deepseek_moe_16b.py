"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408(expert)
vocab=102400, 2 shared + 64 routed experts top-6, fine-grained.
[arXiv:2401.06066; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408 * 8,        # dense-equivalent (first layer is dense in the
    #                       original; we keep all layers MoE for uniform scan)
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    moe_groups=16,      # DP-local dispatch groups (EXPERIMENTS.md §Perf)
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
