"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global interleave, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    d_head=256,
    # 5 local (sliding 1024) : 1 global
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
    rope_theta=1e6,
)

# Mostly-local attention → long_500k runs (global layers decode linearly).
SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
