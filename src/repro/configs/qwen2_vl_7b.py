"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution. Vision frontend is a STUB:
input_specs() provides precomputed patch/token embeddings + 3D position
ids. [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    mrope_sections=(16, 24, 24),   # t/h/w sections of the 128-dim head
    embeds_input=True,
    rope_theta=1e6,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
