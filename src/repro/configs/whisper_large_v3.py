"""whisper-large-v3 [audio] — 32L(dec) d_model=1280 20H d_ff=5120
vocab=51866 — encoder-decoder; conv frontend is a STUB (input_specs()
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    embeds_input=True,      # frame embeddings from the stubbed conv stem
)

# Enc-dec full attention → long_500k skipped.
SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
