"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b family; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    rope_theta=1e4,
)

# Pure full attention → long_500k skipped (DESIGN.md §Arch-applicability).
SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k")
