"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    d_ff_expert=16384,
    window_pattern=(4096,),     # SWA everywhere
    rope_theta=1e6,
    moe_groups=16,      # DP-local dispatch groups (EXPERIMENTS.md §Perf)
)

# SWA → decode touches a bounded window; long_500k runs.
SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
