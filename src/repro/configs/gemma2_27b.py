"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating, logit softcap. [arXiv:2408.00118; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    d_head=128,
    window_pattern=(4096, 0),      # alternating local(4096)/global
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=1e4,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
