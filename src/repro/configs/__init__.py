"""Assigned architecture configs (public literature) + registry.

Each module defines CONFIG (full scale, exercised only via the dry-run's
ShapeDtypeStructs) and SUPPORTED_SHAPES. `get_config(name)` resolves by id.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "stablelm_12b",
    "qwen3_32b",
    "gemma3_4b",
    "gemma2_27b",
    "qwen2_vl_7b",
    "hymba_1_5b",
    "rwkv6_1_6b",
    "deepseek_moe_16b",
    "mixtral_8x22b",
    "whisper_large_v3",
]


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.CONFIG


def supported_shapes(name: str) -> list[str]:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return list(mod.SUPPORTED_SHAPES)


def all_cells():
    """Every (arch, shape) cell — 40 total; unsupported ones are flagged
    so the dry-run records them as documented skips."""
    from repro.models.config import SHAPES
    cells = []
    for a in ARCHS:
        sup = supported_shapes(a)
        for s in SHAPES:
            cells.append((a, s, s in sup))
    return cells
