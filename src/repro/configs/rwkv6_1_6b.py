"""rwkv6-1.6b (Finch) [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — data-dependent per-channel decay. [arXiv:2404.05892; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,           # wkv heads (head_dim 64)
    n_kv_heads=32,
    d_head=64,
    d_ff=7168,
    vocab=65536,
    ssm_state=64,
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
