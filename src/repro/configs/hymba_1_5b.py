"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads in each layer
(outputs mean-combined after per-branch norm). Meta-tokens omitted
(DESIGN.md §Arch-applicability). [arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_expand=2,
    # hymba uses SWA in most layers; 3 global full-attn layers
    window_pattern=(1024,) * 10 + (0,),
)

SUPPORTED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
