"""LR schedules (pure functions of the step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak: float, warmup: int, total: int,
                  floor: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak * s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
