"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state (m, v) is kept in f32 regardless of param dtype; ZeRO
partitioning is a *sharding* concern — the launcher shards these leaves
over the data axis (ZeRO-1) via NamedSharding, the math here is
sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> tuple[Params, jax.Array]:
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params: Params, grads: Params, opt_state: dict,
                 cfg: AdamWConfig, lr: jax.Array | float | None = None
                 ) -> tuple[Params, dict, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
    step = opt_state["step"] + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mh = m / b1c
        vh = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (jax.tree.unflatten(tdef, new_p),
            {"m": jax.tree.unflatten(tdef, new_m),
             "v": jax.tree.unflatten(tdef, new_v),
             "step": step},
            gnorm)
