"""Lowering: block-annotated Plan IR → executable shard_map schedules
(DESIGN.md §8).

`lower_plan` compiles any block-annotated `Plan` (the flat builders in
`core.plans`, GenTree output, baseline plans) into a `CompiledSchedule`:
a sequence of `lax.ppermute` rounds plus N-ary fold phases that runs
inside `shard_map` over a named mesh axis of size plan.n. This closes the
gap between the priced IR and the executed collective — the same Plan the
simulator prices is what the devices run.

Pipeline per synchronized Step:

  1. *expand* — every Transfer/ReduceOp is split into unit-block moves
     (src, dst, block) / folds (dst, block, fan) using the block identity
     recorded by the builders; server ids map to mesh indices through the
     placement map.
  2. *validate* — a symbolic dataflow tracks, per (device, block), the
     bitmask of server contributions held. Fold operands must be pairwise
     disjoint (else: duplicate block reduce), the ReduceOp fan_in must
     match the incoming copies (± the resident copy), and after the final
     step every device must hold every block's full contribution set
     (all-gather completeness). Violations raise `LoweringError` with the
     offending step/server/block.
  3. *schedule* — the step's moves are greedily edge-colored into partial
     permutations (each device sends ≤1 and receives ≤1 block per round —
     a valid `ppermute`), received values land in a staging buffer, and
     fold phases combine staged copies (plus, where the IR says so, the
     device's resident partial) with one N-ary reduction per fold — the
     δ-optimal single-pass fold, routed through the Pallas `fused_reduce`
     kernel when the caller provides it.

The ReduceScatter/AllGather boundary (the step after the last fold) is
detected so ZeRO-3 can run the two halves separately; when num_blocks is
a multiple of n a canonical reorder round is appended so
`reduce_scatter()` yields device i's contiguous shard i (and
`all_gather()` un-reorders before mirroring), matching the flat
collectives' shard contract.

`run_numpy` executes the identical schedule on a (n, size) numpy matrix —
the no-JAX reference used by the hypothesis equivalence suite.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.runtime.trace import default_tracer

from .plans import Plan


class LoweringError(ValueError):
    """A Plan that cannot be compiled into an executable schedule."""


# ---------------------------------------------------------------------------
# Compiled structures (numpy constants, indexed by mesh position)
# ---------------------------------------------------------------------------
@dataclass(eq=False)
class PermRound:
    """One partial permutation. Each device sends at most one *payload*
    per round — a stack of up to W block rows to a single peer (all the
    step's moves between one (src, dst) pair coalesce into one payload,
    so e.g. RHD's half-vector exchange is ONE ppermute, not size/2 of
    them); -1 entries pad payloads narrower than the round width."""
    perm: tuple[tuple[int, int], ...]   # (src_mesh, dst_mesh) pairs
    send_blks: np.ndarray               # (n, W) block rows sent, -1 = pad
    recv_off: np.ndarray                # (n,) first staging row, -1 = none


@dataclass(eq=False)
class FoldPhase:
    """One fold slot: per device, which staged copies (plus optionally the
    resident partial) collapse into which block row."""
    blk: np.ndarray                     # (n,) target block row, -1 = idle
    ops: np.ndarray                     # (n, K) staging rows, -1 = masked
    include_self: np.ndarray            # (n,) bool: resident partial is an operand


@dataclass(eq=False)
class ExecStep:
    rounds: list[PermRound] = field(default_factory=list)
    n_slots: int = 0
    folds: list[FoldPhase] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Single-round / single-fold executors. `CompiledSchedule._run_steps` loops
# these over one buffer; `core.overlap.MergedSchedule` interleaves them over
# TWO independent buffers (RS-of-bucket-k rounds between AG-of-bucket-(k-1)
# rounds), so merged execution reuses the exact machinery the dataflow
# validation in `lower_plan` vouched for.
# ---------------------------------------------------------------------------
def _round_jax(rd: PermRound, buf, stage, idx, zero, axis_name: str,
               ri: int = 0):
    """One ppermute round: gather up to W block rows of `buf`, permute
    along `axis_name`, land the payload in `stage` at recv_off. Returns
    the updated staging buffer."""
    import jax.numpy as jnp
    from jax import lax

    with default_tracer().span("exec/round", round=ri,
                               width=int(rd.send_blks.shape[1]),
                               pairs=len(rd.perm)):
        w = rd.send_blks.shape[1]
        chunk = buf.shape[1]
        sb = jnp.asarray(rd.send_blks)[idx]      # (W,)
        rows = [jnp.where(
            sb[j] >= 0,
            lax.dynamic_index_in_dim(
                buf, jnp.maximum(sb[j], 0), 0, keepdims=False),
            zero) for j in range(w)]
        recv = lax.ppermute(jnp.stack(rows), axis_name,
                            list(rd.perm))  # (W, chunk)
        off = jnp.asarray(rd.recv_off)[idx]
        safe = jnp.maximum(off, 0)
        cur = lax.dynamic_slice(stage, (safe, 0), (w, chunk))
        return lax.dynamic_update_slice(
            stage, jnp.where(off >= 0, recv, cur), (safe, 0))


def _fold_jax(fd: FoldPhase, buf, stage, idx, zero,
              fused_reduce: Callable | None, fi: int = 0):
    """One fold phase: staged copies (plus optionally the resident
    partial) collapse into their target block row of `buf`. Returns the
    updated buffer."""
    import jax.numpy as jnp
    from jax import lax

    with default_tracer().span("exec/fold", fold=fi,
                               fan=int(fd.ops.shape[1])):
        blk = jnp.asarray(fd.blk)[idx]
        safeb = jnp.maximum(blk, 0)
        own = lax.dynamic_index_in_dim(buf, safeb, 0, keepdims=False)
        rows = []
        for j in range(fd.ops.shape[1]):
            s = jnp.asarray(fd.ops[:, j])[idx]
            r = lax.dynamic_index_in_dim(
                stage, jnp.maximum(s, 0), 0, keepdims=False)
            rows.append(jnp.where(s >= 0, r, zero))
        rows.append(jnp.where(
            jnp.asarray(fd.include_self)[idx], own, zero))
        stacked = jnp.stack(rows, axis=0)
        if fused_reduce is not None and stacked.shape[0] > 1:
            folded = fused_reduce(stacked).astype(buf.dtype)
        else:
            folded = stacked.sum(axis=0)
        return lax.dynamic_update_index_in_dim(
            buf, jnp.where(blk >= 0, folded, own), safeb, 0)


@dataclass(eq=False)
class CompiledSchedule:
    """An executable AllReduce: run inside shard_map over `axis_name`."""
    plan_name: str
    n: int
    num_blocks: int
    rs: list[ExecStep]                  # ReduceScatter half
    ag: list[ExecStep]                  # AllGather half
    owner_of_block: np.ndarray          # (num_blocks,) mesh index post-RS
    # canonical-shard support (num_blocks % n == 0): device i's shard is
    # blocks [i*k, (i+1)*k) after the reorder round
    blocks_per_shard: int | None
    reorder: ExecStep | None            # post-RS: owner(b) → b // k
    unorder: ExecStep | None            # pre-AG inverse of `reorder`
    placement: tuple[int, ...]          # server id at each mesh index
    # wire format (cost_model.Precision) for compressed execution: ppermute
    # rounds move quantized payloads + per-tile f32 scales and folds run the
    # fused dequant-reduce. None = full-precision (bit-identical legacy
    # path). The numpy mirror always runs at full precision.
    wire: object | None = None
    # Collective family this schedule computes (plans.FAMILIES). The entry
    # points enforce it: an allgather-family schedule only answers
    # all_gather(), an all_to_all-family one only all_to_all(), etc.
    family: str = "allreduce"
    # p2p family only: the (src_mesh, dst_mesh) edges, for the guard's
    # flat ppermute rung and for introspection.
    perm_pairs: tuple[tuple[int, int], ...] | None = None

    def with_wire(self, precision) -> "CompiledSchedule":
        """A copy of this schedule bound to a wire format (or None to
        strip it). Variants are memoized per wire name: re-resolving the
        same schedule at the same precision returns the SAME object, so
        guard wrappers — memoized per schedule object — keep sticky
        demotion across re-resolves while each wire variant (and the
        full-precision original) still demotes independently."""
        import dataclasses
        if precision is not None and precision.name == "f32":
            precision = None
        if precision is None and self.wire is None:
            return self
        name = precision.name if precision is not None else ""
        variants = self.__dict__.setdefault("_wire_variants", {})
        v = variants.get(name)
        if v is None:
            # replace() copies declared fields only: the variant starts
            # with a clean __dict__ (no inherited guard wrapper / memo)
            v = dataclasses.replace(self, wire=precision)
            variants[name] = v
        return v

    # ---- stats -------------------------------------------------------------
    def total_rounds(self) -> int:
        return sum(len(st.rounds) for st in self.rs + self.ag)

    def describe(self) -> str:
        w = f" wire={self.wire.name}" if self.wire is not None else ""
        f = f" family={self.family}" if self.family != "allreduce" else ""
        return (f"{self.plan_name}: n={self.n} blocks={self.num_blocks} "
                f"steps={len(self.rs)}+{len(self.ag)} "
                f"ppermute_rounds={self.total_rounds()}{w}{f}")

    def _check_family(self, entry: str, allowed: tuple[str, ...]) -> None:
        if self.family not in allowed:
            raise LoweringError(
                f"schedule {self.plan_name!r} compiles a "
                f"{self.family!r}-family plan — {entry}() only runs "
                f"{'/'.join(allowed)} schedules")

    # ---- jax execution (call inside shard_map) -----------------------------
    def _run_steps(self, steps: Sequence[ExecStep], buf, axis_name: str,
                   fused_reduce: Callable | None, phase: str = "steps"):
        # Span caveat (DESIGN.md §11): this body runs at shard_map/jit
        # TRACE time, so span durations measure staging-out, not device
        # execution — but the span *structure* (which step, which round,
        # which fold, at what width/fan) is exactly the executed
        # schedule. Device wall time stays the telemetry hub's job; the
        # numpy mirror below records real durations for the same spans.
        import jax.numpy as jnp
        from jax import lax

        if self.wire is not None:
            return self._run_steps_wire(steps, buf, axis_name, phase)

        tracer = default_tracer()
        idx = lax.axis_index(axis_name)
        chunk = buf.shape[1]
        zero = jnp.zeros((chunk,), buf.dtype)
        for si, st in enumerate(steps):
            if not st.rounds and not st.folds:
                continue
            with tracer.span(f"exec/{phase}/step", step=si,
                             rounds=len(st.rounds), folds=len(st.folds),
                             plan=self.plan_name):
                stage = jnp.zeros((max(st.n_slots, 1), chunk), buf.dtype)
                for ri, rd in enumerate(st.rounds):
                    stage = _round_jax(rd, buf, stage, idx, zero,
                                       axis_name, ri)
                for fi, fd in enumerate(st.folds):
                    buf = _fold_jax(fd, buf, stage, idx, zero,
                                    fused_reduce, fi)
        return buf

    def _run_steps_wire(self, steps: Sequence[ExecStep], buf,
                        axis_name: str, phase: str = "steps"):
        """Compressed mirror of `_run_steps` (DESIGN.md §13): each ppermute
        round quantizes its payload stack to the wire dtype (per-tile f32
        scales ride in a parallel ppermute), staging buffers hold wire
        bytes, and each fold runs the fused dequant-reduce — operands
        decompress in VMEM, accumulate in f32 with the resident partial,
        and only the folded row lands back in `buf`. Masked rows are
        neutralized by a zero *scale* (dequant of anything × 0 = 0), so
        the pad trick of the full-precision path carries over for free.
        bf16 (scale-free) wires skip the scale plumbing: plain casts."""
        import jax.numpy as jnp
        from jax import lax

        from repro.kernels import ops as kops

        tracer = default_tracer()
        idx = lax.axis_index(axis_name)
        chunk = buf.shape[1]
        wire = self.wire
        wdtype = jnp.dtype(wire.wire_dtype)
        tile = int(wire.scale_block or 0)
        scaled = tile > 0
        if scaled:
            nt = -(-chunk // tile)
            lanes = nt * tile
        else:
            nt, lanes = 0, chunk
        zero = jnp.zeros((chunk,), buf.dtype)
        for si, st in enumerate(steps):
            if not st.rounds and not st.folds:
                continue
            with tracer.span(f"exec/{phase}/step", step=si,
                             rounds=len(st.rounds), folds=len(st.folds),
                             plan=self.plan_name, wire=wire.name):
                slots = max(st.n_slots, 1)
                stage_q = jnp.zeros((slots, lanes), wdtype)
                stage_s = (jnp.zeros((slots, nt), jnp.float32)
                           if scaled else None)
                for ri, rd in enumerate(st.rounds):
                    with tracer.span("exec/round", round=ri,
                                     width=int(rd.send_blks.shape[1]),
                                     pairs=len(rd.perm), wire=wire.name):
                        w = rd.send_blks.shape[1]
                        sb = jnp.asarray(rd.send_blks)[idx]      # (W,)
                        rows = [jnp.where(
                            sb[j] >= 0,
                            lax.dynamic_index_in_dim(
                                buf, jnp.maximum(sb[j], 0), 0,
                                keepdims=False),
                            zero) for j in range(w)]
                        payload = jnp.stack(rows).astype(jnp.float32)
                        if scaled:
                            q, s = kops.quantize(payload, wire.wire_dtype,
                                                 tile)
                            q = lax.ppermute(q, axis_name, list(rd.perm))
                            s = lax.ppermute(s, axis_name, list(rd.perm))
                        else:
                            q = lax.ppermute(payload.astype(wdtype),
                                             axis_name, list(rd.perm))
                            s = None
                        off = jnp.asarray(rd.recv_off)[idx]
                        safe = jnp.maximum(off, 0)
                        cur_q = lax.dynamic_slice(stage_q, (safe, 0),
                                                  (w, lanes))
                        stage_q = lax.dynamic_update_slice(
                            stage_q, jnp.where(off >= 0, q, cur_q),
                            (safe, 0))
                        if scaled:
                            cur_s = lax.dynamic_slice(stage_s, (safe, 0),
                                                      (w, nt))
                            stage_s = lax.dynamic_update_slice(
                                stage_s, jnp.where(off >= 0, s, cur_s),
                                (safe, 0))
                for fi, fd in enumerate(st.folds):
                    with tracer.span("exec/fold", fold=fi,
                                     fan=int(fd.ops.shape[1]),
                                     wire=wire.name):
                        blk = jnp.asarray(fd.blk)[idx]
                        safeb = jnp.maximum(blk, 0)
                        own = lax.dynamic_index_in_dim(buf, safeb, 0,
                                                       keepdims=False)
                        own_in = jnp.where(
                            jnp.asarray(fd.include_self)[idx], own, zero)
                        qrows, srows = [], []
                        for j in range(fd.ops.shape[1]):
                            si_ = jnp.asarray(fd.ops[:, j])[idx]
                            qr = lax.dynamic_index_in_dim(
                                stage_q, jnp.maximum(si_, 0), 0,
                                keepdims=False)
                            if scaled:
                                sr = lax.dynamic_index_in_dim(
                                    stage_s, jnp.maximum(si_, 0), 0,
                                    keepdims=False)
                                # masked operand → zero scale → decodes 0
                                srows.append(jnp.where(
                                    si_ >= 0, sr,
                                    jnp.zeros((nt,), jnp.float32)))
                                qrows.append(qr)
                            else:
                                qrows.append(jnp.where(
                                    si_ >= 0, qr.astype(jnp.float32),
                                    zero.astype(jnp.float32)))
                        if scaled:
                            folded = kops.quant_reduce(
                                jnp.stack(qrows), jnp.stack(srows),
                                own_in.astype(jnp.float32), tile, chunk)
                        else:
                            folded = jnp.stack(
                                qrows
                                + [own_in.astype(jnp.float32)]).sum(axis=0)
                        folded = folded.astype(buf.dtype)
                        buf = lax.dynamic_update_index_in_dim(
                            buf, jnp.where(blk >= 0, folded, own),
                            safeb, 0)
        return buf

    def _check_axis(self, axis_name: str) -> None:
        from jax import lax
        n = lax.psum(1, axis_name)      # static under shard_map
        if int(n) != self.n:
            raise LoweringError(
                f"schedule {self.plan_name!r} compiled for {self.n} "
                f"devices; mesh axis {axis_name!r} has {int(n)}")

    def allreduce(self, x, axis_name: str, *,
                  fused_reduce: Callable | None = None):
        """Full AllReduce of a per-device array; same shape out."""
        import jax.numpy as jnp
        self._check_family("allreduce", ("allreduce",))
        self._check_axis(axis_name)
        shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.size) % self.num_blocks
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        buf = flat.reshape(self.num_blocks, -1)
        with default_tracer().span("exec/allreduce", plan=self.plan_name,
                                   n=self.n, blocks=self.num_blocks):
            buf = self._run_steps(self.rs, buf, axis_name, fused_reduce,
                                  phase="rs")
            buf = self._run_steps(self.ag, buf, axis_name, fused_reduce,
                                  phase="ag")
        full = buf.reshape(-1)
        if pad:
            full = full[:-pad]
        return full.reshape(shape)

    def reduce_scatter(self, x, axis_name: str, *,
                       fused_reduce: Callable | None = None):
        """RS half: flat per-device x → canonical shard i on device i."""
        import jax.numpy as jnp
        from jax import lax
        self._check_family("reduce_scatter", ("allreduce", "reduce_scatter"))
        if self.blocks_per_shard is None:
            raise LoweringError(
                f"plan {self.plan_name!r} shards {self.num_blocks} blocks "
                f"over {self.n} devices — no canonical per-device shard; "
                "use allreduce()")
        self._check_axis(axis_name)
        flat = x.reshape(-1)
        pad = (-flat.size) % self.num_blocks
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        buf = flat.reshape(self.num_blocks, -1)
        with default_tracer().span("exec/reduce_scatter",
                                   plan=self.plan_name, n=self.n):
            buf = self._run_steps(self.rs, buf, axis_name, fused_reduce,
                                  phase="rs")
            if self.reorder is not None:
                buf = self._run_steps([self.reorder], buf, axis_name,
                                      None, phase="reorder")
        k = self.blocks_per_shard
        idx = lax.axis_index(axis_name)
        return lax.dynamic_slice_in_dim(buf, idx * k, k, axis=0).reshape(-1)

    def all_gather(self, shard, axis_name: str):
        """AG half: canonical shard i on device i → full flat vector."""
        import jax.numpy as jnp
        from jax import lax
        self._check_family("all_gather", ("allreduce", "allgather"))
        if self.blocks_per_shard is None:
            raise LoweringError(
                f"plan {self.plan_name!r} has no canonical shard layout; "
                "use allreduce()")
        self._check_axis(axis_name)
        k = self.blocks_per_shard
        flat = shard.reshape(-1)
        buf = jnp.zeros((self.num_blocks, flat.size // k), flat.dtype)
        idx = lax.axis_index(axis_name)
        buf = lax.dynamic_update_slice_in_dim(
            buf, flat.reshape(k, -1), idx * k, axis=0)
        with default_tracer().span("exec/all_gather",
                                   plan=self.plan_name, n=self.n):
            if self.unorder is not None:
                buf = self._run_steps([self.unorder], buf, axis_name,
                                      None, phase="unorder")
            buf = self._run_steps(self.ag, buf, axis_name, None,
                                  phase="ag")
        return buf.reshape(-1)

    def all_to_all(self, x, axis_name: str):
        """AllToAll of a per-device operand; same shape out. Semantics
        ≡ `lax.all_to_all(x.reshape(num_blocks, -1), axis, 0, 0)` reshaped
        back: with k = num_blocks / n, device d's output rows
        [s·k, (s+1)·k) are device s's input rows [d·k, (d+1)·k) — the
        standard split-axis-0/concat-axis-0 exchange. Diagonal chunks
        never hit the wire (the lowered plan only ships off-diagonal
        blocks; untouched rows keep the operand value, which IS the
        diagonal). x.size must split into num_blocks equal chunks."""
        if x.size % self.num_blocks:
            raise LoweringError(
                f"all_to_all operand of {x.size} elements does not split "
                f"into {self.num_blocks} equal chunks")
        self._check_family("all_to_all", ("all_to_all",))
        self._check_axis(axis_name)
        shape = x.shape
        buf = x.reshape(self.num_blocks, -1)
        with default_tracer().span("exec/all_to_all", plan=self.plan_name,
                                   n=self.n, blocks=self.num_blocks):
            buf = self._run_steps(self.ag, buf, axis_name, None,
                                  phase="a2a")
        return buf.reshape(shape)

    def p2p(self, x, axis_name: str):
        """Point-to-point exchange: each compiled (src, dst) edge replaces
        dst's buffer with src's payload; devices with no incoming edge
        keep x. Same shape out."""
        self._check_family("p2p", ("p2p",))
        self._check_axis(axis_name)
        shape = x.shape
        buf = x.reshape(1, -1)
        with default_tracer().span("exec/p2p", plan=self.plan_name,
                                   n=self.n):
            buf = self._run_steps(self.ag, buf, axis_name, None,
                                  phase="p2p")
        return buf.reshape(shape)

    # ---- numpy execution (reference; tests) --------------------------------
    def _run_steps_numpy(self, steps: Sequence[ExecStep],
                         buf: np.ndarray,
                         phase: str = "steps") -> np.ndarray:
        # Same span names as the jax path, but here durations are real —
        # this is the interpreter the equivalence suite runs.
        n = self.n
        tracer = default_tracer()
        for si, st in enumerate(steps):
            with tracer.span(f"exec/{phase}/step", step=si,
                             rounds=len(st.rounds), folds=len(st.folds),
                             plan=self.plan_name):
                stage = np.zeros((n, max(st.n_slots, 1), buf.shape[2]),
                                 buf.dtype)
                for ri, rd in enumerate(st.rounds):
                    with tracer.span("exec/round", round=ri,
                                     width=int(rd.send_blks.shape[1]),
                                     pairs=len(rd.perm)):
                        w = rd.send_blks.shape[1]
                        payload = {}
                        for s, _ in rd.perm:
                            rows = np.zeros((w, buf.shape[2]), buf.dtype)
                            for j, b in enumerate(rd.send_blks[s]):
                                if b >= 0:
                                    rows[j] = buf[s, b]
                            payload[s] = rows
                        for s, d in rd.perm:
                            off = rd.recv_off[d]
                            stage[d, off:off + w] = payload[s]
                for fi, fd in enumerate(st.folds):
                    with tracer.span("exec/fold", fold=fi,
                                     fan=int(fd.ops.shape[1])):
                        new = {}
                        for m in range(n):
                            if fd.blk[m] < 0:
                                continue
                            acc = np.zeros(buf.shape[2], np.float64)
                            for s in fd.ops[m]:
                                if s >= 0:
                                    acc = acc + stage[m, s]
                            if fd.include_self[m]:
                                acc = acc + buf[m, fd.blk[m]]
                            new[m] = acc.astype(buf.dtype)
                        for m, v in new.items():
                            buf[m, fd.blk[m]] = v
        return buf

    def run_numpy(self, X: np.ndarray) -> np.ndarray:
        """Execute on a (n, size) matrix of per-device contributions;
        returns the (n, size) per-device results (all rows == column sums
        for a valid plan). Pure numpy mirror of the jax path."""
        self._check_family("run_numpy", ("allreduce",))
        X = np.asarray(X)
        if X.shape[0] != self.n:
            raise LoweringError(f"expected {self.n} device rows")
        size = X.shape[1]
        pad = (-size) % self.num_blocks
        if pad:
            X = np.concatenate(
                [X, np.zeros((self.n, pad), X.dtype)], axis=1)
        buf = X.reshape(self.n, self.num_blocks, -1).copy()
        with default_tracer().span("exec/run_numpy", plan=self.plan_name,
                                   n=self.n, blocks=self.num_blocks):
            buf = self._run_steps_numpy(self.rs, buf, phase="rs")
            buf = self._run_steps_numpy(self.ag, buf, phase="ag")
        out = buf.reshape(self.n, -1)
        return out[:, :size] if pad else out


# ---------------------------------------------------------------------------
# Compilation helpers
# ---------------------------------------------------------------------------
def _color_rounds(moves: list[tuple[int, int, int]], n: int
                  ) -> tuple[list[PermRound], int, dict[int, int]]:
    """Coalesce the step's moves per (src, dst) pair into one payload
    each, then greedily edge-color the payloads into partial permutations
    (≤1 send and ≤1 receive per device per round). Returns rounds, the
    staging depth, and each move's staging slot keyed by position in
    `moves`. A receiving device reserves the full round width W of
    staging rows (payloads narrower than W pad with zero rows that no
    fold references)."""
    edges: dict[tuple[int, int], list[tuple[int, int]]] = {}
    for mi, (s, d, b) in enumerate(moves):
        edges.setdefault((s, d), []).append((mi, b))
    rounds: list[dict] = []
    for (s, d), items in edges.items():
        for r in rounds:
            if s not in r["senders"] and d not in r["receivers"]:
                break
        else:
            r = {"senders": set(), "receivers": set(), "edges": []}
            rounds.append(r)
        r["senders"].add(s)
        r["receivers"].add(d)
        r["edges"].append((s, d, items))

    slot_of: dict[int, int] = {}
    next_slot = [0] * n
    out = []
    max_w = 0
    for r in rounds:
        w = max(len(items) for _, _, items in r["edges"])
        max_w = max(max_w, w)
        send_blks = np.full((n, w), -1, dtype=np.int64)
        recv_off = np.full(n, -1, dtype=np.int64)
        perm = []
        for s, d, items in sorted(r["edges"]):
            perm.append((s, d))
            for j, (_mi, b) in enumerate(items):
                send_blks[s, j] = b
            recv_off[d] = next_slot[d]
            for j, (mi, _b) in enumerate(items):
                slot_of[mi] = next_slot[d] + j
            next_slot[d] += w
        out.append(PermRound(perm=tuple(perm), send_blks=send_blks,
                             recv_off=recv_off))
    # stage depth must also cover the widest round for devices that
    # receive nothing (their masked dynamic_slice still reads W rows)
    return out, max(max(next_slot, default=0), max_w), slot_of


def _build_folds(groups: dict[tuple[int, int], list[int]],
                 include_self: dict[tuple[int, int], bool],
                 n: int) -> list[FoldPhase]:
    """groups: (dst, blk) → staging slots. Packs each device's fold groups
    into uniform per-device fold phases."""
    per_dev: dict[int, list[tuple[int, list[int], bool]]] = {}
    for (d, b), slots in groups.items():
        per_dev.setdefault(d, []).append((b, slots, include_self[(d, b)]))
    depth = max((len(v) for v in per_dev.values()), default=0)
    width = max((len(slots) for _, slots, _ in
                 (g for v in per_dev.values() for g in v)), default=0)
    folds = []
    for f in range(depth):
        blk = np.full(n, -1, dtype=np.int64)
        ops = np.full((n, max(width, 1)), -1, dtype=np.int64)
        self_mask = np.zeros(n, dtype=bool)
        any_active = False
        for d, gl in per_dev.items():
            if f >= len(gl):
                continue
            b, slots, inc = gl[f]
            blk[d] = b
            ops[d, :len(slots)] = slots
            self_mask[d] = inc
            any_active = True
        if any_active:
            folds.append(FoldPhase(blk=blk, ops=ops,
                                   include_self=self_mask))
    return folds


def _movement_step(moves: list[tuple[int, int, int]], n: int) -> ExecStep:
    """Pure data-movement step (reorder rounds): every receive is a plain
    write of the received block."""
    rounds, n_slots, slot_of = _color_rounds(moves, n)
    groups: dict[tuple[int, int], list[int]] = {}
    inc: dict[tuple[int, int], bool] = {}
    for mi, (s, d, b) in enumerate(moves):
        groups[(d, b)] = [slot_of[mi]]
        inc[(d, b)] = False
    return ExecStep(rounds=rounds, n_slots=n_slots,
                    folds=_build_folds(groups, inc, n))


def _movement_step_remap(moves: list[tuple[int, int, int, int]],
                         n: int) -> ExecStep:
    """Movement step whose writes land at a DIFFERENT block row than the
    one sent: `moves` carries (src, dst, src_block, dst_block). The
    AllToAll lowering uses this — src ships its operand chunk for dst
    (blocks in dst's range), and the copy lands in dst's buffer at src's
    row (the split/concat transpose)."""
    rounds, n_slots, slot_of = _color_rounds(
        [(s, d, sb) for s, d, sb, _ in moves], n)
    groups: dict[tuple[int, int], list[int]] = {}
    inc: dict[tuple[int, int], bool] = {}
    for mi, (s, d, _sb, db) in enumerate(moves):
        groups[(d, db)] = [slot_of[mi]]
        inc[(d, db)] = False
    return ExecStep(rounds=rounds, n_slots=n_slots,
                    folds=_build_folds(groups, inc, n))


def _srv_names(mask: int, inv: Mapping[int, int]) -> list[int]:
    return [inv[m] for m in range(mask.bit_length()) if mask >> m & 1]


def _op_blocks(op, si: int, what: str, nb: int,
               unit: float) -> tuple[int, ...]:
    if op.blocks is None:
        raise LoweringError(
            f"step {si}: {what} {op} is not block-annotated")
    want = len(op.blocks) * unit
    if abs(op.size - want) > 1e-6 * max(1.0, abs(want)):
        raise LoweringError(
            f"step {si}: {what} size {op.size} inconsistent with "
            f"{len(op.blocks)} block(s) of {unit} units")
    for b in op.blocks:
        if not 0 <= b < nb:
            raise LoweringError(
                f"step {si}: {what} names block {b} outside "
                f"0..{nb - 1}")
    return op.blocks


# ---------------------------------------------------------------------------
# lower_plan
# ---------------------------------------------------------------------------
def lower_plan(plan: Plan,
               placement: Sequence[int] | Mapping[int, int] | None = None
               ) -> CompiledSchedule:
    """Compile a block-annotated Plan into an executable CompiledSchedule.

    placement maps server id → mesh index; default: the i-th id of
    sorted(plan.ids()) sits at mesh index i. Raises LoweringError on
    unannotated IR, on structural defects (a server contribution folded
    twice, a fan_in that disagrees with the incoming copies, a block never
    fully reduced, an incomplete final gather) and on placement mismatch.
    """
    if plan.num_blocks is None:
        raise LoweringError(
            f"plan {plan.name!r} carries no block annotations "
            "(Plan.num_blocks is None) — rebuild it with a block-aware "
            "builder before lowering")
    with default_tracer().span("lower/lower_plan", plan=plan.name,
                               n=plan.n, blocks=plan.num_blocks):
        return _lower_plan_inner(plan, placement)


def _lower_plan_inner(plan: Plan,
                      placement: Sequence[int] | Mapping[int, int] | None
                      ) -> CompiledSchedule:
    n = plan.n
    ids = plan.ids()
    if placement is None:
        mesh_of = {sid: i for i, sid in enumerate(sorted(ids))}
    elif isinstance(placement, Mapping):
        mesh_of = {int(k): int(v) for k, v in placement.items()}
    else:
        mesh_of = {int(sid): i for i, sid in enumerate(placement)}
    if sorted(mesh_of.get(sid, -1) for sid in ids) != list(range(n)):
        raise LoweringError(
            f"placement must biject the {n} server ids {sorted(ids)} onto "
            f"mesh indices 0..{n - 1}; got {mesh_of}")
    inv = {m: sid for sid, m in mesh_of.items()}

    if plan.family in ("allgather", "all_to_all", "p2p"):
        return _lower_movement_family(plan, mesh_of, inv)
    if plan.family not in ("allreduce", "reduce_scatter"):
        raise LoweringError(f"unknown plan family {plan.family!r}")

    nb = plan.num_blocks
    unit = plan.size / nb
    full = (1 << n) - 1
    # contrib[mesh][block] = bitmask (over mesh indices) of the server
    # contributions currently summed into that device's copy
    contrib = [[1 << m for _ in range(nb)] for m in range(n)]

    def _blocks_of(op, si: int, what: str) -> tuple[int, ...]:
        return _op_blocks(op, si, what, nb, unit)

    exec_steps: list[ExecStep] = []
    last_fold_step = -1
    for si, st in enumerate(plan.steps):
        moves: list[tuple[int, int, int]] = []
        for t in st.transfers:
            if t.src not in mesh_of or t.dst not in mesh_of:
                raise LoweringError(
                    f"step {si}: transfer {t.src}->{t.dst} uses a server "
                    "id missing from the placement map")
            for b in _blocks_of(t, si, "transfer"):
                moves.append((mesh_of[t.src], mesh_of[t.dst], b))
        fans: dict[tuple[int, int], int] = {}
        for r in st.reduces:
            for b in _blocks_of(r, si, "reduce"):
                key = (mesh_of[r.server], b)
                if key in fans:
                    raise LoweringError(
                        f"step {si}: duplicate reduce of block {b} at "
                        f"server {r.server} — a block may fold at most "
                        "once per server per step")
                fans[key] = r.fan_in

        rounds, n_slots, slot_of = _color_rounds(moves, n)
        groups: dict[tuple[int, int], list[int]] = {}
        opmasks: dict[tuple[int, int], list[int]] = {}
        for mi, (s, d, b) in enumerate(moves):
            groups.setdefault((d, b), []).append(slot_of[mi])
            opmasks.setdefault((d, b), []).append(contrib[s][b])

        include_self: dict[tuple[int, int], bool] = {}
        updates: dict[tuple[int, int], int] = {}
        for key, slots in groups.items():
            d, b = key
            fan = fans.pop(key, None)
            got = len(slots)
            if fan is None:
                if got != 1:
                    raise LoweringError(
                        f"step {si}: server {inv[d]} receives {got} "
                        f"copies of block {b} with no reduce — ambiguous "
                        "write")
                include_self[key] = False
                updates[key] = opmasks[key][0]
                continue
            if fan == got:
                inc = False
            elif fan == got + 1:
                inc = True
            else:
                raise LoweringError(
                    f"step {si}: reduce of block {b} at server {inv[d]} "
                    f"declares fan_in={fan} but {got} copies arrive "
                    f"(expected fan_in of {got} or {got + 1})")
            include_self[key] = inc
            acc = contrib[d][b] if inc else 0
            for om, s_slot in zip(opmasks[key], slots):
                if acc & om:
                    dup = _srv_names(acc & om, inv)
                    raise LoweringError(
                        f"step {si}: duplicate block reduce — "
                        f"contribution(s) of server(s) {dup} to block {b} "
                        f"fold twice at server {inv[d]}")
                acc |= om
            updates[key] = acc
        if fans:
            (d, b), fan = next(iter(fans.items()))
            raise LoweringError(
                f"step {si}: reduce of block {b} at server {inv[d]} "
                f"(fan_in={fan}) has no incoming copies")
        for (d, b), mask in updates.items():
            contrib[d][b] = mask
        if st.reduces:
            last_fold_step = si
        exec_steps.append(ExecStep(
            rounds=rounds, n_slots=n_slots,
            folds=_build_folds(groups, include_self, n)))

        if si == last_fold_step:
            rs_contrib = [row[:] for row in contrib]

    # ---- completeness ------------------------------------------------------
    if last_fold_step < 0:
        raise LoweringError(
            f"plan {plan.name!r} contains no reduces — not "
            f"{'an AllReduce' if plan.family == 'allreduce' else 'a ReduceScatter'}")
    if plan.family == "allreduce":
        for m in range(n):
            for b in range(nb):
                if contrib[m][b] != full:
                    missing = _srv_names(full & ~contrib[m][b], inv)
                    raise LoweringError(
                        f"incomplete gather: server {inv[m]} ends without "
                        f"the contribution(s) of server(s) {missing} for "
                        f"block {b}")
    else:
        # reduce_scatter family: the ownership layout is the END state —
        # trailing movement steps (a builder's own reorder) count.
        rs_contrib = [row[:] for row in contrib]

    # ---- ReduceScatter boundary + canonical shard layout -------------------
    owner = np.full(nb, -1, dtype=np.int64)
    for b in range(nb):
        holders = [m for m in range(n) if rs_contrib[m][b] == full]
        if not holders:
            parts = {m: _srv_names(rs_contrib[m][b], inv)
                     for m in range(n) if rs_contrib[m][b]}
            raise LoweringError(
                f"block {b} is never fully reduced by the end of the "
                f"ReduceScatter phase (step {last_fold_step}); partial "
                f"holders: {parts}")
        owner[b] = holders[0]

    blocks_per_shard = nb // n if nb % n == 0 else None
    reorder = unorder = None
    if blocks_per_shard:
        k = blocks_per_shard
        fwd = [(int(owner[b]), b // k, b) for b in range(nb)
               if int(owner[b]) != b // k]
        if fwd:
            reorder = _movement_step(fwd, n)
            unorder = _movement_step([(d, s, b) for s, d, b in fwd], n)

    if plan.family == "reduce_scatter":
        # every step belongs to the RS half; nothing gathers afterwards
        rs_steps, ag_steps = exec_steps, []
    else:
        rs_steps = exec_steps[:last_fold_step + 1]
        ag_steps = exec_steps[last_fold_step + 1:]
    return CompiledSchedule(
        plan_name=plan.name, n=n, num_blocks=nb,
        rs=rs_steps, ag=ag_steps,
        owner_of_block=owner, blocks_per_shard=blocks_per_shard,
        reorder=reorder, unorder=unorder,
        placement=tuple(inv[m] for m in range(n)),
        family=plan.family)


def _lower_movement_family(plan: Plan, mesh_of: Mapping[int, int],
                           inv: Mapping[int, int]) -> CompiledSchedule:
    """Lower a fold-free family (allgather / all_to_all / p2p).

    allgather: each block's initial holder is INFERRED from the steps — a
    server that sends a block before ever receiving it must have started
    with it. Exactly one initial holder per block is required (the
    `all_gather()` entry seeds the canonical shard and `unorder` ships
    each block to that holder, so a second presumed holder would forward
    garbage), and every server must end holding every block.

    all_to_all: every transfer must ship blocks from the sender's operand
    chunk for the destination (block b of src→dst needs dst·k ≤ b <
    (dst+1)·k, k = num_blocks/n); the copy lands at dst row
    src·k + (b − dst·k) — the split-0/concat-0 transpose. Completeness:
    every off-diagonal row received exactly once. Only direct (single-hop)
    plans lower; a hierarchical AllToAll prices fine but fails the chunk
    check here by construction.

    p2p: arbitrary edges, full buffer each; at most one incoming edge per
    receiver per step. The edge list is kept on the schedule
    (`perm_pairs`) for the guard's flat rung."""
    n, nb, family = plan.n, plan.num_blocks, plan.family
    unit = plan.size / nb
    exec_steps: list[ExecStep] = []

    def _expand(st, si):
        moves: list[tuple[int, int, int]] = []
        if st.reduces:
            raise LoweringError(
                f"step {si}: a {family!r}-family plan cannot fold "
                f"(found {len(st.reduces)} reduce op(s))")
        for t in st.transfers:
            if t.src not in mesh_of or t.dst not in mesh_of:
                raise LoweringError(
                    f"step {si}: transfer {t.src}->{t.dst} uses a server "
                    "id missing from the placement map")
            for b in _op_blocks(t, si, "transfer", nb, unit):
                moves.append((mesh_of[t.src], mesh_of[t.dst], b))
        return moves

    if family == "allgather":
        holds = [[False] * nb for _ in range(n)]
        initial = [[False] * nb for _ in range(n)]
        for si, st in enumerate(plan.steps):
            moves = _expand(st, si)
            seen_writes: set[tuple[int, int]] = set()
            for s, d, b in moves:
                if not holds[s][b]:
                    for m in range(n):
                        if initial[m][b]:
                            raise LoweringError(
                                f"step {si}: block {b} would need to start "
                                f"at both server {inv[m]} and server "
                                f"{inv[s]} — ambiguous initial holder")
                    holds[s][b] = True
                    initial[s][b] = True
                if (d, b) in seen_writes:
                    raise LoweringError(
                        f"step {si}: server {inv[d]} receives block {b} "
                        "twice — ambiguous write")
                seen_writes.add((d, b))
            exec_steps.append(_movement_step(moves, n))
            for _s, d, b in moves:
                holds[d][b] = True
        owner = np.full(nb, -1, dtype=np.int64)
        for b in range(nb):
            src = [m for m in range(n) if initial[m][b]]
            if not src:
                if n == 1:
                    owner[b] = 0
                    continue
                raise LoweringError(
                    f"block {b} is never transferred — no initial holder "
                    "to gather it from")
            owner[b] = src[0]
            for m in range(n):
                if not holds[m][b]:
                    raise LoweringError(
                        f"incomplete gather: server {inv[m]} ends without "
                        f"block {b}")
        blocks_per_shard = nb // n if nb % n == 0 else None
        reorder = unorder = None
        if blocks_per_shard:
            k = blocks_per_shard
            fwd = [(int(owner[b]), b // k, b) for b in range(nb)
                   if int(owner[b]) != b // k]
            if fwd:
                reorder = _movement_step(fwd, n)
                unorder = _movement_step([(d, s, b) for s, d, b in fwd], n)
        return CompiledSchedule(
            plan_name=plan.name, n=n, num_blocks=nb, rs=[], ag=exec_steps,
            owner_of_block=owner, blocks_per_shard=blocks_per_shard,
            reorder=reorder, unorder=unorder,
            placement=tuple(inv[m] for m in range(n)), family=family)

    if family == "all_to_all":
        if nb % n:
            raise LoweringError(
                f"all_to_all plan {plan.name!r} needs num_blocks ({nb}) "
                f"divisible by n ({n})")
        k = nb // n
        received: set[tuple[int, int]] = set()
        for si, st in enumerate(plan.steps):
            moves4: list[tuple[int, int, int, int]] = []
            for s, d, b in _expand(st, si):
                if not d * k <= b < (d + 1) * k:
                    raise LoweringError(
                        f"step {si}: transfer {inv[s]}->{inv[d]} ships "
                        f"block {b} outside the destination chunk "
                        f"[{d * k}, {(d + 1) * k}) — only direct "
                        "(single-hop) all_to_all plans lower")
                row = s * k + (b - d * k)
                if (d, row) in received:
                    raise LoweringError(
                        f"step {si}: server {inv[d]} receives output row "
                        f"{row} twice — ambiguous write")
                received.add((d, row))
                moves4.append((s, d, b, row))
            exec_steps.append(_movement_step_remap(moves4, n))
        for d in range(n):
            for s in range(n):
                if s == d:
                    continue    # diagonal chunk never hits the wire
                for j in range(k):
                    if (d, s * k + j) not in received:
                        raise LoweringError(
                            f"incomplete all_to_all: server {inv[d]} never "
                            f"receives row {s * k + j} (chunk of server "
                            f"{inv[s]})")
        return CompiledSchedule(
            plan_name=plan.name, n=n, num_blocks=nb, rs=[], ag=exec_steps,
            owner_of_block=np.arange(nb, dtype=np.int64) // k,
            blocks_per_shard=None, reorder=None, unorder=None,
            placement=tuple(inv[m] for m in range(n)), family=family)

    # p2p
    pairs: list[tuple[int, int]] = []
    for si, st in enumerate(plan.steps):
        moves = _expand(st, si)
        dsts: set[int] = set()
        for s, d, _b in moves:
            if d in dsts:
                raise LoweringError(
                    f"step {si}: server {inv[d]} receives two p2p "
                    "payloads — ambiguous write")
            dsts.add(d)
            pairs.append((s, d))
        exec_steps.append(_movement_step(moves, n))
    return CompiledSchedule(
        plan_name=plan.name, n=n, num_blocks=nb, rs=[], ag=exec_steps,
        owner_of_block=np.zeros(nb, dtype=np.int64),
        blocks_per_shard=None, reorder=None, unorder=None,
        placement=tuple(inv[m] for m in range(n)), family=family,
        perm_pairs=tuple(pairs))


# ---------------------------------------------------------------------------
# Guarded execution (DESIGN.md §12)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GuardPolicy:
    """Launch guard knobs for GuardedSchedule.

    `timeout` is a *post-hoc* budget: schedule launches happen at trace
    time inside shard_map, so an in-flight dispatch cannot be aborted —
    a launch that overruns the budget still returns its (valid) result,
    but the guard counts the timeout and demotes subsequent launches to
    the flat fallback rung. `max_retries` bounds re-attempts of the
    planned rung with exponential `backoff` (seconds, doubling, capped
    at 2 s). `fallback=False` turns the ladder's flat rung off: the last
    error is raised instead."""
    timeout: float | None = None
    max_retries: int = 1
    backoff: float = 0.05
    fallback: bool = True


class GuardedSchedule:
    """Fallback-laddered wrapper around a CompiledSchedule.

    Ladder per launch: planned schedule (with bounded retry) → flat jax
    collective (`lax.psum` / psum+slice / `lax.all_gather`) → raise.
    Every rung transition is counted in the metrics registry
    (`guarded_*_total`) and opens a telemetry re-measure window
    (`Telemetry.remeasure`) — a fallback means measurements of the
    planned schedule stopped describing what actually ran. After a
    fallback or timeout the guard *demotes*: subsequent launches take
    the flat rung directly (sticky, cleared by `reset_guard`), so a
    persistently failing schedule costs one failed attempt, not one per
    step. An armed `runtime.faults` injector is consulted before each
    planned-rung attempt (`check_launch`), which is how chaos tests
    exercise the ladder deterministically.

    Everything not guarded (describe, blocks_per_shard, run_numpy-less
    attrs, …) delegates to the wrapped schedule, so the wrapper is a
    drop-in anywhere a CompiledSchedule flows (core.bucketing probes
    `blocks_per_shard` via getattr; collectives compare by identity).
    """

    def __init__(self, schedule, *, policy: GuardPolicy | None = None,
                 telemetry=None):
        self.inner = schedule
        self.policy = policy or GuardPolicy()
        self.telemetry = telemetry
        self._demoted = False
        self._wire_demoted = False
        self._full = None               # lazy full-precision rung
        self.stats = {"launches": 0, "retries": 0, "fallbacks": 0,
                      "timeouts": 0, "demoted_launches": 0,
                      "wire_fallbacks": 0, "wire_demoted_launches": 0,
                      "reprobes": 0}
        _GUARD_REGISTRY.add(self)

    def __getattr__(self, name):
        inner = self.__dict__.get("inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)

    @property
    def demoted(self) -> bool:
        return self._demoted

    @property
    def wire_demoted(self) -> bool:
        return self._wire_demoted

    def reset_guard(self) -> None:
        self._demoted = False
        self._wire_demoted = False

    # -- internals ----------------------------------------------------------
    def _metrics(self):
        from repro.runtime.metrics import default_metrics
        return default_metrics()

    def _remeasure(self, reason: str, info: dict) -> None:
        tele = self.telemetry
        if tele is None:
            from repro.runtime.telemetry import peek_default_telemetry
            tele = peek_default_telemetry()
        if tele is not None:
            tele.remeasure(reason, info)

    def _note_fallback(self, what: str, err) -> None:
        self.stats["fallbacks"] += 1
        self._demoted = True
        self._metrics().counter(
            "guarded_fallbacks_total",
            "guarded launches demoted to the flat collective rung").inc()
        default_tracer().instant("guard/fallback", plan=self.inner.plan_name,
                                 what=what, error=repr(err))
        self._remeasure("guard_fallback",
                        {"plan": self.inner.plan_name, "what": what,
                         "error": repr(err)})

    def _full_rung(self):
        """The full-precision planned rung of a compressed schedule: the
        same CompiledSchedule with the wire stripped (lazy, cached)."""
        if self._full is None:
            self._full = self.inner.with_wire(None)
        return self._full

    def _note_wire_fallback(self, what: str, err) -> None:
        self.stats["wire_fallbacks"] += 1
        self._wire_demoted = True
        self._metrics().counter(
            "guarded_wire_fallbacks_total",
            "compressed launches demoted to the full-precision rung").inc()
        default_tracer().instant("guard/wire_fallback",
                                 plan=self.inner.plan_name, what=what,
                                 wire=self.inner.wire.name, error=repr(err))
        self._remeasure("guard_wire_fallback",
                        {"plan": self.inner.plan_name, "what": what,
                         "wire": self.inner.wire.name, "error": repr(err)})

    def _guarded_wire(self, what: str, attempt, mid, fallback):
        """Top rung of the compressed ladder (DESIGN.md §13): one attempt
        at the wire schedule — a failure demotes (sticky) to the full-
        precision planned rung, which keeps `_guarded`'s own retry/flat
        ladder below it. compressed → full-precision → flat psum."""
        m = self._metrics()
        if not self._wire_demoted:
            self.stats["launches"] += 1
            m.counter("guarded_launches_total",
                      "collective launches through the schedule guard"
                      ).inc()
            try:
                from repro.runtime.faults import active_injector
                inj = active_injector()
                if inj is not None:
                    inj.check_launch(f"{self.inner.plan_name}/{what}")
                return attempt()
            except Exception as e:        # noqa: BLE001 — ladder rung
                self._note_wire_fallback(what, e)
                return self._guarded(what, mid, fallback)
        self.stats["wire_demoted_launches"] += 1
        m.counter("guarded_wire_demoted_launches_total",
                  "launches served at full precision after wire demotion"
                  ).inc()
        return self._guarded(what, mid, fallback)

    def _guarded(self, what: str, attempt, fallback):
        import time as _time
        m = self._metrics()
        self.stats["launches"] += 1
        m.counter("guarded_launches_total",
                  "collective launches through the schedule guard").inc()
        pol = self.policy
        if self._demoted and fallback is not None and pol.fallback:
            self.stats["demoted_launches"] += 1
            m.counter("guarded_demoted_launches_total",
                      "launches served by the flat rung after demotion"
                      ).inc()
            return fallback()
        err = None
        for attempt_i in range(pol.max_retries + 1):
            if attempt_i:
                self.stats["retries"] += 1
                m.counter("guarded_retries_total",
                          "planned-rung retry attempts").inc()
                if pol.backoff > 0:
                    _time.sleep(min(pol.backoff * (2 ** (attempt_i - 1)),
                                    2.0))
            try:
                from repro.runtime.faults import active_injector
                inj = active_injector()
                if inj is not None:
                    inj.check_launch(f"{self.inner.plan_name}/{what}")
                t0 = _time.perf_counter()
                out = attempt()
                dt = _time.perf_counter() - t0
                if pol.timeout is not None and dt > pol.timeout:
                    # dispatch already completed — result is valid, but
                    # demote so the next launch takes the flat rung
                    self.stats["timeouts"] += 1
                    self._demoted = True
                    m.counter("guarded_timeouts_total",
                              "launches exceeding the per-launch budget"
                              ).inc()
                    self._remeasure("guard_timeout",
                                    {"plan": self.inner.plan_name,
                                     "what": what, "dt": dt,
                                     "budget": pol.timeout})
                return out
            except Exception as e:            # noqa: BLE001 — ladder rung
                err = e
        if fallback is not None and pol.fallback:
            self._note_fallback(what, err)
            return fallback()
        raise err

    # -- guarded collective surface -----------------------------------------
    def allreduce(self, x, axis_name: str, *,
                  fused_reduce: Callable | None = None):
        from jax import lax
        attempt = lambda: self.inner.allreduce(  # noqa: E731
            x, axis_name, fused_reduce=fused_reduce)
        flat = lambda: lax.psum(x, axis_name)    # noqa: E731
        if getattr(self.inner, "wire", None) is not None:
            return self._guarded_wire(
                "allreduce", attempt,
                lambda: self._full_rung().allreduce(
                    x, axis_name, fused_reduce=fused_reduce),
                flat)
        return self._guarded("allreduce", attempt, flat)

    def reduce_scatter(self, x, axis_name: str, *,
                       fused_reduce: Callable | None = None):
        def flat_rs():
            # mirror the inner contract: pad to the block multiple, full
            # psum, take this device's canonical shard
            import jax.numpy as jnp
            from jax import lax
            flat = x.reshape(-1)
            pad = (-flat.size) % self.inner.num_blocks
            if pad:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((pad,), flat.dtype)])
            full = lax.psum(flat, axis_name)
            k = full.size // self.inner.n
            idx = lax.axis_index(axis_name)
            return lax.dynamic_slice_in_dim(full, idx * k, k)

        attempt = lambda: self.inner.reduce_scatter(  # noqa: E731
            x, axis_name, fused_reduce=fused_reduce)
        if getattr(self.inner, "wire", None) is not None:
            return self._guarded_wire(
                "reduce_scatter", attempt,
                lambda: self._full_rung().reduce_scatter(
                    x, axis_name, fused_reduce=fused_reduce),
                flat_rs)
        return self._guarded("reduce_scatter", attempt, flat_rs)

    def all_gather(self, shard, axis_name: str):
        def flat_ag():
            from jax import lax
            return lax.all_gather(shard.reshape(-1), axis_name, axis=0,
                                  tiled=True)

        attempt = lambda: self.inner.all_gather(  # noqa: E731
            shard, axis_name)
        if getattr(self.inner, "wire", None) is not None:
            return self._guarded_wire(
                "all_gather", attempt,
                lambda: self._full_rung().all_gather(shard, axis_name),
                flat_ag)
        return self._guarded("all_gather", attempt, flat_ag)

    def all_to_all(self, x, axis_name: str):
        def flat_a2a():
            from jax import lax
            nb = self.inner.num_blocks
            return lax.all_to_all(x.reshape(nb, -1), axis_name, 0,
                                  0).reshape(x.shape)

        attempt = lambda: self.inner.all_to_all(x, axis_name)  # noqa: E731
        if getattr(self.inner, "wire", None) is not None:
            return self._guarded_wire(
                "all_to_all", attempt,
                lambda: self._full_rung().all_to_all(x, axis_name),
                flat_a2a)
        return self._guarded("all_to_all", attempt, flat_a2a)

    def p2p(self, x, axis_name: str):
        def flat_p2p():
            import jax.numpy as jnp
            from jax import lax
            pairs = list(self.inner.perm_pairs or ())
            if not pairs:
                return x
            recv = lax.ppermute(x, axis_name, pairs)
            has_in = np.zeros(self.inner.n, dtype=bool)
            for _s, d in pairs:
                has_in[d] = True
            idx = lax.axis_index(axis_name)
            return jnp.where(jnp.asarray(has_in)[idx], recv, x)

        attempt = lambda: self.inner.p2p(x, axis_name)  # noqa: E731
        if getattr(self.inner, "wire", None) is not None:
            return self._guarded_wire(
                "p2p", attempt,
                lambda: self._full_rung().p2p(x, axis_name),
                flat_p2p)
        return self._guarded("p2p", attempt, flat_p2p)

    def run_numpy(self, X: np.ndarray) -> np.ndarray:
        # reference path: guard machinery applies (bench measures its
        # overhead here) but there is no flat numpy rung — errors raise
        return self._guarded("run_numpy",
                             lambda: self.inner.run_numpy(X), None)


# Every live guard, for health-restoration re-probes. Guards stay alive
# exactly as long as their schedule (guard_schedule memoizes the wrapper
# on the schedule object), so a WeakSet tracks precisely the schedules
# still cached somewhere.
_GUARD_REGISTRY: "weakref.WeakSet[GuardedSchedule]" = weakref.WeakSet()


def reprobe_guards(reason: str = "health_restore") -> int:
    """Re-arm every live demoted guard (DESIGN.md §12): sticky demotion
    exists so a *persistently* failing schedule costs one failed attempt
    instead of one per step — but after a `link_restore` / remesh the
    fault that caused the demotion is gone, and staying pinned to the
    flat rung forever forfeits the planned schedule's speedup.
    `PlannerService.mark_degraded(level, factor >= 1)` (the restore path
    `runtime.ft` drives on link_restore events) and `clear_degraded` call
    this, so the next launch re-probes the planned (and compressed) rung.
    Returns the number of guards re-armed."""
    cleared = 0
    for g in list(_GUARD_REGISTRY):
        if g._demoted or g._wire_demoted:
            g.reset_guard()
            g.stats["reprobes"] += 1
            cleared += 1
    if cleared:
        from repro.runtime.metrics import default_metrics
        default_metrics().counter(
            "guarded_reprobes_total",
            "demoted guards re-armed by health-restoration events"
        ).inc(cleared)
        default_tracer().instant("guard/reprobe", reason=reason,
                                 cleared=cleared)
    return cleared


def guard_schedule(schedule, *, telemetry=None, policy=None):
    """Memoized GuardedSchedule for `schedule`: repeated calls (one per
    train step on the bucketed path) return the SAME wrapper, so sticky
    demotion and guard stats survive across launches instead of being
    reset by every re-wrap. Idempotent on an already-guarded schedule."""
    if schedule is None or isinstance(schedule, GuardedSchedule):
        return schedule
    g = getattr(schedule, "_guard_wrapper", None)
    if g is None:
        g = GuardedSchedule(schedule, telemetry=telemetry, policy=policy)
        try:
            schedule._guard_wrapper = g
        except (AttributeError, TypeError):
            pass                      # unwritable object: unmemoized wrap
    return g
