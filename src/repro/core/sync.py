"""Gradient synchronization strategies — where GenTree meets the trainer.

A SyncConfig selects how DP gradients are reduced across the mesh's
data-parallel axes. `strategy="gentree"` builds the TPU-pod tree topology,
prices every plan type per level with GenModel (TPU_V5E parameters), and
picks the winner — typically hierarchical CPS with fan-ins capped by the
per-level incast threshold w_t, exactly the paper's δ/ε trade-off.

Used inside shard_map train steps (manual engine) and by the launcher to
pick mesh-axis factorizations for the pjit (auto) engine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from . import collectives
from .cost_model import GenModelParams, TPU_V5E, best_flat_plan


@dataclasses.dataclass(frozen=True)
class AxisPlan:
    axis: str
    strategy: str                   # psum | ring | rhd | cps | hcps | plan
    factors: tuple[int, ...] | None = None
    # strategy == "plan": the lowered GenTree schedule to execute
    # (core.lower.CompiledSchedule; compared/hashed by identity)
    schedule: object | None = None
    # modeled cost of this axis's plan at the priced size (seconds) —
    # what the runtime pairs with measured timings when it feeds the
    # online loop (PlannerService.observe, DESIGN.md §10)
    predicted: float | None = None


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """strategy: auto|psum|ring|rhd|cps|hcps|gentree|plan per DP axis.
    "gentree" picks a flat plan-type label per axis; "plan" lowers the
    GenTree Plan IR itself and executes its compiled schedule — bucketed
    and pipelined by default (core.bucketing, DESIGN.md §9):
    bucket_bytes=None lets GenModel pick the bucket size (the sweep
    argmin), an explicit value pins it, and 0 disables bucketing
    (legacy per-leaf execution). pipeline=False runs buckets
    back-to-back instead of overlapping AG(k) with RS(k+1)."""
    strategy: str = "auto"
    factors: tuple[int, ...] | None = None   # for explicit hcps
    compress: str | None = None              # None | "int8"
    params: dict[str, GenModelParams] | None = None
    bucket_bytes: int | None = None          # None=auto | 0=off | fixed
    pipeline: bool = True                    # double-buffer RS/AG halves
    # Backward-overlapped issuance (DESIGN.md §15): issue buckets in
    # reverse-layer readiness order (backward produces last-layer grads
    # first) and fuse RS(k)/AG(k−1) into one merged launch when the
    # planner's contended argmin picked "merged". False restores
    # forward-order sequential issuance.
    backward_overlap: bool = True
    # Wrap executed schedules in core.lower.GuardedSchedule (retry +
    # flat-psum fallback ladder, DESIGN.md §12). Off ⇒ raw schedules.
    guard: bool = True
    # Wire precision for the planned path (DESIGN.md §13). `precision`
    # pins a PRECISIONS name ("f32"|"bf16"|"fp8"|"int8"); None lets the
    # bucket-plan sweep argmin over precisions allowed by `tolerance`
    # (max relative gradient error the caller accepts). tolerance=None
    # means no lossy consent: the sweep stays lossless and a pinned
    # lossy precision whose budget exceeds a float tolerance clamps to
    # f32 (cost_model.resolve_precision).
    precision: str | None = None
    tolerance: float | None = None


# Table-5 class per mesh-axis position: the leaf axis rides the pod fabric
# (ICI → "root_sw" pricing), every outer axis the cross-pod DCI.
AXIS_LEVELS = ("root_sw",) + ("cross_dc",) * 8


def axis_level(i: int) -> str:
    return AXIS_LEVELS[min(i, len(AXIS_LEVELS) - 1)]


def level_switch_topo(n: int, params: dict[str, GenModelParams],
                      level: str):
    """Single-switch stand-in for a mesh axis at a Table-5 level class:
    one switch, n servers whose uplink bandwidth realizes the level's β
    (seconds per 4-byte unit → bytes/s), pricing α/γ/δ/ε/w_t coming from
    the params table. The ONE synthesis shared by axis pricing
    (`plan_axes_gentree`) and axis execution
    (`PlannerService.get_axis_executable`) — the executed plan must be
    the plan the model priced."""
    from .topology import single_switch
    p = params.get(level, params["server"])
    bw = 4.0 / p.beta if p.beta > 0 else 1e18
    return single_switch(int(n), bw=bw, lat=0.0, level=level)


def plan_axes_gentree(axes: Sequence[tuple[str, int]], size_floats: float,
                      params: dict[str, GenModelParams] | None = None, *,
                      engine: str | None = None,
                      gentree_kwargs: dict | None = None) -> list[AxisPlan]:
    """Per-level plan selection for a hierarchical mesh.

    axes: [(axis_name, size), ...] ordered leaf-level first (e.g.
    [("data", 16), ("pod", 2)]). Level 0 prices with pod-internal (ICI)
    parameters, outer levels with the cross-pod (DCI) parameters — the
    TPU analogue of the paper's Table-5 level classes.

    With default `engine`/`gentree_kwargs` each axis is priced by the
    GenModel closed forms (`best_flat_plan`). When either is configured
    (a PlannerService built with engine="reference"/"fast" or custom
    gentree_kwargs), the axis is priced by running GenTree itself on the
    equivalent single-switch topology — one switch, n servers whose link
    bandwidth realizes the level's β — with exactly that engine and those
    kwargs, so service configuration reaches cold axis pricing instead of
    being silently dropped.
    """
    params = params or TPU_V5E
    gkw = dict(gentree_kwargs or {})
    use_gentree = engine is not None or bool(gkw)
    out: list[AxisPlan] = []
    for i, (name, n) in enumerate(axes):
        lvl = axis_level(i)
        p = params[lvl]
        # the γ/δ terms always price at the chip ("server") level
        srv = params["server"]
        p = dataclasses.replace(p, gamma=srv.gamma, delta=srv.delta)
        if n == 1:
            continue
        if use_gentree:
            from .gentree import gentree as run_gentree
            topo = level_switch_topo(n, {lvl: p, "server": srv}, lvl)
            res = run_gentree(topo, size_floats,
                              params={lvl: p, "server": srv},
                              engine=engine, **gkw)
            dec = res.decisions[topo.name]
            kind = "cps" if dec.algo == "acps" else dec.algo
            fac = dec.factors
            cost = dec.cost
        else:
            kind, fac, cost = best_flat_plan(n, size_floats, p)
        out.append(AxisPlan(name, kind, tuple(fac) if fac else None,
                            predicted=float(cost)))
    return out


def resolve_axis_plans(axes: Sequence[tuple[str, int]], cfg: "SyncConfig",
                       size_floats: float) -> list[AxisPlan]:
    """Per-axis plan resolution shared by the gradient-sync and ZeRO-3
    engines. hcps factors are validated per axis (explicit factors only
    apply where they multiply to the axis size; otherwise the first valid
    factorization, degrading to cps on prime axes)."""
    import math as _math
    from .plans import factorizations

    if cfg.strategy == "gentree":
        # Route through the planner service: lookups are fingerprinted,
        # size-bucketed and LRU-cached (repro.planner, DESIGN.md §5), so
        # repeated train steps don't re-price the mesh. Lazy import —
        # planner depends on this module.
        from repro.planner.service import default_service
        return default_service().get_axis_plans(axes, size_floats,
                                                params=cfg.params)
    if cfg.strategy == "plan":
        # Execute the GenTree Plan IR itself: per axis, the service
        # generates (or cache-hits) the plan AND its lowered schedule
        # (DESIGN.md §8); the returned AxisPlan carries the compiled
        # schedule for collectives.allreduce/reduce_scatter to run.
        # Pricing matches plan_axes_gentree: leaf axis at "root_sw",
        # outer axes at "cross_dc", cfg.params honoured.
        from repro.planner.service import default_service
        svc = default_service()
        wire = None
        pname = getattr(cfg, "precision", None)
        if pname is not None:
            from .cost_model import resolve_precision
            prec = resolve_precision(pname, getattr(cfg, "tolerance", None))
            wire = prec if prec.name != "f32" else None
        out = []
        # level index counts the ORIGINAL axis position (n==1 axes are
        # skipped but still occupy their mesh level), exactly as
        # plan_axes_gentree enumerates — same axis, same Table-5 class.
        for i, (a, n) in enumerate(axes):
            if n <= 1:
                continue
            resp = svc.get_axis_executable(a, n, size_floats,
                                           level=axis_level(i),
                                           params=cfg.params)
            sched = resp.schedule
            if wire is not None:
                # wire-bound copy (fresh object): its guard wrapper
                # memoizes separately from the full-precision users of
                # the same cached schedule (DESIGN.md §13)
                sched = sched.with_wire(wire)
            if getattr(cfg, "guard", True):
                from .lower import guard_schedule
                sched = guard_schedule(
                    sched, telemetry=getattr(svc, "telemetry", None))
            out.append(AxisPlan(a, "plan", schedule=sched,
                                predicted=resp.predicted_time))
        return out

    def axis_plan(a: str, n: int) -> AxisPlan:
        if cfg.strategy != "hcps":
            return AxisPlan(a, cfg.strategy, cfg.factors)
        if cfg.factors and _math.prod(cfg.factors) == n:
            return AxisPlan(a, "hcps", tuple(cfg.factors))
        facs = factorizations(n)
        if facs:
            return AxisPlan(a, "hcps", tuple(facs[0]))
        return AxisPlan(a, "cps", None)

    return [axis_plan(a, n) for a, n in axes if n > 1]


# ---------------------------------------------------------------------------
# Expert-parallel AllToAll context (ISSUE 9 tentpole): the trainer opens
# `expert_parallel(...)` around loss tracing so the MoE layer's dispatch/
# combine exchanges run over the right mesh axis — and, under
# strategy="plan", from the lowered all_to_all plan instead of
# lax.all_to_all. Trace-time state, like the plan lookups themselves.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EPContext:
    axis: str                       # mesh axis the experts shard over
    size: int                       # axis size (number of expert groups)
    # lowered family="all_to_all" CompiledSchedule (possibly guarded);
    # None ⇒ lax.all_to_all
    schedule: object | None = None


_EP_CONTEXT: list = [None]


def ep_context() -> EPContext | None:
    """The active expert-parallel context, if any (trace-time)."""
    return _EP_CONTEXT[0]


class expert_parallel:
    """Context manager installing an EPContext for the enclosed trace."""

    def __init__(self, axis: str, size: int, schedule=None):
        self._ctx = EPContext(axis, int(size), schedule)
        self._prev = None

    def __enter__(self) -> EPContext:
        self._prev = _EP_CONTEXT[0]
        _EP_CONTEXT[0] = self._ctx
        return self._ctx

    def __exit__(self, *exc):
        _EP_CONTEXT[0] = self._prev
        return False


def ep_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """AllToAll for the MoE dispatch/combine: the active EPContext's
    planned schedule when it matches `axis_name`, lax otherwise."""
    ctx = _EP_CONTEXT[0]
    sched = ctx.schedule if ctx is not None and ctx.axis == axis_name \
        else None
    return collectives.all_to_all(x, axis_name, schedule=sched)


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def allreduce_int8_cps(x: jax.Array, axis_name: str) -> jax.Array:
    """CPS AllReduce with int8 wire format (gradient compression): 4× less
    β/ε cost per the paper's model, at one extra γ/δ quantize pass."""
    n = lax.psum(1, axis_name)
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    q, scale = _quantize_int8(flat)
    parts = lax.all_to_all(q.reshape(n, -1), axis_name,
                           split_axis=0, concat_axis=0)
    scales = lax.all_gather(scale, axis_name)           # (n,)
    shard = (parts.astype(jnp.float32) * scales[:, None]).sum(0)
    qs, sc = _quantize_int8(shard)
    full_q = lax.all_gather(qs, axis_name, axis=0, tiled=True)
    full_s = lax.all_gather(sc, axis_name)
    chunk = qs.shape[0]
    full = full_q.astype(jnp.float32) * jnp.repeat(full_s, chunk)
    if pad:
        full = full[:-pad]
    return full.reshape(shape).astype(x.dtype)


def allreduce_topk(x: jax.Array, axis_name: str, k_frac: float = 0.01
                   ) -> jax.Array:
    """Top-k sparsified AllReduce for the low-bandwidth (DCI) hop: keep
    the k·|g| largest-magnitude entries per device, exchange (values,
    indices) — wire bytes ≈ 2k vs the dense gradient. Error feedback is
    the caller's concern (runtime keeps the residual); GenModel prices the
    trade: β/ε shrink by ~1/(2·k_frac) at one extra γ/δ pass for the
    top-k selection."""
    n = lax.psum(1, axis_name)
    shape = x.shape
    flat = x.reshape(-1)
    k = max(1, int(flat.size * k_frac))
    vals, idx = lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    # dense scatter of every device's sparse contribution: gather the
    # (vals, idx) pairs and accumulate locally — the wire cost is the
    # gathered sparse pairs, not the dense tensor.
    all_vals = lax.all_gather(vals, axis_name)      # (n, k)
    all_idx = lax.all_gather(idx, axis_name)        # (n, k)
    out = jnp.zeros_like(flat).at[all_idx.reshape(-1)].add(
        all_vals.reshape(-1))
    return out.reshape(shape)


def sync_gradients(grads, axes: Sequence[tuple[str, int]], cfg: SyncConfig,
                   fused_reduce: Callable | None = None,
                   stats: dict | None = None):
    """AllReduce every gradient leaf across the DP axes per the config.

    Must be called inside shard_map with all `axes` present. Hierarchical:
    leaf-level axis first, then outer axes — the multi-pod pattern
    (intra-pod reduce, inter-pod exchange) falls out naturally.

    `stats`, when given, is filled at trace time with the resolved
    plans' identity and modeled costs (bucketed path: the bucket plan's
    fingerprint and pipelined prediction; per-leaf path: the per-axis
    predictions) so the caller can pair them with measured timings for
    the online loop.
    """
    if cfg.strategy == "auto":
        names = tuple(a for a, n in axes if n > 1)
        return jax.tree.map(lambda g: lax.psum(g, names), grads)

    if cfg.strategy == "plan" and cfg.bucket_bytes != 0:
        # Bucketed, double-buffered execution (DESIGN.md §9): the whole
        # pytree partitions into GenModel-sized buckets and bucket k's
        # AllGather half overlaps bucket k+1's ReduceScatter half,
        # instead of one schedule launch per leaf. bucket_bytes=0 opts
        # back into the per-leaf path below.
        from .bucketing import sync_bucketed
        return sync_bucketed(grads, axes, cfg, fused_reduce=fused_reduce,
                             stats=stats)

    plans = resolve_axis_plans(axes, cfg, size_floats=float(
        sum(x.size for x in jax.tree.leaves(grads))))
    if stats is not None:
        stats.update({
            "axis_plans": [(p.axis, p.strategy, p.predicted)
                           for p in plans],
            "predicted_total": (sum(p.predicted for p in plans)
                                if all(p.predicted is not None
                                       for p in plans) and plans
                                else None),
        })

    def leaf(g):
        for pl in plans:
            if cfg.compress == "int8" and pl.strategy in ("cps", "hcps"):
                g = allreduce_int8_cps(g, pl.axis)
            else:
                g = collectives.allreduce(g, pl.axis, pl.strategy,
                                          factors=pl.factors,
                                          fused_reduce=fused_reduce,
                                          schedule=pl.schedule)
        return g

    from repro.runtime.trace import default_tracer
    with default_tracer().span("sync/gradients", strategy=cfg.strategy,
                               axes=len(plans)):
        return jax.tree.map(leaf, grads)
