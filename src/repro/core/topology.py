"""Tree-like physical topologies (paper §4.2, Figure 6/11) + TPU pod trees.

A topology is a rooted tree. Leaves are servers (compute endpoints holding
data); internal nodes are switches. Every non-root node has an uplink to its
parent with a bandwidth (bytes/s) and a latency contribution. GenModel
parameters (alpha/beta/gamma/delta/epsilon/w_t) attach per *level class*
(paper Table 5: Cross-DC / Root-SW / Middle-SW / Server).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np


class RoutingIndex:
    """Dense array view of a finalized topology (built by `finalize()`).

    Assigns every node a DFS index and every *directed* uplink a dense link
    id (`2*node` = 'up' through node's uplink, `2*node+1` = 'down'), and
    tabulates each server's ancestor chain so the links on any src→dst path
    become pure array lookups: a level-`l` ancestor of `src` lies strictly
    below the LCA — and hence its uplink is on the path — exactly when it
    differs from `dst`'s level-`l` ancestor. `core.simfast` vectorizes the
    whole per-step routing of a Plan over these tables; `path_links()`
    remains the reference implementation (property-tested against this).
    """

    def __init__(self, root: "TopoNode"):
        self.root = root
        self.nodes: list[TopoNode] = list(root.iter_nodes())
        idx = {id(n): i for i, n in enumerate(self.nodes)}
        self.n_nodes = len(self.nodes)
        self.n_links = 2 * self.n_nodes
        servers = root.servers()
        self.n_servers = len(servers)
        # Server arrays are indexed by _sid. For a tree finalized at this
        # root, sids are contiguous 0..n-1; for a subtree of an enclosing
        # finalized tree they are a sparse subset of the global ids, so
        # size by the largest sid instead of the count.
        self.sids = tuple(s._sid for s in servers)   # staleness check key
        self.sid_cap = max(self.sids, default=-1) + 1

        # Per-node (and so per-link-pair) physical attributes. A link's
        # GenModel level class is its *parent switch*'s level (the fabric
        # the uplink plugs into), matching the reference simulator.
        self.link_bw = np.zeros(self.n_nodes)
        self.link_latency = np.zeros(self.n_nodes)
        levels: list[str] = []
        level_idx: dict[str, int] = {}
        self.link_level = np.zeros(self.n_nodes, dtype=np.int64)
        depth_of: dict[int, int] = {id(root): 0}
        for i, n in enumerate(self.nodes):
            self.link_bw[i] = n.uplink_bw
            self.link_latency[i] = n.uplink_latency
            lvl = n.parent.level if n.parent is not None else n.level
            if lvl not in level_idx:
                level_idx[lvl] = len(levels)
                levels.append(lvl)
            self.link_level[i] = level_idx[lvl]
            if n is not root:
                depth_of[id(n)] = depth_of[id(n.parent)] + 1
        self.levels = levels                    # level-class names, indexed
        self.level_idx = level_idx

        # Per-server tables (indexed by _sid).
        self.max_depth = max((depth_of[id(s)] for s in servers), default=0)
        self.srv_node = np.zeros(self.sid_cap, dtype=np.int64)
        self.srv_bw = np.zeros(self.sid_cap)
        self.srv_level = np.zeros(self.sid_cap, dtype=np.int64)
        # anc[s, l] = node index of server s's ancestor at tree depth l
        # (root = depth 0, the server itself at its own depth); -1 pads
        # levels below the server in ragged-depth trees.
        self.anc = np.full((self.sid_cap, self.max_depth + 1), -1,
                           dtype=np.int64)
        for s in servers:
            sid = s._sid
            self.srv_node[sid] = idx[id(s)]
            self.srv_bw[sid] = s.uplink_bw
            plvl = s.parent.level if s.parent is not None else "root_sw"
            if plvl not in level_idx:
                level_idx[plvl] = len(levels)
                levels.append(plvl)
            self.srv_level[sid] = level_idx[plvl]
            chain = []
            n = s
            while True:     # climb to this index's root, never above it
                chain.append(idx[id(n)])
                if n is root:
                    break
                n = n.parent
            for l, node_i in enumerate(reversed(chain)):
                self.anc[sid, l] = node_i

    def path_link_ids(self, src_sid: int, dst_sid: int) -> list[int]:
        """Dense link ids on the src→dst path (src-side 'up' links first,
        then dst-side 'down' links root-to-leaf). Mirrors `path_links`."""
        out_up, out_down = [], []
        for l in range(1, self.max_depth + 1):
            a, b = self.anc[src_sid, l], self.anc[dst_sid, l]
            if a == b:
                continue
            if a != -1:
                out_up.append(2 * int(a))
            if b != -1:
                out_down.append(2 * int(b) + 1)
        return out_up[::-1] + out_down


@dataclass
class TopoNode:
    name: str
    children: list["TopoNode"] = field(default_factory=list)
    # Uplink to parent (irrelevant for root).
    uplink_bw: float = 0.0          # bytes / s
    uplink_latency: float = 0.0     # s
    level: str = "server"           # "server" | "middle_sw" | "root_sw" | "cross_dc"
    parent: "TopoNode | None" = None
    # Health state (DESIGN.md §12): "ok" | "degraded" | "dead". A degraded
    # link keeps serving at reduced uplink_bw (nominal_bw preserves the
    # healthy value for restore); a dead node is excluded by prune_dead().
    health: str = "ok"
    nominal_bw: float | None = None
    _sid: int = -1                  # server id (leaves only, assigned by finalize)
    _routing: "RoutingIndex | None" = field(default=None, repr=False,
                                            compare=False)

    # ---- structure helpers -------------------------------------------------
    @property
    def is_server(self) -> bool:
        return not self.children

    def servers(self) -> list["TopoNode"]:
        if self.is_server:
            return [self]
        out: list[TopoNode] = []
        for c in self.children:
            out.extend(c.servers())
        return out

    def num_servers(self) -> int:
        return len(self.servers())

    def switches(self) -> list["TopoNode"]:
        """All internal nodes, bottom-up (children before parents)."""
        if self.is_server:
            return []
        out: list[TopoNode] = []
        for c in self.children:
            out.extend(c.switches())
        out.append(self)
        return out

    def iter_nodes(self):
        yield self
        for c in self.children:
            yield from c.iter_nodes()

    def finalize(self) -> "TopoNode":
        """Assign parent pointers, contiguous server ids (DFS order) and
        build the dense RoutingIndex. Idempotent; call again after
        structural edits to refresh the index."""
        sid = itertools.count()

        def walk(node: TopoNode, parent: TopoNode | None):
            node.parent = parent
            if node.is_server:
                node._sid = next(sid)
            for c in node.children:
                walk(c, node)

        walk(self, None)
        self._routing = RoutingIndex(self)
        return self

    def routing(self) -> "RoutingIndex":
        """The dense routing index (building it on demand if needed).

        For a node that is itself the finalized root, this returns the
        index built by `finalize()`. For a *subtree* of an enclosing
        finalized tree (valid server ids already assigned) it builds a
        local index without re-finalizing — re-finalizing would sever the
        subtree's parent pointer and renumber the enclosing tree's ids.
        A cached index is discarded when the server ids it was built
        against no longer match (e.g. the enclosing tree was edited and
        re-finalized, renumbering sids DFS-wide).
        """
        sids = tuple(s._sid for s in self.servers())
        if (self._routing is None or self._routing.root is not self
                or self._routing.sids != sids):
            if -1 in sids or len(set(sids)) != len(sids):
                self.finalize()          # never finalized: safe to assign
            else:
                self._routing = RoutingIndex(self)
        return self._routing

    def server_ids(self) -> list[int]:
        return [s._sid for s in self.servers()]

    # ---- health (DESIGN.md §12) --------------------------------------------
    def _invalidate_routing(self) -> None:
        """Drop cached routing indices that bake in this node's uplink.
        The uplink appears only in indices rooted at this node or at an
        ancestor, so climbing to the root suffices; descendant subtree
        indices never route over it."""
        n = self
        while n is not None:
            n._routing = None
            n = n.parent

    def mark_degraded(self, factor: float) -> "TopoNode":
        """Degrade this node's uplink to `factor` × its nominal bandwidth
        (0 < factor ≤ 1). The changed uplink_bw flows into the planner
        fingerprint (`topo_canonical` hashes it), so any PlannerService
        keyed on this topology reprices from a cold cache entry — no
        schedule priced for the healthy link survives."""
        factor = float(factor)
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"degrade factor must be in (0, 1]: {factor}")
        if self.nominal_bw is None:
            self.nominal_bw = self.uplink_bw
        self.uplink_bw = self.nominal_bw * factor
        self.health = "ok" if factor == 1.0 else "degraded"
        self._invalidate_routing()
        return self

    def mark_dead(self) -> "TopoNode":
        """Mark this node (and implicitly its subtree) failed. Dead nodes
        still fingerprint distinctly (health is hashed) and are removed
        from planning topologies via `prune_dead()`."""
        self.health = "dead"
        self._invalidate_routing()
        return self

    def restore_health(self) -> "TopoNode":
        if self.nominal_bw is not None:
            self.uplink_bw = self.nominal_bw
        self.health = "ok"
        self._invalidate_routing()
        return self

    def has_dead(self) -> bool:
        return any(n.health == "dead" for n in self.iter_nodes())

    def prune_dead(self) -> "TopoNode":
        """A finalized deep copy of this tree without dead subtrees (a
        switch whose children all died is itself removed). Raises
        ValueError when nothing survives — the caller has no topology
        left to plan over."""
        def copy(node: "TopoNode") -> "TopoNode | None":
            if node.health == "dead":
                return None
            kids = [k for k in (copy(c) for c in node.children)
                    if k is not None]
            if node.children and not kids:
                return None
            out = TopoNode(name=node.name, children=kids,
                           uplink_bw=node.uplink_bw,
                           uplink_latency=node.uplink_latency,
                           level=node.level, health=node.health,
                           nominal_bw=node.nominal_bw)
            return out

        root = copy(self)
        if root is None or not root.servers():
            raise ValueError("prune_dead: no live servers remain")
        return root.finalize()

    # ---- routing -----------------------------------------------------------
    def path_links(self, src: "TopoNode", dst: "TopoNode") -> list["TopoNode"]:
        """Links (represented by their child endpoint node) on src→dst path.

        Full-duplex links: the 'up' direction of node X's uplink and the
        'down' direction are distinct capacities; we return (node, dir) pairs.
        """
        a_path = []
        n = src
        while n is not None:
            a_path.append(n)
            n = n.parent
        anc = set(id(x) for x in a_path)
        down = []
        n = dst
        while id(n) not in anc:
            down.append(n)
            n = n.parent
        lca = n
        up = []
        for x in a_path:
            if x is lca:
                break
            up.append(x)
        # 'up' direction uses src-side uplinks; 'down' uses dst-side uplinks.
        return [(x, "up") for x in up] + [(x, "down") for x in reversed(down)]


def _server(name: str, bw: float, lat: float) -> TopoNode:
    return TopoNode(name=name, uplink_bw=bw, uplink_latency=lat, level="server")


# ---------------------------------------------------------------------------
# Builders (paper Figure 11 instances + TPU pods)
# ---------------------------------------------------------------------------
GBPS = 1e9 / 8.0  # 1 Gbps in bytes/s


def single_switch(n: int, *, bw: float = 10 * GBPS, lat: float = 5e-6,
                  name: str = "root", level: str = "middle_sw") -> TopoNode:
    """In-rack cluster: n servers on one switch. The paper's testbed switch
    is a 10 Gbps ToR — parameter class 'middle_sw' in Table 5."""
    root = TopoNode(name=name, level=level)
    root.children = [_server(f"s{i}", bw, lat) for i in range(n)]
    return root.finalize()


def symmetric_tree(n_middle: int, servers_per_middle: int, *,
                   server_bw: float = 10 * GBPS,
                   uplink_bw: float = 100 * GBPS,
                   lat: float = 5e-6) -> TopoNode:
    root = TopoNode(name="root", level="root_sw")
    for m in range(n_middle):
        sw = TopoNode(name=f"msw{m}", uplink_bw=uplink_bw, uplink_latency=lat,
                      level="middle_sw")
        sw.children = [_server(f"s{m}_{i}", server_bw, lat)
                       for i in range(servers_per_middle)]
        root.children.append(sw)
    return root.finalize()


def asymmetric_tree(n_middle: int = 16, big: int = 32, small: int = 16, *,
                    server_bw: float = 10 * GBPS,
                    uplink_bw: float = 100 * GBPS,
                    lat: float = 5e-6) -> TopoNode:
    root = TopoNode(name="root", level="root_sw")
    for m in range(n_middle):
        k = big if m < n_middle // 2 else small
        sw = TopoNode(name=f"msw{m}", uplink_bw=uplink_bw, uplink_latency=lat,
                      level="middle_sw")
        sw.children = [_server(f"s{m}_{i}", server_bw, lat) for i in range(k)]
        root.children.append(sw)
    return root.finalize()


def cross_dc(*, dc0_middle: int = 8, dc0_servers: int = 32,
             dc1_middle: int = 8, dc1_servers: int = 16,
             server_bw: float = 10 * GBPS, uplink_bw: float = 100 * GBPS,
             wan_bw: float = 10 * GBPS, wan_lat: float = 30e-3,
             lat: float = 5e-6) -> TopoNode:
    """Two DCs joined by a WAN link. Modelled as a virtual root whose two
    children are the DC root switches; the WAN link is dc1-root's uplink
    (dc0-root's uplink to the virtual root is considered infinite/local)."""
    top = TopoNode(name="wan_root", level="cross_dc")
    for d, (nm, ns, bw, lt) in enumerate(
            [(dc0_middle, dc0_servers, 1e18, 0.0),
             (dc1_middle, dc1_servers, wan_bw, wan_lat)]):
        dc = TopoNode(name=f"dc{d}", uplink_bw=bw, uplink_latency=lt,
                      level="root_sw")
        for m in range(nm):
            sw = TopoNode(name=f"dc{d}_msw{m}", uplink_bw=uplink_bw,
                          uplink_latency=lat, level="middle_sw")
            sw.children = [_server(f"dc{d}_s{m}_{i}", server_bw, lat)
                           for i in range(ns)]
            dc.children.append(sw)
        top.children.append(dc)
    return top.finalize()


def tpu_pod_tree(n_pods: int = 2, chips_per_pod: int = 256, *,
                 ici_bw: float = 50e9, dci_bw: float = 25e9,
                 ici_lat: float = 1e-6, dci_lat: float = 1e-5) -> TopoNode:
    """A multi-pod TPU deployment seen as a tree (DESIGN.md §3): root joins
    pods via DCI; each pod's chips hang off a virtual 'pod fabric' node whose
    internal bandwidth is the ICI bisection share per chip."""
    root = TopoNode(name="dci_root", level="cross_dc")
    for p in range(n_pods):
        pod = TopoNode(name=f"pod{p}", uplink_bw=dci_bw, uplink_latency=dci_lat,
                       level="root_sw")
        pod.children = [_server(f"chip{p}_{c}", ici_bw, ici_lat)
                        for c in range(chips_per_pod)]
        root.children.append(pod)
    return root.finalize()
