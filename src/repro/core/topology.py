"""Tree-like physical topologies (paper §4.2, Figure 6/11) + TPU pod trees.

A topology is a rooted tree. Leaves are servers (compute endpoints holding
data); internal nodes are switches. Every non-root node has an uplink to its
parent with a bandwidth (bytes/s) and a latency contribution. GenModel
parameters (alpha/beta/gamma/delta/epsilon/w_t) attach per *level class*
(paper Table 5: Cross-DC / Root-SW / Middle-SW / Server).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field


@dataclass
class TopoNode:
    name: str
    children: list["TopoNode"] = field(default_factory=list)
    # Uplink to parent (irrelevant for root).
    uplink_bw: float = 0.0          # bytes / s
    uplink_latency: float = 0.0     # s
    level: str = "server"           # "server" | "middle_sw" | "root_sw" | "cross_dc"
    parent: "TopoNode | None" = None
    _sid: int = -1                  # server id (leaves only, assigned by finalize)

    # ---- structure helpers -------------------------------------------------
    @property
    def is_server(self) -> bool:
        return not self.children

    def servers(self) -> list["TopoNode"]:
        if self.is_server:
            return [self]
        out: list[TopoNode] = []
        for c in self.children:
            out.extend(c.servers())
        return out

    def num_servers(self) -> int:
        return len(self.servers())

    def switches(self) -> list["TopoNode"]:
        """All internal nodes, bottom-up (children before parents)."""
        if self.is_server:
            return []
        out: list[TopoNode] = []
        for c in self.children:
            out.extend(c.switches())
        out.append(self)
        return out

    def iter_nodes(self):
        yield self
        for c in self.children:
            yield from c.iter_nodes()

    def finalize(self) -> "TopoNode":
        """Assign parent pointers and contiguous server ids (DFS order)."""
        sid = itertools.count()

        def walk(node: TopoNode, parent: TopoNode | None):
            node.parent = parent
            if node.is_server:
                node._sid = next(sid)
            for c in node.children:
                walk(c, node)

        walk(self, None)
        return self

    def server_ids(self) -> list[int]:
        return [s._sid for s in self.servers()]

    # ---- routing -----------------------------------------------------------
    def path_links(self, src: "TopoNode", dst: "TopoNode") -> list["TopoNode"]:
        """Links (represented by their child endpoint node) on src→dst path.

        Full-duplex links: the 'up' direction of node X's uplink and the
        'down' direction are distinct capacities; we return (node, dir) pairs.
        """
        a_path = []
        n = src
        while n is not None:
            a_path.append(n)
            n = n.parent
        anc = set(id(x) for x in a_path)
        down = []
        n = dst
        while id(n) not in anc:
            down.append(n)
            n = n.parent
        lca = n
        up = []
        for x in a_path:
            if x is lca:
                break
            up.append(x)
        # 'up' direction uses src-side uplinks; 'down' uses dst-side uplinks.
        return [(x, "up") for x in up] + [(x, "down") for x in reversed(down)]


def _server(name: str, bw: float, lat: float) -> TopoNode:
    return TopoNode(name=name, uplink_bw=bw, uplink_latency=lat, level="server")


# ---------------------------------------------------------------------------
# Builders (paper Figure 11 instances + TPU pods)
# ---------------------------------------------------------------------------
GBPS = 1e9 / 8.0  # 1 Gbps in bytes/s


def single_switch(n: int, *, bw: float = 10 * GBPS, lat: float = 5e-6,
                  name: str = "root", level: str = "middle_sw") -> TopoNode:
    """In-rack cluster: n servers on one switch. The paper's testbed switch
    is a 10 Gbps ToR — parameter class 'middle_sw' in Table 5."""
    root = TopoNode(name=name, level=level)
    root.children = [_server(f"s{i}", bw, lat) for i in range(n)]
    return root.finalize()


def symmetric_tree(n_middle: int, servers_per_middle: int, *,
                   server_bw: float = 10 * GBPS,
                   uplink_bw: float = 100 * GBPS,
                   lat: float = 5e-6) -> TopoNode:
    root = TopoNode(name="root", level="root_sw")
    for m in range(n_middle):
        sw = TopoNode(name=f"msw{m}", uplink_bw=uplink_bw, uplink_latency=lat,
                      level="middle_sw")
        sw.children = [_server(f"s{m}_{i}", server_bw, lat)
                       for i in range(servers_per_middle)]
        root.children.append(sw)
    return root.finalize()


def asymmetric_tree(n_middle: int = 16, big: int = 32, small: int = 16, *,
                    server_bw: float = 10 * GBPS,
                    uplink_bw: float = 100 * GBPS,
                    lat: float = 5e-6) -> TopoNode:
    root = TopoNode(name="root", level="root_sw")
    for m in range(n_middle):
        k = big if m < n_middle // 2 else small
        sw = TopoNode(name=f"msw{m}", uplink_bw=uplink_bw, uplink_latency=lat,
                      level="middle_sw")
        sw.children = [_server(f"s{m}_{i}", server_bw, lat) for i in range(k)]
        root.children.append(sw)
    return root.finalize()


def cross_dc(*, dc0_middle: int = 8, dc0_servers: int = 32,
             dc1_middle: int = 8, dc1_servers: int = 16,
             server_bw: float = 10 * GBPS, uplink_bw: float = 100 * GBPS,
             wan_bw: float = 10 * GBPS, wan_lat: float = 30e-3,
             lat: float = 5e-6) -> TopoNode:
    """Two DCs joined by a WAN link. Modelled as a virtual root whose two
    children are the DC root switches; the WAN link is dc1-root's uplink
    (dc0-root's uplink to the virtual root is considered infinite/local)."""
    top = TopoNode(name="wan_root", level="cross_dc")
    for d, (nm, ns, bw, lt) in enumerate(
            [(dc0_middle, dc0_servers, 1e18, 0.0),
             (dc1_middle, dc1_servers, wan_bw, wan_lat)]):
        dc = TopoNode(name=f"dc{d}", uplink_bw=bw, uplink_latency=lt,
                      level="root_sw")
        for m in range(nm):
            sw = TopoNode(name=f"dc{d}_msw{m}", uplink_bw=uplink_bw,
                          uplink_latency=lat, level="middle_sw")
            sw.children = [_server(f"dc{d}_s{m}_{i}", server_bw, lat)
                           for i in range(ns)]
            dc.children.append(sw)
        top.children.append(dc)
    return top.finalize()


def tpu_pod_tree(n_pods: int = 2, chips_per_pod: int = 256, *,
                 ici_bw: float = 50e9, dci_bw: float = 25e9,
                 ici_lat: float = 1e-6, dci_lat: float = 1e-5) -> TopoNode:
    """A multi-pod TPU deployment seen as a tree (DESIGN.md §3): root joins
    pods via DCI; each pod's chips hang off a virtual 'pod fabric' node whose
    internal bandwidth is the ICI bisection share per chip."""
    root = TopoNode(name="dci_root", level="cross_dc")
    for p in range(n_pods):
        pod = TopoNode(name=f"pod{p}", uplink_bw=dci_bw, uplink_latency=dci_lat,
                       level="root_sw")
        pod.children = [_server(f"chip{p}_{c}", ici_bw, ici_lat)
                        for c in range(chips_per_pod)]
        root.children.append(pod)
    return root.finalize()
