"""Compiled plan-evaluation engine (DESIGN.md §7).

The reference `core.simulator.Simulator` prices a Plan by walking
`topo.path_links()` per transfer in pure Python — correct, but the
dominant cost of cold GenTree generation at the Table-7 scales (hundreds
of candidate plans × thousands of transfers each). This module lowers a
`Plan` once into per-step numpy arrays and evaluates the full GenModel
step cost with vectorized reductions:

    t_step = α_eff + max_link(bytes/bw + incast) + max_server(compute)

Lowering uses the topology's `RoutingIndex` (built at `finalize()`): a
level-`l` ancestor of `src` lies strictly below the src↔dst LCA — and so
its uplink is on the path — exactly when it differs from `dst`'s level-`l`
ancestor, which turns per-transfer routing into `max_depth` vectorized
comparisons. Per-link byte totals and distinct-sender counts come from
`np.bincount` / `np.unique`; per-server reduce adds/mem_ops likewise.

The engine must agree with the reference simulator within 1e-9 on every
quantity (total, per_step, comm/compute/latency/incast_extra) — enforced
by `tests/test_simfast.py`. `Simulator.simulate` delegates here unless
constructed with `engine="reference"` (or `$REPRO_SIM_ENGINE=reference`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .cost_model import GenModelParams, PAPER_TABLE5
from .plans import Plan, Step
from .topology import TopoNode


@dataclass
class CompiledStep:
    """One Step lowered onto a RoutingIndex: everything the GenModel step
    cost needs, as dense arrays over touched links / servers only."""
    # links touched by at least one transfer (dense link ids, see
    # RoutingIndex: 2*node = up through node's uplink, 2*node+1 = down)
    lids: np.ndarray          # int64 (L,)
    lunits: np.ndarray        # float  (L,)  data units through the link
    lnsend: np.ndarray        # int64 (L,)  distinct senders on the link
    # receiving endpoints
    rdst: np.ndarray          # int64 (R,)  server ids with >=1 inbound flow
    runits: np.ndarray        # float  (R,)  units received
    rfan: np.ndarray          # int64 (R,)  distinct senders into the server
    # compute
    csrv: np.ndarray          # int64 (C,)  servers running reduces
    cadds: np.ndarray         # float  (C,)  γ-term ops
    cmem: np.ndarray          # float  (C,)  δ-term ops
    has_transfers: bool = False
    has_reduces: bool = False


@dataclass
class ParamTable:
    """GenModelParams spread onto the routing index's dense ids."""
    node_tpb: np.ndarray      # seconds per data unit through node's uplink
    node_lat: np.ndarray
    node_alpha: np.ndarray
    node_eps: np.ndarray
    node_wt: np.ndarray
    srv_tpb: np.ndarray       # per server-id NIC time per unit
    srv_eps: np.ndarray       # parent-level ε / w_t at the endpoint
    srv_wt: np.ndarray
    alpha_srv: float
    gamma: float
    delta: float


_EMPTY_I = np.empty(0, dtype=np.int64)
_EMPTY_F = np.empty(0)


class FastEngine:
    """Vectorized GenModel evaluator over a finalized topology."""

    def __init__(self, topo: TopoNode,
                 params: dict[str, GenModelParams] | None = None,
                 unit_bytes: int = 4, precision=None):
        self.topo = topo
        self.rx = topo.routing()
        self.params = params or PAPER_TABLE5
        self.unit = unit_bytes
        self.scale = unit_bytes / 4.0
        # Wire-format compression: transfers shrink by bytes_per_elem/4,
        # reduces pick up the quant/dequant memory passes (γ/δ). Applied at
        # compile_step so `compile_arrays` (the batched GenTree search path)
        # stays precision-agnostic. Same accounting as
        # `cost_model.evaluate_plan(precision=...)`.
        if precision is not None:
            from .cost_model import resolve_precision
            precision = resolve_precision(precision)
            if precision.name == "f32":
                precision = None
        self.precision = precision
        self.pt = self._build_param_table()

    def _p(self, level: str) -> GenModelParams:
        return self.params.get(level, self.params["server"])

    def _build_param_table(self) -> ParamTable:
        rx = self.rx
        lvl = [self._p(name) for name in rx.levels]
        lvl_alpha = np.array([p.alpha for p in lvl])
        lvl_eps = np.array([p.epsilon for p in lvl])
        lvl_wt = np.array([float(p.w_t) for p in lvl])
        bw = rx.link_bw
        # matches the reference: 0 time when bw == 0, else bytes/bw
        node_tpb = np.where(bw != 0.0,
                            self.unit / np.maximum(bw, 1e-30), 0.0)
        sbw = rx.srv_bw
        srv_tpb = np.where(sbw != 0.0,
                           self.unit / np.maximum(sbw, 1e-30), 0.0)
        psrv = self._p("server")
        return ParamTable(
            node_tpb=node_tpb, node_lat=rx.link_latency,
            node_alpha=lvl_alpha[rx.link_level],
            node_eps=lvl_eps[rx.link_level],
            node_wt=lvl_wt[rx.link_level],
            srv_tpb=srv_tpb,
            srv_eps=lvl_eps[rx.srv_level], srv_wt=lvl_wt[rx.srv_level],
            alpha_srv=psrv.alpha, gamma=psrv.gamma, delta=psrv.delta)

    # ---- lowering ----------------------------------------------------------
    def compile_arrays(self, src: np.ndarray, dst: np.ndarray,
                       size, red_srv: np.ndarray, red_adds,
                       red_mem) -> CompiledStep:
        """Lower a step already given as arrays (the batched GenTree search
        builds candidates in this form directly, no Transfer objects).
        `size` may be scalar (uniform transfers); red_adds/red_mem are the
        per-reduce γ/δ op counts, scalar or arrays aligned with red_srv."""
        rx = self.rx
        n_srv = rx.sid_cap    # server arrays are indexed by (sparse) _sid
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        size_arr = np.ascontiguousarray(
            np.broadcast_to(np.asarray(size, dtype=float), src.shape))

        has_t = src.size > 0
        if has_t:
            A = rx.anc[src]                     # (T, D+1)
            B = rx.anc[dst]
            lid_parts, tid_parts = [], []
            tindex = np.arange(src.size)
            for l in range(1, rx.max_depth + 1):
                a, b = A[:, l], B[:, l]
                neq = a != b
                mu = neq & (a != -1)
                md = neq & (b != -1)
                if mu.any():
                    lid_parts.append(2 * a[mu])
                    tid_parts.append(tindex[mu])
                if md.any():
                    lid_parts.append(2 * b[md] + 1)
                    tid_parts.append(tindex[md])
            if lid_parts:
                lid = np.concatenate(lid_parts)
                tid = np.concatenate(tid_parts)
            else:
                lid, tid = _EMPTY_I, _EMPTY_I
            nlinks = rx.n_links
            counts = np.bincount(lid, minlength=nlinks)
            units = np.bincount(lid, weights=size_arr[tid], minlength=nlinks)
            # distinct senders per link: unique (link, src) pairs
            ukey = np.unique(lid * n_srv + src[tid])
            nsend = np.bincount(ukey // n_srv, minlength=nlinks)
            lids = np.nonzero(counts)[0]
            lunits, lnsend = units[lids], nsend[lids]
            # endpoint aggregates
            rcount = np.bincount(dst, minlength=n_srv)
            rdst = np.nonzero(rcount)[0]
            runits = np.bincount(dst, weights=size_arr,
                                 minlength=n_srv)[rdst]
            pkey = np.unique(src * n_srv + dst)
            rfan = np.bincount(pkey % n_srv, minlength=n_srv)[rdst]
        else:
            lids, lunits, lnsend = _EMPTY_I, _EMPTY_F, _EMPTY_I
            rdst, runits, rfan = _EMPTY_I, _EMPTY_F, _EMPTY_I

        red_srv = np.asarray(red_srv, dtype=np.int64)
        has_r = red_srv.size > 0
        if has_r:
            adds = np.ascontiguousarray(np.broadcast_to(
                np.asarray(red_adds, dtype=float), red_srv.shape))
            mem = np.ascontiguousarray(np.broadcast_to(
                np.asarray(red_mem, dtype=float), red_srv.shape))
            ccount = np.bincount(red_srv, minlength=n_srv)
            csrv = np.nonzero(ccount)[0]
            cadds = np.bincount(red_srv, weights=adds, minlength=n_srv)[csrv]
            cmem = np.bincount(red_srv, weights=mem, minlength=n_srv)[csrv]
        else:
            csrv, cadds, cmem = _EMPTY_I, _EMPTY_F, _EMPTY_F

        return CompiledStep(lids=lids, lunits=lunits, lnsend=lnsend,
                            rdst=rdst, runits=runits, rfan=rfan,
                            csrv=csrv, cadds=cadds, cmem=cmem,
                            has_transfers=has_t, has_reduces=has_r)

    def compile_step(self, step: Step) -> CompiledStep:
        src = np.fromiter((t.src for t in step.transfers), dtype=np.int64,
                          count=len(step.transfers))
        dst = np.fromiter((t.dst for t in step.transfers), dtype=np.int64,
                          count=len(step.transfers))
        size = np.fromiter((t.size for t in step.transfers), dtype=float,
                           count=len(step.transfers))
        rsrv = np.fromiter((r.server for r in step.reduces), dtype=np.int64,
                           count=len(step.reduces))
        adds = np.fromiter((r.adds for r in step.reduces), dtype=float,
                           count=len(step.reduces))
        mem = np.fromiter((r.mem_ops for r in step.reduces), dtype=float,
                          count=len(step.reduces))
        p = self.precision
        if p is not None:
            size = size * p.comm_scale()
            rsize = np.fromiter((r.size for r in step.reduces), dtype=float,
                                count=len(step.reduces))
            adds = adds + p.extra_adds(rsize)
            mem = mem + p.extra_mem_ops(rsize)
        return self.compile_arrays(src, dst, size, rsrv, adds, mem)

    def compile_plan(self, plan: Plan) -> list[CompiledStep]:
        return [self.compile_step(st) for st in plan.steps]

    # ---- evaluation --------------------------------------------------------
    def step_cost(self, cs: CompiledStep
                  ) -> tuple[float, float, float, float, float]:
        """(t_step, comm, comp, alpha_eff, incast_extra) — identical
        accounting to the reference simulator's per-step loop."""
        pt = self.pt
        comm = 0.0
        incast = 0.0
        alpha_eff = pt.alpha_srv if cs.has_transfers else 0.0
        if cs.lids.size:
            nid = cs.lids >> 1
            extra = (np.maximum(cs.lnsend - pt.node_wt[nid], 0.0)
                     * cs.lunits * self.scale * pt.node_eps[nid])
            t_link = cs.lunits * pt.node_tpb[nid] + extra + pt.node_lat[nid]
            incast += float(extra.sum())
            comm = float(t_link.max())
            alpha_eff = max(alpha_eff, float(pt.node_alpha[nid].max()))
        if cs.rdst.size:
            w = cs.rfan + 1.0
            extra = (np.maximum(w - pt.srv_wt[cs.rdst], 0.0)
                     * cs.runits * self.scale * pt.srv_eps[cs.rdst])
            t_nic = cs.runits * pt.srv_tpb[cs.rdst] + extra
            incast += float(extra.sum())
            comm = max(comm, float(t_nic.max()))
        comp = 0.0
        if cs.csrv.size:
            comp = float(((cs.cadds * pt.gamma + cs.cmem * pt.delta)
                          * self.scale).max())
        if cs.has_reduces and not cs.has_transfers:
            alpha_eff = max(alpha_eff, pt.alpha_srv)
        return alpha_eff + comm + comp, comm, comp, alpha_eff, incast

    def total(self, compiled: Sequence[CompiledStep]) -> float:
        t = 0.0
        for cs in compiled:
            t += self.step_cost(cs)[0]
        return t

    # ---- link-contention pricing (DESIGN.md §15) ---------------------------
    def merge_steps(self, parts: Sequence[CompiledStep]) -> CompiledStep:
        """Occupancy merge of concurrent rounds: shared links serialize
        (units add) and their distinct-sender counts — hence incast
        fan-ins — SUM; disjoint links keep their own time and overlap
        through `step_cost`'s per-link max. Vectorized twin of
        `cost_model.LinkOccupancy.merge` (must agree ≤ 1e-9)."""
        parts = [cs for cs in parts if cs is not None]
        if len(parts) == 1:
            return parts[0]
        nlinks, nsrv = self.rx.n_links, self.rx.sid_cap
        lu = np.zeros(nlinks)
        ln = np.zeros(nlinks, dtype=np.int64)
        ltouch = np.zeros(nlinks, dtype=bool)
        ru = np.zeros(nsrv)
        rf = np.zeros(nsrv, dtype=np.int64)
        rtouch = np.zeros(nsrv, dtype=bool)
        ca = np.zeros(nsrv)
        cm = np.zeros(nsrv)
        ctouch = np.zeros(nsrv, dtype=bool)
        has_t = has_r = False
        for cs in parts:
            if cs.lids.size:
                np.add.at(lu, cs.lids, cs.lunits)
                np.add.at(ln, cs.lids, cs.lnsend)
                ltouch[cs.lids] = True
            if cs.rdst.size:
                np.add.at(ru, cs.rdst, cs.runits)
                np.add.at(rf, cs.rdst, cs.rfan)
                rtouch[cs.rdst] = True
            if cs.csrv.size:
                np.add.at(ca, cs.csrv, cs.cadds)
                np.add.at(cm, cs.csrv, cs.cmem)
                ctouch[cs.csrv] = True
            has_t |= cs.has_transfers
            has_r |= cs.has_reduces
        lids = np.nonzero(ltouch)[0]
        rdst = np.nonzero(rtouch)[0]
        csrv = np.nonzero(ctouch)[0]
        return CompiledStep(lids=lids, lunits=lu[lids], lnsend=ln[lids],
                            rdst=rdst, runits=ru[rdst], rfan=rf[rdst],
                            csrv=csrv, cadds=ca[csrv], cmem=cm[csrv],
                            has_transfers=has_t, has_reduces=has_r)

    def concurrent_cost(self, parts: Sequence[CompiledStep]
                        ) -> tuple[float, float, float, float, float]:
        """Contended cost of ≥1 rounds running concurrently — one merged
        fan-in SUMS the incast: two below-threshold rounds can together
        cross w_t, so this may exceed the two sequential costs. That is
        the signal the planner's argmin{sequential, merged} keys on."""
        return self.step_cost(self.merge_steps(parts))

    def contended_pair_total(self, ca: Sequence[CompiledStep],
                             cb: Sequence[CompiledStep]) -> float:
        """Two compiled step lists run concurrently, paired round-by-round
        (leftover rounds of the longer list price alone). Mirrors
        `cost_model.contended_pair_time` at ≤ 1e-9."""
        t = 0.0
        for i in range(max(len(ca), len(cb))):
            parts = []
            if i < len(ca):
                parts.append(ca[i])
            if i < len(cb):
                parts.append(cb[i])
            t += self.step_cost(self.merge_steps(parts))[0]
        return t

    def contended_halves_total(self, plan_a: Plan, plan_b: Plan) -> float:
        """Contended concurrent price of two whole plans (e.g. the RS half
        of bucket k against the AG half of bucket k-1)."""
        return self.contended_pair_total(self.compile_plan(plan_a),
                                         self.compile_plan(plan_b))

    def contended_halves(self, plan: Plan) -> float:
        """Steady-state joint time of an allreduce plan's own halves run
        concurrently (the bucket pipeline's inner term). Non-allreduce
        plans have a single half — their contended time is just the total."""
        from .plans import family_halves
        if plan.family != "allreduce":
            return self.total(self.compile_plan(plan))
        rs, ag = family_halves(plan)
        return self.contended_halves_total(rs, ag)

    def totals(self, batch: Sequence[Sequence[CompiledStep]]) -> list[float]:
        """Batched candidate evaluation: one call prices every candidate's
        compiled step list (the GenTree per-switch search path)."""
        return [self.total(compiled) for compiled in batch]

    def halves_totals(self, plan: Plan) -> tuple[float, float]:
        """(t_rs, t_ag): the plan priced as its two pipeline stages.

        An allreduce plan splits at its Kolmakov–Zhang cut (the last
        fold step — `plans.family_halves`), the stages `bucketing.
        pipelined_time` and `get_step_plan` overlap. A standalone
        family plan prices entirely on its own side; pure-movement
        families (allgather/all_to_all/p2p) count as AG-stage work."""
        from .plans import family_halves
        if plan.family == "allreduce":
            rs, ag = family_halves(plan)
            return (self.total(self.compile_plan(rs)),
                    self.total(self.compile_plan(ag)))
        t = self.total(self.compile_plan(plan))
        if plan.family == "reduce_scatter":
            return t, 0.0
        return 0.0, t

    def simulate(self, plan: Plan):
        """Full SimResult, field-for-field compatible with the reference."""
        from .simulator import SimResult
        res = SimResult(total=0.0)
        for st in plan.steps:
            t, comm, comp, alpha, incast = self.step_cost(
                self.compile_step(st))
            res.per_step.append(t)
            res.total += t
            res.comm += comm
            res.compute += comp
            res.latency += alpha
            res.incast_extra += incast
        return res
