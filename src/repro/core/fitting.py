"""Fitting toolkit (paper §3.4): estimate GenModel parameters for a cluster.

The paper fits (α, 2β+γ, δ, ε, w_t) from co-located-PS benchmarks over
2..N communicators, plus the Fig.-4 memory microbenchmark for (δ, γ).
Everything here is plain least squares on numpy — no hardware assumptions —
so it runs on recorded measurements from any cluster (or our simulator).
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from .cost_model import GenModelParams, cost_cps


def fit_delta_gamma(xs: np.ndarray, times: np.ndarray, s: float
                    ) -> tuple[float, float]:
    """Fit the Fig.-4 microbenchmark:  T(x) = (x+1)·S·δ + (x−1)·S·γ.

    xs: fan-in degrees; times: measured seconds; s: vector length (units).
    Returns (delta, gamma) per data unit.
    """
    A = np.stack([(xs + 1) * s, (xs - 1) * s], axis=1)
    coef, *_ = np.linalg.lstsq(A, times, rcond=None)
    return float(coef[0]), float(coef[1])


def detect_w_t(xs: np.ndarray, times: np.ndarray,
               rel_jump: float = 0.08) -> int:
    """Detect the incast threshold: the smallest fan-in where the x-to-x
    time departs from its flat plateau by more than `rel_jump` (paper §3.2:
    T(x) = α + Sβ is constant below w_t)."""
    base = float(np.median(times[: max(2, len(times) // 3)]))
    for x, t in zip(xs, times):
        if t > base * (1.0 + rel_jump):
            return int(x)
    return int(xs[-1]) + 1  # no incast observed in range


def fit_epsilon(xs: np.ndarray, times: np.ndarray, s: float, w_t: int,
                beta_alpha_base: float | None = None) -> float:
    """Fit ε from the post-threshold linear growth of x-to-x tests:
    T(x) = (α + Sβ) + max(x − w_t, 0)·S·ε."""
    base = beta_alpha_base
    if base is None:
        mask = xs < w_t
        base = float(np.mean(times[mask])) if mask.any() else float(times[0])
    mask = xs >= w_t
    if not mask.any():
        return 0.0
    excess = (xs[mask] - w_t) * s
    extra = times[mask] - base
    denom = float(np.dot(excess, excess))
    return float(np.dot(excess, extra) / denom) if denom > 0 else 0.0


def _lstsq_cps(ns, sizes, times, w_t):
    col_alpha = np.full_like(ns, 2.0)
    col_bg = 2.0 * (ns - 1) * sizes / ns
    col_delta = (ns + 1) * sizes / ns
    col_eps = 2.0 * (ns - 1) * sizes / ns * np.maximum(ns - w_t, 0.0)
    A = np.stack([col_alpha, col_bg, col_delta, col_eps], axis=1)
    coef, res, *_ = np.linalg.lstsq(A, times, rcond=None)
    pred = A @ coef
    return coef, float(((pred - times) ** 2).sum())


def fit_from_cps_benchmarks(ns: np.ndarray, sizes: np.ndarray,
                            times: np.ndarray,
                            w_t: int | None = None) -> GenModelParams:
    """Fit (α, β, γ, δ, ε) jointly from co-located-PS runs at varying
    (N, S). Uses the Table-2 CPS expression as the design matrix. The β and
    γ coefficients keep a fixed 2:1 ratio (paper: only 2β+γ is identifiable)
    — we report β = (2β+γ)/2·(2/2.5), γ = .5β convention-free by fitting the
    combined column and splitting with the paper's convention γ = β/2·...;
    here we simply expose the combined coefficient through β and set γ via
    the δ microbench when available."""
    ns = np.asarray(ns, dtype=float)
    sizes = np.asarray(sizes, dtype=float)
    times = np.asarray(times, dtype=float)
    if w_t is None:
        # grid-search the threshold: pick the w_t whose piecewise-linear
        # CPS model explains the curve best (robust to interleaved sizes,
        # unlike plateau detection on raw x-to-x times)
        best = None
        for cand in range(2, int(ns.max()) + 1):
            _, resid = _lstsq_cps(ns, sizes, times, cand)
            if best is None or resid < best[1]:
                best = (cand, resid)
        w_t = best[0]
    coef, _ = _lstsq_cps(ns, sizes, times, w_t)
    alpha, bg, delta, eps = [float(max(c, 0.0)) for c in coef]
    # split combined β+γ/2 with the paper's 2:1 coefficient structure:
    beta = bg * 2.0 / 2.5
    gamma = bg / 2.5
    return GenModelParams(alpha=alpha, beta=beta, gamma=gamma,
                          delta=delta, epsilon=eps, w_t=int(w_t))


def fit_params_for_level(base: GenModelParams, **overrides) -> GenModelParams:
    return replace(base, **overrides)


# ---------------------------------------------------------------------------
# Per-term residual attribution — the cost ledger's diagnosis side
# (DESIGN.md §11).  Input: one share vector per observed collective
# (predicted seconds booked under each GenModel term, from
# cost_model.evaluate_plan_terms) plus the measured wall time.  Output:
# per-term multipliers m_t minimizing ||S·m − measured||₂, i.e. the
# uniform per-term scaling that best explains all samples at once.
# m_t == 1 → the term is priced right; m_t == 3 → "δ drifted 3×".
# ---------------------------------------------------------------------------
TERM_NAMES = ("alpha", "beta", "gamma", "delta", "incast")


def attribute_term_drift(shares: list[dict[str, float]],
                         measured: list[float],
                         ) -> dict[str, float | None]:
    """Least-squares per-term drift multipliers over a sample window.

    ``shares[i][t]`` is the predicted seconds sample *i* books under term
    *t*; ``measured[i]`` its wall time.  Terms with zero share across the
    whole window are unidentifiable and map to ``None``.  Needs at least
    one sample; with fewer samples than active terms the minimum-norm
    solution is returned (pinned to the observed directions).
    """
    if len(shares) != len(measured):
        raise ValueError("shares and measured must have equal length")
    if not shares:
        return {t: None for t in TERM_NAMES}
    S = np.array([[float(sh.get(t, 0.0)) for t in TERM_NAMES]
                  for sh in shares], dtype=float)
    y = np.asarray(measured, dtype=float)
    active = S.any(axis=0)
    out: dict[str, float | None] = {t: None for t in TERM_NAMES}
    if not active.any():
        return out
    coef, *_ = np.linalg.lstsq(S[:, active], y, rcond=None)
    for t, m in zip(np.array(TERM_NAMES)[active], coef):
        out[str(t)] = float(m)
    return out


# ---------------------------------------------------------------------------
# Online-measurement normalization (runtime telemetry → the CPS fit)
# ---------------------------------------------------------------------------
def cps_equivalent_time(n: int, size_floats: float, measured: float,
                        plan_predicted: float, p: GenModelParams) -> float:
    """Normalize the measured wall time of an *arbitrary executed plan*
    into the equivalent co-located-PS sample the least-squares path above
    consumes.

    The runtime executes whatever plan GenTree picked — not the CPS
    microbench the Table-2 design matrix describes — so a raw measured
    time cannot enter `fit_from_cps_benchmarks` directly. But the model
    itself prices both: scaling the measurement by the *modeled* ratio
    cost_cps(n, S) / plan_predicted maps "what the executed plan took"
    onto "what the CPS bench would have taken" under the same parameter
    drift. At zero drift the factor round-trips exactly; under drift the
    multiplicative error terms (β, ε) it is designed to recover dominate,
    which is precisely when the refit fires. This keeps ONE fitting
    codepath: offline microbenches and online telemetry samples both run
    through the Table-2 least squares.
    """
    if plan_predicted <= 0.0:
        return float(measured)
    factor = cost_cps(int(n), float(size_floats), p) / float(plan_predicted)
    return float(measured) * factor
