"""JAX version compatibility shims (DESIGN.md §6).

The codebase targets the modern `jax.shard_map` surface (`check_vma`,
`axis_names`). Older jax releases (< 0.5) only ship
`jax.experimental.shard_map.shard_map`, whose equivalents are `check_rep`
and `auto` (the complement of `axis_names` over the mesh). This wrapper
presents the modern signature on both, so engines, kernels and tests can
import one name:

    from repro.core.compat import shard_map
"""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map
    _MODERN = True
except ImportError:                      # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map
    _MODERN = False


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              axis_names=None, **kw):
    if _MODERN:
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
