"""Gradient bucketing + double-buffered pipelined plan execution (DESIGN.md §9).

GenModel's two new terms pull the gradient bucket size in opposite
directions: the memory-access term (γ/δ) and the per-round launch term (α)
penalize many small fragmented reduces, while the incast (ε) and
serialization terms penalize one monolithic transfer whose rounds cannot
overlap. The paper's own cost model therefore *picks* the bucket size:
`PlannerService.get_bucket_plan` sweeps powers-of-two candidates, prices
each with GenModel (FastEngine by default), and returns the argmin together
with one lowered `CompiledSchedule` per axis (cached on the plan entry —
never re-lowered per step).

This module holds the mechanics around that decision:

  * `partition(sizes, dtypes, cap[, itemsizes])` — split the flattened
    gradient pytree into size-bounded (byte-bounded with itemsizes),
    dtype-homogeneous `Bucket`s (empty leaves pass through, an
    oversized leaf rides alone);
  * `pipelined_time` / `serial_time` — the two-stage pipeline model the
    sweep prices: with K buckets, bucket k's AllGather half overlaps
    bucket k+1's ReduceScatter half, so
    T = T_RS + (K−1)·max(T_RS, T_AG) + T_AG instead of K·(T_RS + T_AG);
  * `execute_buckets` — the double-buffered executor: per bucket an RS
    chain over the DP axes (leaf axis first) then the mirrored AG chain,
    issued so that bucket k+1's RS is in flight before bucket k's AG
    drains (XLA may overlap the independent collectives; the issuance
    order documents the modeled schedule). Falls back to sequential
    per-bucket `allreduce` when a schedule has no canonical RS/AG halves;
  * `sync_bucketed` — the `SyncConfig(strategy="plan")` entry point used
    by `core.sync.sync_gradients`;
  * `zero3_gather_bucketed` / `zero3_scatter_bucketed` — the ZeRO-3
    trainer's bucketed param-AllGather / grad-ReduceScatter (one schedule
    launch per bucket instead of per leaf; single-DP-axis layout);
  * `invalidate_schedules` — drops every lowered schedule and bucket plan
    derived from a service's cache. Called after `elastic_remesh` and on
    `FaultTolerantLoop` resume: a schedule compiled for the old axis size
    must not survive an axis-size change.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

from repro.runtime.metrics import default_metrics
from repro.runtime.trace import default_tracer


# ---------------------------------------------------------------------------
# Config + bucket structure
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BucketConfig:
    """How gradients are bucketed for plan execution.

    bucket_bytes: None → "auto" (GenModel argmin over the sweep);
    an explicit int fixes the bucket size; 0 disables bucketing entirely
    (legacy per-leaf execution).

    precision pins a wire format by name ("f32"/"bf16"/"fp8"/"int8");
    None lets the sweep argmin over every format `tolerance` allows.
    tolerance is the caller's per-sync relative error budget: None means
    no lossy consent (the sweep stays lossless; a pinned lossy precision
    is trusted as explicit opt-in), a float clamps any format whose
    `Precision.error_budget` exceeds it to full precision
    (DESIGN.md §13). Both are part of `key()` — and therefore of the
    bucket-plan cache fingerprint — so a tolerance change can never be
    served a stale compressed schedule.
    """
    bucket_bytes: int | None = None
    pipeline: bool = True               # overlap AG(k) with RS(k+1)
    min_bucket_bytes: int = 1 << 18     # sweep floor (256 KiB)
    max_bucket_bytes: int = 1 << 28     # sweep ceiling (256 MiB)
    precision: str | None = None        # pinned wire format (None: sweep)
    tolerance: float | None = None      # error budget (None: lossless only)

    def __post_init__(self):
        if self.bucket_bytes is not None and self.bucket_bytes < 0:
            raise ValueError(
                f"bucket_bytes must be None (auto), 0 (off) or positive; "
                f"got {self.bucket_bytes}")
        if not 0 < self.min_bucket_bytes <= self.max_bucket_bytes:
            raise ValueError(
                f"need 0 < min_bucket_bytes <= max_bucket_bytes; got "
                f"{self.min_bucket_bytes}..{self.max_bucket_bytes}")
        if self.precision is not None:
            from .cost_model import PRECISIONS
            if self.precision not in PRECISIONS:
                raise ValueError(
                    f"unknown precision {self.precision!r}; one of "
                    f"{sorted(PRECISIONS)}")

    @property
    def enabled(self) -> bool:
        return self.bucket_bytes != 0

    def key(self) -> tuple:
        return (self.bucket_bytes if self.bucket_bytes is not None else -1,
                int(self.pipeline), self.min_bucket_bytes,
                self.max_bucket_bytes, self.precision or "",
                -1.0 if self.tolerance is None else float(self.tolerance))


@dataclass(frozen=True)
class Bucket:
    """One dtype-homogeneous group of leaf positions, bounded in size."""
    indices: tuple[int, ...]            # leaf positions (flattened order)
    sizes: tuple[int, ...]              # element count per member leaf
    dtype: object                       # shared numpy/jax dtype

    @property
    def size(self) -> int:
        return sum(self.sizes)


def partition(sizes: Sequence[int], dtypes: Sequence[object],
              cap: int | float,
              itemsizes: Sequence[int] | None = None) -> list[Bucket]:
    """Greedy, order-preserving partition of the flattened leaf list into
    dtype-homogeneous buckets. `cap` bounds each bucket's total *elements*
    — or total *bytes* when `itemsizes` (per-leaf element widths) is
    given, so a mixed f32/bf16 pytree honours one byte budget across both
    dtype classes instead of letting the wider dtype carry itemsize× the
    bound.

    Leaves keep their relative order within each dtype class; a leaf larger
    than the bound gets a bucket of its own; empty (size-0) leaves are
    assigned to no bucket (the executor passes them through unchanged).
    Buckets are returned ordered by their first member's leaf index, so the
    output is deterministic for a given leaf list.
    """
    cap = max(1, int(cap))
    weights = [int(s) for s in sizes] if itemsizes is None else \
        [int(s) * int(w) for s, w in zip(sizes, itemsizes)]
    open_by_dtype: dict[object, list[tuple[int, int]]] = {}
    open_weight: dict[object, int] = {}
    closed: list[list[tuple[int, int]]] = []

    def close(key):
        members = open_by_dtype.pop(key, None)
        open_weight.pop(key, None)
        if members:
            closed.append(members)

    for i, (sz, dt) in enumerate(zip(sizes, dtypes)):
        sz = int(sz)
        if sz == 0:
            continue
        key = str(dt)
        cur = open_by_dtype.setdefault(key, [])
        if cur and open_weight.get(key, 0) + weights[i] > cap:
            close(key)
            cur = open_by_dtype.setdefault(key, [])
        cur.append((i, sz))
        open_weight[key] = open_weight.get(key, 0) + weights[i]
        if weights[i] >= cap:
            close(key)
    for key in list(open_by_dtype):
        close(key)

    closed.sort(key=lambda members: members[0][0])
    return [Bucket(indices=tuple(i for i, _ in members),
                   sizes=tuple(s for _, s in members),
                   dtype=dtypes[members[0][0]])
            for members in closed]


# ---------------------------------------------------------------------------
# Pipeline time model (what the sweep prices)
# ---------------------------------------------------------------------------
def serial_time(t_rs: float, t_ag: float, k: int) -> float:
    """K buckets executed back-to-back: no overlap."""
    return max(0, k) * (t_rs + t_ag)


def pipelined_time(t_rs: float, t_ag: float, k: int) -> float:
    """Two-stage software pipeline: bucket k's AG overlaps bucket k+1's RS,
    so the steady state advances one bucket per max(T_RS, T_AG).

    This is the NAIVE model — it assumes the overlapped halves never share
    a link. Kept as the optimistic baseline the contended model is
    benchmarked against (`contended_vs_naive_pipeline_error`); the sweep
    itself ranks on `contended_pipelined_time`."""
    if k <= 0:
        return 0.0
    if k == 1:
        return t_rs + t_ag
    return t_rs + (k - 1) * max(t_rs, t_ag) + t_ag


def contended_pipelined_time(t_rs: float, t_ag: float, k: int,
                             t_joint: float | None = None) -> float:
    """Link-contention-aware pipeline model (DESIGN.md §15): the steady
    state advances one bucket per the CONTENDED concurrent time of the
    RS and AG halves — `t_joint`, priced by merging the halves' per-link
    occupancy vectors (`FastEngine.contended_pair_total` /
    `cost_model.contended_pair_time`) — not their optimistic `max()`.

    On disjoint links t_joint == max(t_rs, t_ag) and this reduces to
    `pipelined_time`; on shared links the serialized β/ε push it toward
    (and past — summed incast fan-in crossing w_t) t_rs + t_ag. The
    planner controls issuance, so the steady state never does worse than
    back-to-back halves: t_joint clamps to [max(t_rs, t_ag), t_rs + t_ag].
    """
    if k <= 0:
        return 0.0
    if k == 1:
        return t_rs + t_ag
    if t_joint is None:
        t_joint = max(t_rs, t_ag)
    t_joint = min(max(t_joint, max(t_rs, t_ag)), t_rs + t_ag)
    return t_rs + (k - 1) * t_joint + t_ag


# ---------------------------------------------------------------------------
# Executors (call inside shard_map; all shapes static at trace time)
# ---------------------------------------------------------------------------
def supports_halves(axis_plans) -> bool:
    """True when every axis schedule exposes the canonical RS/AG halves
    the double-buffered pipeline needs; otherwise execute_buckets
    degrades to sequential whole-plan allreduce per bucket."""
    return all(pl.schedule is not None
               and getattr(pl.schedule, "blocks_per_shard", None)
               for pl in axis_plans)


def _rs_chain(vec, axis_plans, fused_reduce):
    """Hierarchical ReduceScatter: leaf axis first. Returns the final shard
    plus the pre-RS vector size per axis (needed to undo schedule padding
    on the mirrored AG chain)."""
    sizes = []
    for pl in axis_plans:
        sizes.append(vec.size)
        vec = pl.schedule.reduce_scatter(vec, pl.axis,
                                         fused_reduce=fused_reduce)
    return vec, sizes


def _ag_chain(shard, axis_plans, sizes):
    for pl, sz in zip(reversed(axis_plans), reversed(sizes)):
        shard = pl.schedule.all_gather(shard, pl.axis)[:sz]
    return shard


def _allreduce_chain(vec, axis_plans, fused_reduce):
    for pl in axis_plans:
        vec = pl.schedule.allreduce(vec, pl.axis, fused_reduce=fused_reduce)
    return vec


def execute_buckets(leaves, buckets: Sequence[Bucket], axis_plans, *,
                    pipeline: bool = True,
                    fused_reduce: Callable | None = None,
                    merged=None, reverse: bool = False) -> list:
    """AllReduce every bucket across the DP axes; returns the reduced
    leaf list (leaves outside any bucket — empty leaves — unchanged).

    Scheduler state machine (DESIGN.md §9): each bucket moves
    QUEUED → RS → SHARD → AG → DONE with at most two buckets in flight;
    at step k the executor issues RS(bucket k) *then* AG(bucket k−1), so
    the next bucket's reduce is on the wire before the previous bucket's
    gather drains.

    `reverse=True` issues buckets in reverse-layer readiness order
    (DESIGN.md §15): backward produces gradients last-layer-first, and
    the greedy partition orders buckets by first leaf index, so the
    LAST bucket's gradients materialize first — issuing k−1, k−2, … lets
    each RS leave as soon as its bucket is ready instead of stalling on
    bucket 0. Results land in leaf order either way.

    `merged` (a `core.overlap.MergedSchedule` from the bucket plan's
    {sequential, merged} argmin) fuses each steady-state step into ONE
    round-interleaved launch — RS(bucket k) coalesced with AG(bucket
    k−1) on their disjoint links. Single-axis chains only (the
    hierarchical handoff already serializes at the axis boundary);
    ignored otherwise.
    """
    import jax.numpy as jnp

    out = list(leaves)
    if not buckets:
        return out
    flats = []
    for bk in buckets:
        parts = [leaves[i].reshape(-1) for i in bk.indices]
        flats.append(parts[0] if len(parts) == 1
                     else jnp.concatenate(parts))

    k = len(flats)
    order = list(range(k - 1, -1, -1)) if reverse else list(range(k))
    tracer = default_tracer()
    results: list = [None] * k
    use_merged = (merged is not None and pipeline and k > 1
                  and len(axis_plans) == 1 and supports_halves(axis_plans))
    if use_merged:
        pl = axis_plans[0]
        shards: list = [None] * k
        prev = None
        for i in order:
            if prev is None:
                with tracer.span("bucket/rs", bucket=i,
                                 elements=int(flats[i].size)):
                    shards[i] = pl.schedule.reduce_scatter(
                        flats[i], pl.axis, fused_reduce=fused_reduce)
            else:
                with tracer.span("bucket/rs_ag", bucket=i, drains=prev):
                    shards[i], full = merged.rs_ag(
                        flats[i], shards[prev], pl.axis,
                        fused_reduce=fused_reduce)
                results[prev] = full[:int(flats[prev].size)]
                shards[prev] = None
            prev = i
        with tracer.span("bucket/ag", bucket=prev):
            results[prev] = pl.schedule.all_gather(
                shards[prev], pl.axis)[:int(flats[prev].size)]
    elif pipeline and k > 1 and supports_halves(axis_plans):
        shards, sizes = [None] * k, [None] * k
        prev = None
        for i in order:
            with tracer.span("bucket/rs", bucket=i,
                             elements=int(flats[i].size)):
                shards[i], sizes[i] = _rs_chain(flats[i], axis_plans,
                                                fused_reduce)
            if prev is not None:
                with tracer.span("bucket/ag", bucket=prev):
                    results[prev] = _ag_chain(shards[prev], axis_plans,
                                              sizes[prev])
                shards[prev] = None
            prev = i
        with tracer.span("bucket/ag", bucket=prev):
            results[prev] = _ag_chain(shards[prev], axis_plans,
                                      sizes[prev])
    elif supports_halves(axis_plans):
        for i in order:
            with tracer.span("bucket/rs", bucket=i,
                             elements=int(flats[i].size)):
                shard, sizes = _rs_chain(flats[i], axis_plans,
                                         fused_reduce)
            with tracer.span("bucket/ag", bucket=i):
                results[i] = _ag_chain(shard, axis_plans, sizes)
    else:
        # no canonical shard layout on some axis: sequential whole-plan
        # AllReduce per bucket (still amortizes per-leaf launches)
        for i in order:
            with tracer.span("bucket/allreduce", bucket=i,
                             elements=int(flats[i].size)):
                results[i] = _allreduce_chain(flats[i], axis_plans,
                                              fused_reduce)

    for bk, res in zip(buckets, results):
        off = 0
        for i, sz in zip(bk.indices, bk.sizes):
            out[i] = res[off:off + sz].reshape(leaves[i].shape)
            off += sz
    return out


def sync_bucketed(grads, axes: Sequence[tuple[str, int]], cfg, *,
                  service=None, fused_reduce: Callable | None = None,
                  stats: dict | None = None):
    """Bucketed, double-buffered gradient AllReduce — the
    `SyncConfig(strategy="plan")` execution path of
    `core.sync.sync_gradients`. Must run inside shard_map with every
    axis present. The bucket size, per-axis plans and their lowered
    schedules come from `PlannerService.get_bucket_plan` (resolved at
    trace time; warm lookups are a cache probe).

    `stats`, when given, is filled in place with the resolved bucket
    plan's identity and modeled costs (plan fingerprint key, bucket
    size, bucket count, predicted pipelined/serial seconds) — the
    trainer pairs these predictions with measured step timings when it
    feeds the online loop (`PlannerService.observe`, DESIGN.md §10)."""
    import jax

    leaves, treedef = jax.tree.flatten(grads)
    sizes = [int(x.size) for x in leaves]
    total = float(sum(sizes))
    live = [(a, int(n)) for a, n in axes if int(n) > 1]
    if not live or total == 0 or not leaves:
        return grads

    if service is None:
        from repro.planner.service import default_service
        service = default_service()
    bcfg = BucketConfig(bucket_bytes=cfg.bucket_bytes,
                        pipeline=cfg.pipeline,
                        precision=getattr(cfg, "precision", None),
                        tolerance=getattr(cfg, "tolerance", None))
    # price in f32-equivalent units of the tree's total BYTES, so the
    # chosen byte budget does not depend on which dtype happens to
    # flatten first in a mixed-dtype pytree
    total_bytes = sum(int(x.size) * x.dtype.itemsize for x in leaves)
    bplan = service.get_bucket_plan(axes, total_bytes / 4.0,
                                    dtype="float32",
                                    params=cfg.params, config=bcfg)
    # backward-overlapped issuance (DESIGN.md §15): reverse-layer order
    # plus the merged RS/AG launch, but ONLY when the planner's
    # {sequential, merged} argmin says the contended price wins
    reverse = bool(getattr(cfg, "backward_overlap", True))
    merged = bplan.merged_schedule \
        if bplan.overlap.get("mode") == "merged" else None
    if stats is not None:
        stats.update({
            "key": bplan.key, "source": bplan.source,
            "axes": list(bplan.axes),
            "bucket_floats": bplan.bucket_floats,
            "bucket_bytes": bplan.bucket_bytes,
            "num_buckets": bplan.num_buckets,
            "precision": bplan.precision,
            "predicted_pipelined": bplan.predicted_pipelined,
            "predicted_serial": bplan.predicted_serial,
            "predicted_contended": bplan.predicted_contended,
            "overlap_mode": bplan.overlap.get("mode", "sequential"),
            "backward_overlap": reverse,
        })
    # byte-capped partition: every dtype class honours the same budget
    buckets = partition(sizes, [x.dtype for x in leaves],
                        bplan.bucket_bytes,
                        itemsizes=[x.dtype.itemsize for x in leaves])
    m = default_metrics()
    m.counter("sync_bucketed_total",
              "bucketed plan-strategy gradient syncs").inc()
    m.histogram("sync_buckets_per_step",
                "buckets per sync_bucketed call",
                buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)
                ).observe(float(len(buckets)))
    # pipeline occupancy: modeled speedup of the double-buffered
    # schedule over serial execution, normalized to [0.5, 1] — 0.5 for
    # a single bucket (nothing overlaps), → 1 as the RS/AG halves
    # balance and the bucket count grows (DESIGN.md §9's pipeline model).
    # Charged on the CONTENDED pipeline estimate (§15), so the gauge
    # reflects what link sharing leaves of the modeled overlap.
    contended = bplan.predicted_contended or bplan.predicted_pipelined
    if contended > 0.0:
        m.gauge("bucket_pipeline_occupancy",
                "modeled serial/contended speedup, normalized to [.5,1]"
                ).set(bplan.predicted_serial / (2.0 * contended))
    if merged is not None:
        m.counter("sync_bucketed_merged_issue_total",
                  "syncs issued with the merged RS/AG schedule "
                  "(planner argmin chose merged)").inc()
    axis_plans = bplan.axis_plans
    if getattr(cfg, "guard", True):
        # guard the executed schedules (DESIGN.md §12); guard_schedule
        # memoizes per underlying schedule, so demotion state persists
        # across steps that cache-hit the same bucket plan
        import dataclasses as _dc

        from .lower import guard_schedule
        tele = getattr(service, "telemetry", None)
        axis_plans = [
            _dc.replace(pl, schedule=guard_schedule(pl.schedule,
                                                    telemetry=tele))
            if pl.schedule is not None else pl
            for pl in axis_plans]
    with default_tracer().span("sync/bucketed", buckets=len(buckets),
                               bucket_bytes=bplan.bucket_bytes,
                               source=bplan.source,
                               overlap=bplan.overlap.get("mode",
                                                         "sequential"),
                               reverse=reverse):
        out = execute_buckets(leaves, buckets, axis_plans,
                              pipeline=bcfg.pipeline,
                              fused_reduce=fused_reduce,
                              merged=merged, reverse=reverse)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# ZeRO-3 bucketed halves (single DP axis; launch/train.py manual engine)
# ---------------------------------------------------------------------------
def _pad_to(vec, multiple: int):
    import jax.numpy as jnp
    pad = (-vec.size) % multiple
    if pad:
        vec = jnp.concatenate([vec, jnp.zeros((pad,), vec.dtype)])
    return vec


def zero3_gather_bucketed(shards, specs, plan, bucket_bytes: int, n: int
                          ) -> list:
    """Bucketed parameter AllGather for the ZeRO-3 row layout.

    `shards[ℓ]` is leaf ℓ's flat per-device shard (row i of the leaf
    padded to a multiple of `n` and reshaped (n, chunk_ℓ) — the
    `shard_params_zero3` layout); `specs[ℓ] = (shape, dtype)` describes
    the full leaf. Same-dtype shards concatenate into one row per bucket,
    padded to the schedule's blocks-per-shard multiple, and ONE
    `all_gather` launch per bucket reassembles the (n, ΣC) matrix whose
    columns split back into the full leaves — per-leaf α collapses to
    per-bucket α. The shard cap is `bucket_bytes / n`: the gather
    launch reassembles n× its input, so this keeps the moved data per
    launch at the bucket size the GenModel sweep actually priced."""
    import jax.numpy as jnp

    cs = plan.schedule
    k = cs.blocks_per_shard
    buckets = partition([s.size for s in shards],
                        [s.dtype for s in shards],
                        max(1, int(bucket_bytes) // max(1, int(n))),
                        itemsizes=[s.dtype.itemsize for s in shards])
    out = [None] * len(shards)
    tracer = default_tracer()
    for bi, bk in enumerate(buckets):
        row = jnp.concatenate([shards[i].reshape(-1) for i in bk.indices]) \
            if len(bk.indices) > 1 else shards[bk.indices[0]].reshape(-1)
        ncols = row.size
        row = _pad_to(row, k)
        with tracer.span("bucket/zero3_ag", bucket=bi,
                         leaves=len(bk.indices)):
            mat = cs.all_gather(row, plan.axis).reshape(n, -1)[:, :ncols]
        off = 0
        for i, c in zip(bk.indices, bk.sizes):
            shape, dtype = specs[i]
            count = 1
            for s in shape:
                count *= s
            out[i] = (mat[:, off:off + c].reshape(-1)[:count]
                      .reshape(shape).astype(dtype))
            off += c
    for i, (shape, dtype) in enumerate(specs):
        if out[i] is None:          # empty leaf: nothing was gathered
            out[i] = jnp.zeros(shape, dtype)
    return out


def zero3_scatter_bucketed(fulls, plan, bucket_bytes: int, n: int,
                           reverse: bool = False) -> list:
    """Bucketed gradient ReduceScatter (inverse layout of
    `zero3_gather_bucketed`): each full leaf pads to a multiple of `n`
    and contributes its (n, chunk_ℓ) rows as columns of the bucket
    matrix; ONE `reduce_scatter` launch per bucket returns row i — the
    concatenation of every member leaf's canonical shard i.

    `reverse=True` issues buckets in reverse-layer readiness order
    (DESIGN.md §15): backward materializes the LAST bucket's gradients
    first, so its reduce leaves the wire without stalling on bucket 0.
    Output placement is by bucket index — results are identical."""
    import jax.numpy as jnp

    cs = plan.schedule
    k = cs.blocks_per_shard
    sizes = [int(x.size) for x in fulls]
    chunks = [(sz + (-sz) % n) // n for sz in sizes]
    buckets = partition(sizes, [x.dtype for x in fulls], bucket_bytes,
                        itemsizes=[x.dtype.itemsize for x in fulls])
    out = [None] * len(fulls)
    tracer = default_tracer()
    issue = list(enumerate(buckets))
    if reverse:
        issue.reverse()
    for bi, bk in issue:
        mats = [_pad_to(fulls[i].reshape(-1), n).reshape(n, -1)
                for i in bk.indices]
        mat = mats[0] if len(mats) == 1 else jnp.concatenate(mats, axis=1)
        ncols = mat.shape[1]
        pad = (-ncols) % k
        if pad:
            mat = jnp.concatenate(
                [mat, jnp.zeros((n, pad), mat.dtype)], axis=1)
        with tracer.span("bucket/zero3_rs", bucket=bi,
                         leaves=len(bk.indices)):
            shard = cs.reduce_scatter(mat.reshape(-1), plan.axis)
        off = 0
        for i in bk.indices:
            out[i] = shard[off:off + chunks[i]]
            off += chunks[i]
    for i, x in enumerate(fulls):
        if out[i] is None:          # empty leaf: empty shard
            out[i] = jnp.zeros((0,), x.dtype)
    return out


# ---------------------------------------------------------------------------
# Invalidation (elastic remesh / fault-tolerant resume)
# ---------------------------------------------------------------------------
def invalidate_schedules(service=None) -> int:
    """Drop every lowered `CompiledSchedule` and cached bucket plan derived
    from the service's plan cache (the priced plans themselves survive —
    they are placement-independent). Returns the number of artifacts
    dropped. With `service=None` the process-wide default service is
    invalidated *if it exists* (never created just to be emptied).

    Call after any event that changes the executing mesh: an axis-size
    change (`runtime.ft.elastic_remesh`), a fault-tolerant restore onto
    possibly-different hardware (`FaultTolerantLoop`). A stale schedule
    compiled for the old axis size would raise at best (`_check_axis`)
    and silently mis-reduce at worst; after invalidation the next lookup
    re-lowers against the new axis sizes."""
    if service is None:
        from repro.planner.service import peek_default_service
        service = peek_default_service()
        if service is None:
            return 0
    return service.invalidate_executables()
