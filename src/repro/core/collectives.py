"""AllReduce plan types as JAX collective schedules (DESIGN.md §3).

Each of the paper's plan types becomes a shard_map-compatible schedule over
a named mesh axis, built from `lax.ppermute` / `lax.all_to_all` /
`lax.all_gather`:

  * ring  — 2(N−1) ppermute rounds, fan-in-2 chained adds (ε-optimal)
  * rhd   — 2·log N ppermute rounds, pairwise halving/doubling (any N;
            non-powers-of-two fold the χ(N) extras in and out)
  * cps   — one all_to_all + ONE fused N-ary reduce (δ-optimal; the fused
            reduce is the Pallas `fused_reduce` kernel on TPU)
  * hcps  — m staged sub-group exchanges with fan-ins f_0..f_{m−1}
            (the paper's trade-off point between δ and ε optimality)
  * psum  — XLA's native all-reduce (baseline / "auto")
  * plan  — a lowered GenTree plan (`core.lower.CompiledSchedule`),
            executed round-for-round (DESIGN.md §8)

All functions assume they run inside shard_map with `axis_name` a mesh axis
of size n, and operate on a flat per-device array `x` (identical shape on
every device — the DP-gradient case). reduce_scatter_* return x's shard
(size/n); all_gather_* invert them. allreduce composes the two and handles
padding.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax


def _dyn_take(parts: jax.Array, i: jax.Array) -> jax.Array:
    """parts: (n, chunk); i: traced scalar index → parts[i]."""
    return lax.dynamic_index_in_dim(parts, i, axis=0, keepdims=False)


def _dyn_put(buf: jax.Array, val: jax.Array, i: jax.Array) -> jax.Array:
    return lax.dynamic_update_index_in_dim(buf, val, i, axis=0)


def _shift_perm(n: int, k: int) -> list[tuple[int, int]]:
    return [(i, (i + k) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# Ring
# ---------------------------------------------------------------------------
def reduce_scatter_ring(x: jax.Array, axis_name: str) -> jax.Array:
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    parts = x.reshape((n, -1))
    acc = jnp.zeros_like(parts[0])
    for s in range(n - 1):
        k = (idx - 1 - s) % n
        acc = acc + _dyn_take(parts, k)
        acc = lax.ppermute(acc, axis_name, _shift_perm(n, 1))
    return acc + _dyn_take(parts, idx)


def all_gather_ring(x: jax.Array, axis_name: str) -> jax.Array:
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = _dyn_put(out, x, idx)
    cur = x
    for s in range(n - 1):
        cur = lax.ppermute(cur, axis_name, _shift_perm(n, 1))
        out = _dyn_put(out, cur, (idx - 1 - s) % n)
    return out.reshape((-1,) + x.shape[1:]) if x.ndim > 1 else out.reshape(-1)


# ---------------------------------------------------------------------------
# Recursive Halving & Doubling (any axis size; non-powers-of-two use the
# fold-in/fold-out patch `plans.rhd` models — the Table-1 χ(N) extra steps)
# ---------------------------------------------------------------------------
def _rhd_pow2(n: int) -> tuple[int, int]:
    pow2 = 1 << (n.bit_length() - 1)
    return pow2, n - pow2


def reduce_scatter_rhd(x: jax.Array, axis_name: str) -> jax.Array:
    """RHD halving phase. For non-power-of-two n, devices pow2..n-1 first
    fold their whole vector into partner idx-pow2 and sit out the halving;
    the returned shard is size/pow2 (meaningful on the pow2 core — compose
    with all_gather_rhd, whose fold-out re-broadcasts to the extras).
    x.size must be a multiple of pow2 (allreduce pads accordingly)."""
    n = lax.psum(1, axis_name)
    pow2, extra = _rhd_pow2(n)
    idx = lax.axis_index(axis_name)
    flat = x.reshape(-1)
    if extra:
        recv = lax.ppermute(flat, axis_name,
                            [(pow2 + e, e) for e in range(extra)])
        flat = flat + recv          # non-receivers get zeros: unchanged
    cur = flat.reshape((pow2, -1))
    d = pow2 // 2
    while d >= 1:
        m = cur.shape[0]
        lower, upper = cur[: m // 2], cur[m // 2:]
        bit = (idx // d) % 2
        keep = lax.select(bit == 1, upper, lower)
        send = lax.select(bit == 1, lower, upper)
        recv = lax.ppermute(send, axis_name,
                            [(i, i ^ d) for i in range(pow2)])
        cur = keep + recv
        d //= 2
    return cur.reshape(-1)


def all_gather_rhd(x: jax.Array, axis_name: str) -> jax.Array:
    """RHD doubling phase; for non-power-of-two n a final fold-out step
    ships the full vector from device e to its folded partner pow2+e."""
    n = lax.psum(1, axis_name)
    pow2, extra = _rhd_pow2(n)
    idx = lax.axis_index(axis_name)
    cur = x.reshape((1, -1))
    d = 1
    while d < pow2:
        recv = lax.ppermute(cur, axis_name,
                            [(i, i ^ d) for i in range(pow2)])
        bit = (idx // d) % 2
        lower = lax.select(bit == 1, recv, cur)
        upper = lax.select(bit == 1, cur, recv)
        cur = jnp.concatenate([lower, upper], axis=0)
        d *= 2
    full = cur.reshape(-1)
    if extra:
        recv = lax.ppermute(full, axis_name,
                            [(e, pow2 + e) for e in range(extra)])
        full = jnp.where(idx >= pow2, recv, full)
    return full


# ---------------------------------------------------------------------------
# Co-located PS (δ-optimal: single fused N-ary reduce)
# ---------------------------------------------------------------------------
def reduce_scatter_cps(x: jax.Array, axis_name: str,
                       fused_reduce: Callable | None = None) -> jax.Array:
    n = lax.psum(1, axis_name)
    parts = lax.all_to_all(x.reshape((n, -1)), axis_name,
                           split_axis=0, concat_axis=0)
    if fused_reduce is not None:
        return fused_reduce(parts)
    return parts.sum(axis=0)


def all_gather_cps(x: jax.Array, axis_name: str) -> jax.Array:
    return lax.all_gather(x, axis_name, axis=0, tiled=True)


# ---------------------------------------------------------------------------
# Hierarchical CPS with fan-ins `factors` (paper Figure 5)
# ---------------------------------------------------------------------------
def _digit_shift_perm(n: int, radix: int, f: int, k: int) -> list[tuple[int, int]]:
    """Permutation advancing mixed-radix digit (radix block f) by k."""
    perm = []
    for i in range(n):
        g = (i // radix) % f
        j = i + ((g + k) % f - g) * radix
        perm.append((i, j))
    return perm


def hcps_shard_index(factors: Sequence[int]) -> list[int]:
    """Shard index held by each device after reduce_scatter_hcps.

    Stage i keys on mixed-radix digit i (LSB-first) of the device index, so
    device idx ends with shard whose MSB-first digits are (g_0, g_1, ...):
    a digit reversal. Returns shard_of_device[idx]."""
    n = math.prod(factors)
    out = []
    for idx in range(n):
        rem, s = idx, 0
        for f in factors:
            s = s * f + rem % f
            rem //= f
        out.append(s)
    return out


def reduce_scatter_hcps(x: jax.Array, axis_name: str,
                        factors: Sequence[int],
                        fused_reduce: Callable | None = None,
                        reorder: bool = False) -> jax.Array:
    n = lax.psum(1, axis_name)
    assert math.prod(factors) == n, (factors, n)
    idx = lax.axis_index(axis_name)
    cur = x.reshape(-1)
    radix = 1
    for f in factors:
        parts = cur.reshape((f, -1))
        g = (idx // radix) % f
        pieces = [_dyn_take(parts, g)]
        for k in range(1, f):
            # I send my copy of member (g+k)'s piece; by symmetry I receive
            # my own piece from member (g−k). The permutation is a digit
            # shift by +k within this stage's groups.
            piece = _dyn_take(parts, (g + k) % f)
            recv = lax.ppermute(piece, axis_name,
                                _digit_shift_perm(n, radix, f, k))
            pieces.append(recv)
        stacked = jnp.stack(pieces, axis=0)
        cur = fused_reduce(stacked) if fused_reduce is not None \
            else stacked.sum(axis=0)
        radix *= f
    if reorder:
        # move each shard to its natural owner (device i ↔ shard i)
        sidx = hcps_shard_index(factors)
        cur = lax.ppermute(cur, axis_name, [(i, sidx[i]) for i in range(n)])
    return cur


def all_gather_hcps(x: jax.Array, axis_name: str,
                    factors: Sequence[int]) -> jax.Array:
    n = lax.psum(1, axis_name)
    assert math.prod(factors) == n
    idx = lax.axis_index(axis_name)
    cur = x.reshape(-1)
    radix = n
    for f in reversed(factors):
        radix //= f
        g = (idx // radix) % f
        out = jnp.zeros((f,) + cur.shape, cur.dtype)
        out = _dyn_put(out, cur, g)
        for k in range(1, f):
            recv = lax.ppermute(cur, axis_name,
                                _digit_shift_perm(n, radix, f, k))
            out = _dyn_put(out, recv, (g - k) % f)
        cur = out.reshape(-1)
    return cur


# ---------------------------------------------------------------------------
# Composed AllReduce
# ---------------------------------------------------------------------------
def _pad_multiple(n: int, strategy: str) -> int:
    """Flat size must divide by this for the strategy's schedule: the axis
    size, except non-power-of-two RHD also halves down to the pow2 core."""
    if strategy == "rhd":
        pow2, extra = _rhd_pow2(n)
        if extra:
            return n * pow2 // math.gcd(n, pow2)
    return n


def allreduce(x: jax.Array, axis_name: str, strategy: str = "psum",
              factors: Sequence[int] | None = None,
              fused_reduce: Callable | None = None,
              schedule=None) -> jax.Array:
    """AllReduce a per-device array with the selected plan type.

    Pads to a multiple of the axis size; returns the same shape as x.
    strategy ∈ {psum, ring, rhd, cps, hcps, plan}; "plan" executes a
    `core.lower.CompiledSchedule` (a lowered GenTree plan) passed as
    `schedule`.
    """
    if strategy == "psum":
        return lax.psum(x, axis_name)
    if strategy == "plan":
        assert schedule is not None, "strategy='plan' needs a schedule"
        return schedule.allreduce(x, axis_name, fused_reduce=fused_reduce)
    n = lax.psum(1, axis_name)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.size) % _pad_multiple(n, strategy)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
    if strategy == "ring":
        shard = reduce_scatter_ring(flat, axis_name)
        full = all_gather_ring(shard, axis_name)
    elif strategy == "rhd":
        shard = reduce_scatter_rhd(flat, axis_name)
        full = all_gather_rhd(shard, axis_name)
    elif strategy == "cps":
        shard = reduce_scatter_cps(flat, axis_name, fused_reduce)
        full = all_gather_cps(shard, axis_name)
    elif strategy == "hcps":
        assert factors is not None, "hcps needs fan-in factors"
        shard = reduce_scatter_hcps(flat, axis_name, factors, fused_reduce)
        full = all_gather_hcps(shard, axis_name, factors)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    if pad:
        full = full[:-pad]
    return full.reshape(shape)


# one process-wide warning when allreduce_planned degrades to the flat
# plan-type labels (tests reset this to re-assert the warning fires)
_planned_fallback_warned = False


def allreduce_planned(x: jax.Array, axis_name: str, *,
                      service=None,
                      fused_reduce: Callable | None = None,
                      bucketing=None,
                      precision: str | None = None,
                      tolerance: float | None = None,
                      stats: dict | None = None) -> jax.Array:
    """AllReduce that executes the PlannerService's GenTree plan directly
    (cached, GenModel-priced — DESIGN.md §5/§8). The lookup + lowering
    happen at trace time (axis size and per-device shard size are static),
    so the compiled schedule's ppermute rounds are staged straight into
    the jitted computation; warm lookups are a cache probe, not a GenTree
    run.

    `bucketing` (a `core.bucketing.BucketConfig`) splits x into
    GenModel-sized buckets executed through the double-buffered RS/AG
    pipeline (DESIGN.md §9). `precision`/`tolerance` select the wire
    format (DESIGN.md §13): a pinned precision is resolved against the
    error budget (`cost_model.resolve_precision` — clamps to f32 when
    the tolerance disallows it); a tolerance alone runs the planner's
    priced precision argmin. On the bucketed path they override the
    config's own fields; on the direct path the schedule is bound via
    `with_wire`. Falls back to the flat plan-type labels only if the
    plan cannot be lowered (e.g. a legacy unannotated cache entry); the
    fallback ignores any bucketing config AND any compression (full
    precision), warns once per process, and records its reason in
    `stats` (pass a dict to receive `{"mode", "fallback_reason",
    "bucketing_ignored", ...}`). Like the plan lookup itself, `stats` is
    written at TRACE time — a dict passed into an already-jitted
    computation is never touched.
    """
    from repro.planner.service import default_service
    svc = service or default_service()
    if stats is None:
        stats = {}
    else:
        stats.clear()   # a reused dict must not mix keys across calls
    n = lax.psum(1, axis_name)        # static: psum of a python int
    if int(n) < 2:
        stats["mode"] = "noop"
        return x
    if (precision is not None or tolerance is not None) \
            and bucketing is not None:
        import dataclasses as _dc
        bucketing = _dc.replace(
            bucketing,
            precision=precision if precision is not None
            else bucketing.precision,
            tolerance=tolerance if tolerance is not None
            else bucketing.tolerance)
    from repro.core.lower import LoweringError
    reason = None
    try:
        if bucketing is not None and bucketing.enabled:
            from repro.core.bucketing import (Bucket, execute_buckets,
                                              supports_halves)
            bplan = svc.get_bucket_plan([(axis_name, int(n))],
                                        float(x.size), dtype=str(x.dtype),
                                        config=bucketing)
            # a single array has no leaf boundaries to bucket at — chunk
            # it into bucket-sized pieces (each its own bucket) so the
            # RS/AG pipeline overlaps
            bf = max(1, int(bplan.bucket_floats))
            flat = x.reshape(-1)
            pieces = [flat[off:off + bf]
                      for off in range(0, max(flat.size, 1), bf)]
            buckets = [Bucket(indices=(i,), sizes=(p.size,), dtype=p.dtype)
                       for i, p in enumerate(pieces) if p.size]
            out = execute_buckets(pieces, buckets, bplan.axis_plans,
                                  pipeline=bucketing.pipeline,
                                  fused_reduce=fused_reduce)
            # pipeline reports what actually ran: a schedule without
            # canonical RS/AG halves (or a single bucket) degrades to
            # sequential whole-plan allreduce per bucket
            halved = supports_halves(bplan.axis_plans)
            stats.update(mode="bucketed",
                         bucket_floats=bf, num_buckets=len(buckets),
                         halves=halved, precision=bplan.precision,
                         pipeline=bool(bucketing.pipeline and halved
                                       and len(buckets) > 1))
            return (out[0] if len(out) == 1
                    else jnp.concatenate(out)).reshape(x.shape)
        resp = svc.get_axis_executable(axis_name, int(n), float(x.size))
    except LoweringError as e:
        reason = f"plan could not be lowered: {e}"
        resp = None
    if resp is not None and resp.schedule is not None:
        from repro.core.cost_model import resolve_precision
        prec = None
        if precision is not None:
            prec = resolve_precision(precision, tolerance)
        elif tolerance is not None:
            # tolerance without a pin: reuse the planner's priced
            # precision argmin (monolithic single-bucket pin collapses
            # the size sweep; the result is cached like any bucket plan)
            from repro.core.bucketing import BucketConfig
            from repro.core.cost_model import PRECISIONS
            mono = BucketConfig(bucket_bytes=int(max(x.size, 1)) * 4,
                                tolerance=tolerance)
            sel = svc.get_bucket_plan([(axis_name, int(n))],
                                      float(x.size), dtype=str(x.dtype),
                                      config=mono)
            prec = PRECISIONS[sel.precision]
        sched = resp.schedule
        if prec is not None and prec.name != "f32":
            sched = sched.with_wire(prec)
        stats.update(mode="plan", algo=resp.algo, source=resp.source,
                     precision=prec.name if prec is not None else "f32")
        return sched.allreduce(x, axis_name, fused_reduce=fused_reduce)
    # ---- flat-label fallback ----------------------------------------------
    reason = reason or "service returned no executable schedule"
    stats.update(mode="flat-label", fallback_reason=reason,
                 bucketing_ignored=bucketing is not None
                 and bucketing.enabled)
    global _planned_fallback_warned
    if not _planned_fallback_warned:
        _planned_fallback_warned = True
        import warnings
        warnings.warn(
            "allreduce_planned fell back to flat plan-type labels "
            f"({reason})"
            + ("; the requested bucketing config is IGNORED on this path"
               if stats["bucketing_ignored"] else ""),
            RuntimeWarning, stacklevel=2)
    plans = svc.get_axis_plans([(axis_name, int(n))], float(x.size))
    if not plans:
        stats["mode"] = "psum"
        return lax.psum(x, axis_name)
    pl = plans[0]
    stats["strategy"] = pl.strategy
    return allreduce(x, axis_name, pl.strategy, factors=pl.factors,
                     fused_reduce=fused_reduce)


def reduce_scatter(x: jax.Array, axis_name: str, strategy: str = "psum",
                   factors: Sequence[int] | None = None,
                   fused_reduce: Callable | None = None,
                   schedule=None) -> jax.Array:
    """ReduceScatter with the selected plan type; x padded to axis multiple.

    Shape contract: every strategy returns the FLAT (chunk,) shard —
    device i holds slice i of the summed, padded vector. (The psum path
    once used `tiled=False` on the (n, chunk) reshape, which hands back a
    (1, chunk) slab instead of the flat shard the manual schedules
    return.) Non-power-of-two rhd shards over its pow2 core instead —
    devices beyond the core return an UNREDUCED slice of their own input
    (they sit out the halving, receiving zeros in every round); only
    composition with all_gather_rhd, whose fold-out overwrites them,
    yields a meaningful result there.
    """
    n = lax.psum(1, axis_name)
    if strategy == "plan":
        assert schedule is not None, "strategy='plan' needs a schedule"
        return schedule.reduce_scatter(x, axis_name,
                                       fused_reduce=fused_reduce)
    flat = x.reshape(-1)
    pad = (-flat.size) % _pad_multiple(n, strategy)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    if strategy == "psum":
        return lax.psum_scatter(flat, axis_name,
                                scatter_dimension=0, tiled=True)
    if strategy == "ring":
        return reduce_scatter_ring(flat, axis_name)
    if strategy == "rhd":
        return reduce_scatter_rhd(flat, axis_name)
    if strategy == "cps":
        return reduce_scatter_cps(flat, axis_name, fused_reduce)
    if strategy == "hcps":
        return reduce_scatter_hcps(flat, axis_name, factors, fused_reduce,
                                   reorder=True)
    raise ValueError(strategy)


def all_gather(x: jax.Array, axis_name: str, strategy: str = "psum",
               factors: Sequence[int] | None = None,
               schedule=None) -> jax.Array:
    """Inverse of `reduce_scatter` for the same strategy: gathers the
    per-device shard back into the full (padded) vector on every device.

    Shard-order contract: `reduce_scatter` returns NATURAL order (device
    i ↔ slice i) for every strategy — hcps re-orders its digit-reversed
    native holders on the way out (`reorder=True`). This dispatch
    therefore UN-reorders back to native holders before running the hcps
    doubling phase; calling `all_gather_hcps` directly on a
    `reduce_scatter(..., "hcps")` shard yields a block-permuted vector
    (the ZeRO-3 round-trip bug this dispatch exists to prevent).
    Non-power-of-two rhd composes through its own pow2-core convention
    (the fold-out overwrites the extras' placeholder shards)."""
    if strategy == "plan":
        assert schedule is not None, "strategy='plan' needs a schedule"
        return schedule.all_gather(x, axis_name)
    if strategy in ("psum", "auto"):
        return lax.all_gather(x.reshape(-1), axis_name, axis=0, tiled=True)
    if strategy == "ring":
        return all_gather_ring(x.reshape(-1), axis_name)
    if strategy == "rhd":
        return all_gather_rhd(x, axis_name)
    if strategy == "cps":
        return all_gather_cps(x.reshape(-1), axis_name)
    if strategy == "hcps":
        assert factors is not None, "hcps needs fan-in factors"
        n = int(lax.psum(1, axis_name))
        sidx = hcps_shard_index(factors)
        native = lax.ppermute(x.reshape(-1), axis_name,
                              [(sidx[i], i) for i in range(n)])
        return all_gather_hcps(native, axis_name, factors)
    raise ValueError(strategy)


def all_to_all(x: jax.Array, axis_name: str, schedule=None) -> jax.Array:
    """AllToAll over leading-dim chunks: device d's chunk j goes to device
    j as chunk d (the expert-parallel dispatch/combine primitive). x.size
    must divide by the axis size. With `schedule` (a lowered
    `core.lower.CompiledSchedule` of family "all_to_all") the exchange
    executes the plan's coalesced ppermute rounds; otherwise it is
    `lax.all_to_all`. Both paths return x's shape."""
    if schedule is not None:
        return schedule.all_to_all(x, axis_name)
    n = lax.psum(1, axis_name)
    parts = lax.all_to_all(x.reshape((n, -1)), axis_name,
                           split_axis=0, concat_axis=0)
    return parts.reshape(x.shape)
