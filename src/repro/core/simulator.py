"""Flow-level, incast-aware AllReduce simulator (paper §5.3).

Simulates a Plan IR over a tree topology. Per synchronized step:

    t_step = α_eff + max_link(bytes/bw + incast) + max_server(compute)

* every transfer is routed src→dst over tree links (full duplex: 'up' and
  'down' directions of an uplink are independent capacities);
* incast applies wherever distinct flows funnel into one link or endpoint
  beyond that level's threshold w_t:  extra = max(flows − w_t, 0)·bytes·ε;
* compute cost on each server uses the γ (adds) and δ (memory ops) terms;
* α_eff is the max per-round launch latency across the levels touched
  (cross-DC rounds pay the WAN α, paper Table 5).

Deterministic, no wall-clock dependence.

This module is the *reference oracle*: `simulate()` delegates to the
compiled engine (`core.simfast.FastEngine`, DESIGN.md §7) unless the
simulator is constructed with `engine="reference"` or
`$REPRO_SIM_ENGINE=reference` is set; `simulate_reference()` always runs
the pure-Python path. The two must agree within 1e-9 on every SimResult
field (tests/test_simfast.py).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

from .cost_model import GenModelParams, PAPER_TABLE5
from .plans import Plan
from .topology import TopoNode


@dataclass
class SimResult:
    total: float
    per_step: list[float] = field(default_factory=list)
    comm: float = 0.0
    compute: float = 0.0
    latency: float = 0.0
    incast_extra: float = 0.0


class Simulator:
    def __init__(self, topo: TopoNode,
                 params: dict[str, GenModelParams] | None = None,
                 unit_bytes: int = 4, engine: str | None = None):
        self.topo = topo
        self.params = params or PAPER_TABLE5
        self.unit = unit_bytes
        self._srv = {s._sid: s for s in topo.servers()}
        self.engine = (engine or os.environ.get("REPRO_SIM_ENGINE")
                       or "fast")
        if self.engine not in ("fast", "reference"):
            raise ValueError(f"unknown sim engine {self.engine!r}")
        self._fast = None

    def _p(self, level: str) -> GenModelParams:
        return self.params.get(level, self.params["server"])

    def fast_engine(self):
        """The shared compiled engine for this (topo, params, unit)."""
        if self._fast is None:
            from .simfast import FastEngine
            self._fast = FastEngine(self.topo, self.params, self.unit)
        return self._fast

    def simulate(self, plan: Plan) -> SimResult:
        if self.engine == "fast":
            return self.fast_engine().simulate(plan)
        return self.simulate_reference(plan)

    def simulate_reference(self, plan: Plan) -> SimResult:
        res = SimResult(total=0.0)
        for st in plan.steps:
            # ---- route flows onto links ----------------------------------
            link_bytes: dict[tuple[int, str], float] = {}
            link_flows: dict[tuple[int, str], set] = {}
            link_node: dict[tuple[int, str], TopoNode] = {}
            # All sizes below stay in data units (floats); GenModel params
            # are per-float; link bandwidths are bytes/s.
            scale = self.unit / 4.0  # rescale per-float params if unit != 4B
            for t in st.transfers:
                src, dst = self._srv[t.src], self._srv[t.dst]
                for node, dirn in self.topo.path_links(src, dst):
                    key = (id(node), dirn)
                    link_bytes[key] = link_bytes.get(key, 0.0) + t.size
                    link_flows.setdefault(key, set()).add((t.src, t.dst))
                    link_node[key] = node

            comm = 0.0
            incast_extra = 0.0
            alpha_eff = self._p("server").alpha if st.transfers else 0.0
            for key, units in link_bytes.items():
                node = link_node[key]
                lvl = node.parent.level if node.parent is not None else node.level
                p = self._p(lvl)
                base = units * self.unit / max(node.uplink_bw, 1e-30) \
                    if node.uplink_bw else 0.0
                # incast at this link: distinct SENDERS converging on it
                # (many-to-one is what triggers PFC pause storms; fan-out
                # from one sender does not). The paper's data rearrangement
                # wins exactly by shrinking this count on the WAN link.
                nflow = len({f[0] for f in link_flows[key]})
                extra = max(nflow - p.w_t, 0) * units * scale * p.epsilon
                incast_extra += extra
                comm = max(comm, base + extra + node.uplink_latency)
                alpha_eff = max(alpha_eff, p.alpha)
            # endpoint incast at receiving server NICs — priced with the
            # parent switch's ε (paper attributes incast to the fabric level)
            psrv = self._p("server")
            fi = st.fan_in_by_dst()
            for dst, units in st.recv_bytes_by_dst().items():
                srv = self._srv[dst]
                plvl = self._p(srv.parent.level if srv.parent else "root_sw")
                w = fi.get(dst, 0) + 1  # paper counts the receiver's own block
                extra = max(w - plvl.w_t, 0) * units * scale * plvl.epsilon
                incast_extra += extra
                nic = srv.uplink_bw
                t_nic = units * self.unit / max(nic, 1e-30) if nic else 0.0
                comm = max(comm, t_nic + extra)

            # ---- compute --------------------------------------------------
            comp = 0.0
            by_srv: dict[int, tuple[float, float]] = {}
            for r in st.reduces:
                a, d = by_srv.get(r.server, (0.0, 0.0))
                by_srv[r.server] = (a + r.adds, d + r.mem_ops)
            for a, d in by_srv.values():
                comp = max(comp, (a * psrv.gamma + d * psrv.delta) * scale)
            if st.reduces and not st.transfers:
                alpha_eff = max(alpha_eff, psrv.alpha)

            t_step = alpha_eff + comm + comp
            res.per_step.append(t_step)
            res.total += t_step
            res.comm += comm
            res.compute += comp
            res.latency += alpha_eff
            res.incast_extra += incast_extra
        return res
