"""GenModel — the paper's AllReduce time-cost model (§3).

    T = A·α + B·β + C·γ + D·δ + max(w − w_t, 0)·B·ε      (Eq. 11)

Closed forms for the classic plan types (Table 2) plus a generic evaluator
that walks a Plan IR step by step. The generic evaluator agrees with the
closed forms on single-switch networks (property-tested).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

from .plans import Plan, factorizations


# ---------------------------------------------------------------------------
# Wire precision — compression priced honestly (DESIGN.md §13).
#
# The paper's own argument makes compression a first-class lever: β·S and
# the incast term scale with the bytes actually on the wire, while the
# quantize/dequantize passes are extra γ/δ work (§3.1's memory-access
# accounting). A Precision describes one wire format; the evaluators below
# accept it and reprice every term, so the planner can argmin over
# {f32, bf16, fp8, int8} with the same model it uses for plan shape.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Precision:
    """One wire format for collective payloads.

    `bits` is the payload width per element; `scale_block` elements share
    one f32 scale (0 = scale-free cast, e.g. bf16); `quant_passes` counts
    the extra quantize/dequantize memory passes per hop that γ/δ must pick
    up; `error_budget` is the relative error a sync through this format
    may introduce (0.0 = lossless — bit-identical to the f32 path)."""
    name: str
    wire_dtype: str            # jnp dtype name of the payload
    bits: int                  # wire bits per element
    scale_block: int = 0       # elements per f32 scale (0: none)
    quant_passes: int = 0      # extra quant/dequant memory passes per hop
    error_budget: float = 0.0  # max relative error per sync (0: lossless)

    @property
    def lossless(self) -> bool:
        return self.error_budget == 0.0

    @property
    def bytes_per_elem(self) -> float:
        """Payload bytes per element, scales included."""
        return self.bits / 8.0 + (4.0 / self.scale_block
                                  if self.scale_block else 0.0)

    def comm_scale(self) -> float:
        """Multiplier on wire volume in f32 data units: β·S and the incast
        receive both shrink (or hold) by this factor."""
        return self.bytes_per_elem / 4.0

    def wire_bytes(self, n_elems: int) -> int:
        """Exact wire bytes for an n-element payload: packed values plus
        one f32 scale per (partial) scale block."""
        n_elems = int(n_elems)
        payload = (n_elems * self.bits + 7) // 8
        scales = (4 * ((n_elems + self.scale_block - 1) // self.scale_block)
                  if self.scale_block and n_elems else 0)
        return payload + scales

    def extra_adds(self, size: float) -> float:
        """γ ops of the quant passes (abs-max scan + scale multiply —
        one pass-equivalent of adds per element per pass)."""
        return self.quant_passes * size

    def extra_mem_ops(self, size: float) -> float:
        """δ ops of the quant passes: each pass reads the f32 copy and
        writes the compressed one (or vice versa), so a pass touches
        (1 + bits/32) f32-unit-equivalents per element."""
        return self.quant_passes * size * (1.0 + self.bits / 32.0)


# The four wire formats the planner sweeps. Budgets are per-sync relative
# error bounds (validated by tests/test_quant.py and the 8-device
# differential fuzz): quantization error per hop is ≲ half an ulp of the
# per-tile amax, accumulated over the RS fold and the AG requant hop.
PRECISIONS = {
    "f32":  Precision("f32", "float32", 32),
    "bf16": Precision("bf16", "bfloat16", 16, scale_block=0,
                      quant_passes=1, error_budget=0.02),
    "fp8":  Precision("fp8", "float8_e4m3fn", 8, scale_block=128,
                      quant_passes=2, error_budget=0.25),
    "int8": Precision("int8", "int8", 8, scale_block=128,
                      quant_passes=2, error_budget=0.08),
}


def resolve_precision(precision: "Precision | str | None",
                      tolerance: float | None = None) -> Precision:
    """The error-budget guard (DESIGN.md §13): map a requested precision +
    caller tolerance onto the wire format actually allowed to run.

    `tolerance=None` means "trust the explicit request": a caller pinning
    fp8 by name has opted into fp8's budget. A float tolerance is a hard
    bound — a pinned precision whose budget exceeds it CLAMPS to full
    precision (lossy sync disallowed), never errors. `precision=None`
    returns f32."""
    if precision is None:
        return PRECISIONS["f32"]
    prec = PRECISIONS[precision] if isinstance(precision, str) else precision
    if tolerance is not None and prec.error_budget > float(tolerance):
        return PRECISIONS["f32"]
    return prec


def allowed_precisions(tolerance: float | None) -> list[Precision]:
    """Sweep candidates under a caller tolerance: every registered format
    whose error budget fits. None (no lossy consent) → lossless only."""
    tol = 0.0 if tolerance is None else float(tolerance)
    return [p for p in PRECISIONS.values() if p.error_budget <= tol]


@dataclass(frozen=True)
class GenModelParams:
    """Defaults = the paper's CPU testbed (15 servers on a 10 Gbps ToR):
    α/γ/δ from the server row of Table 5, β/ε from the middle-switch row
    (the ToR is a middle-layer switch in the paper's level classes)."""
    alpha: float = 6.58e-3      # s per communication round
    beta: float = 6.4e-9        # s per data unit through a link
    gamma: float = 6.0e-10      # s per add
    delta: float = 1.87e-10     # s per memory read/write
    epsilon: float = 1.22e-10   # s per data unit of incast excess
    w_t: int = 9                # incast fan-in threshold

    def legacy(self) -> "GenModelParams":
        """The (α, β, γ) model: δ = ε = 0 (for accuracy comparisons)."""
        return replace(self, delta=0.0, epsilon=0.0)


# Paper Table 5 per-level parameters (units: seconds, floats).
PAPER_TABLE5 = {
    "cross_dc":  GenModelParams(alpha=3.00e-2, beta=6.40e-9,
                                epsilon=6.00e-11, w_t=9),
    "root_sw":   GenModelParams(alpha=6.58e-3, beta=6.40e-10,
                                epsilon=6.00e-12, w_t=9),
    "middle_sw": GenModelParams(alpha=6.58e-3, beta=6.40e-9,
                                epsilon=1.22e-10, w_t=9),
    "server":    GenModelParams(alpha=6.58e-3, gamma=6.00e-10,
                                delta=1.87e-10, w_t=7),
}

# TPU v5e-flavoured parameters (DESIGN.md §3): units seconds / bytes.
TPU_V5E = {
    # inter-pod DCI: ~25 GB/s, higher launch latency
    "cross_dc":  GenModelParams(alpha=1.0e-5, beta=1 / 25e9,
                                epsilon=4.0e-12, w_t=4),
    # pod-level ICI fabric ~50 GB/s per link
    "root_sw":   GenModelParams(alpha=1.0e-6, beta=1 / 50e9,
                                epsilon=2.0e-12, w_t=6),
    "middle_sw": GenModelParams(alpha=1.0e-6, beta=1 / 50e9,
                                epsilon=2.0e-12, w_t=6),
    # chip: HBM 819 GB/s → δ per byte; VPU adds
    "server":    GenModelParams(alpha=1.0e-6, gamma=1 / 4e12,
                                delta=1 / 819e9, w_t=6),
}


def chi(n: int) -> int:
    """χ(N) = 0 if N is a power of two, else 1 (Table 1/2)."""
    return 0 if (n & (n - 1)) == 0 else 1


def _incast(fan_in: int, recv: float, p: GenModelParams) -> float:
    return max(fan_in - p.w_t, 0) * recv * p.epsilon


# ---------------------------------------------------------------------------
# Closed forms (paper Table 2), single-switch, N servers, S data units.
# ---------------------------------------------------------------------------
def cost_reduce_broadcast(n: int, s: float, p: GenModelParams) -> float:
    return (2 * p.alpha + 2 * (n - 1) * s * p.beta + (n - 1) * s * p.gamma
            + (n + 1) * s * p.delta
            + max(n - p.w_t, 0) * (n - 1) * s * p.epsilon)


def cost_ring(n: int, s: float, p: GenModelParams) -> float:
    return (2 * (n - 1) * p.alpha + 2 * (n - 1) * s / n * p.beta
            + (n - 1) * s / n * p.gamma + 3 * (n - 1) * s / n * p.delta)


def cost_rhd(n: int, s: float, p: GenModelParams) -> float:
    base = (2 * math.ceil(math.log2(n)) * p.alpha
            + 2 * (n - 1) * s / n * p.beta + (n - 1) * s / n * p.gamma
            + 3 * (n - 1) * s / n * p.delta)
    return base + chi(n) * (2 * s * p.beta + s * p.gamma + 3 * s * p.delta)


def cost_cps(n: int, s: float, p: GenModelParams) -> float:
    return (2 * p.alpha + 2 * (n - 1) * s / n * p.beta
            + (n - 1) * s / n * p.gamma + (n + 1) * s / n * p.delta
            + 2 * (n - 1) * s / n * max(n - p.w_t, 0) * p.epsilon)


def cost_hcps(factors: list[int], s: float, p: GenModelParams) -> float:
    """m-step hierarchical CPS (Table 2 row 5).

    Memory term: step i reduces f_i blocks of size s/(prod_{j<=i} f_j) on
    each server → D_i = (f_i + 1) * s / prod_{j<=i} f_j; total matches the
    paper's (2*sum(prod f) + N + 1)/N form.
    Incast term: per-step fan-in f_i over the data received that step.
    """
    n = 1
    for f in factors:
        n *= f
    m = len(factors)
    t = 2 * m * p.alpha
    t += 2 * (n - 1) * s / n * p.beta
    t += (n - 1) * s / n * p.gamma
    shard = s
    for f in factors:
        blk = shard / f
        t += (f + 1) * blk * p.delta                      # δ of this stage
        t += _incast(f, (f - 1) * blk, p)                 # ε of this stage
        shard = blk
    return t


CLOSED_FORMS = {
    "reduce_broadcast": cost_reduce_broadcast,
    "ring": cost_ring,
    "rhd": cost_rhd,
    "cps": cost_cps,
}


# ---------------------------------------------------------------------------
# Generic IR evaluator (single-switch assumption: every transfer shares the
# per-server NIC; per-step time = α + max-per-server comm + max compute).
# ---------------------------------------------------------------------------
def compressed_plan(plan: Plan, precision: Precision | None) -> Plan:
    """The same plan repriced for a compressed wire: every transfer shrinks
    to its wire volume (comm_scale × f32 units) and every reduce picks up
    the quant/dequant passes as extra γ adds and δ mem_ops. Any pricer
    (reference Simulator, FastEngine, the evaluators here) then charges
    compression with zero changes to its own walk — the transform IS the
    pricing model of DESIGN.md §13."""
    if precision is None or precision.name == "f32":
        return plan
    from .plans import QuantReduceOp, Step
    cs = precision.comm_scale()
    steps = []
    for st in plan.steps:
        s = Step()
        s.transfers = [replace(t, size=t.size * cs) for t in st.transfers]
        s.reduces = [QuantReduceOp(
            server=r.server, fan_in=r.fan_in, size=r.size, blocks=r.blocks,
            extra_adds=precision.extra_adds(r.size),
            extra_mem_ops=precision.extra_mem_ops(r.size))
            for r in st.reduces]
        steps.append(s)
    return Plan(plan.name, plan.n, plan.size, steps=steps,
                servers=plan.servers, num_blocks=plan.num_blocks,
                family=plan.family)


# Per-device wire volume of each collective family, as a multiple of the
# payload M (DESIGN.md §14). THE wire-byte convention: the planner's
# per-family plans move exactly these bytes, and `launch.hlo_analysis`
# books the same so an HLO-extracted mix is not systematically
# overpriced vs the plans quoted for it. Payload M per family:
#   all-reduce / reduce-scatter / all-to-all — the per-device operand;
#   all-gather                              — the full result;
#   collective-permute (p2p)                — the buffer moved per edge.
def family_wire_bytes(family: str, n: int, payload: float) -> float:
    """Wire units each device moves for `payload` units of family
    `family` over an n-member group (n ≤ 1 ⇒ nothing moves)."""
    if n <= 1:
        return 0.0
    if family in ("all-reduce", "allreduce"):
        return 2.0 * (n - 1) / n * payload      # RS + AG halves
    if family in ("reduce-scatter", "reduce_scatter",
                  "all-gather", "allgather",
                  "all-to-all", "all_to_all", "alltoall"):
        return (n - 1) / n * payload
    if family in ("collective-permute", "p2p"):
        return float(payload)
    raise ValueError(f"unknown collective family {family!r}")


def evaluate_plan(plan: Plan, p: GenModelParams,
                  precision: Precision | None = None) -> float:
    cs = precision.comm_scale() if precision is not None else 1.0
    total = 0.0
    for st in plan.steps:
        send: dict[int, float] = {}
        for t in st.transfers:
            send[t.src] = send.get(t.src, 0.0) + t.size * cs
        recv = st.recv_bytes_by_dst()
        fi = st.fan_in_by_dst()
        comm = 0.0
        for srv in set(send) | set(recv):
            b = max(send.get(srv, 0.0), recv.get(srv, 0.0) * cs)
            w = fi.get(srv, 0) + 1 if srv in fi else 0  # w counts self
            c = b * p.beta + _incast(w, recv.get(srv, 0.0) * cs, p)
            comm = max(comm, c)
        comp = 0.0
        by_srv: dict[int, tuple[float, float]] = {}
        for r in st.reduces:
            a, d = by_srv.get(r.server, (0.0, 0.0))
            qa = precision.extra_adds(r.size) if precision else 0.0
            qd = precision.extra_mem_ops(r.size) if precision else 0.0
            by_srv[r.server] = (a + r.adds + qa, d + r.mem_ops + qd)
        for a, d in by_srv.values():
            comp = max(comp, a * p.gamma + d * p.delta)
        total += p.alpha + comm + comp
    return total


# ---------------------------------------------------------------------------
# Link-contention pricing of concurrent rounds (DESIGN.md §15).
#
# The bucket pipeline overlaps RS-of-bucket-k with AG-of-bucket-(k-1); the
# naive steady-state model `max(t_rs, t_ag)` assumes the two rounds never
# share a link. On multi-level meshes they do — and GenModel says exactly
# how that hurts: transfers sharing a link serialize their β volume, and
# their incast fan-ins SUM at the shared endpoint (ε is superadditive past
# w_t). A `LinkOccupancy` is one round's footprint on the routing index's
# dense link ids; merging two occupancies and repricing with the same
# per-step walk gives the *contended* concurrent time:
#
#   max(t_a, t_b)  ≤  t_contended   (disjoint links ⇒ equality)
#   t_contended may EXCEED t_a + t_b when summed fan-in crosses w_t —
#   which is precisely when the planner must not merge.
#
# This is the pure-Python reference path; `FastEngine.merge_steps` is the
# vectorized twin and must agree ≤ 1e-9 (tests/test_overlap.py).
# ---------------------------------------------------------------------------
@dataclass
class LinkOccupancy:
    """One Step's footprint on a topology: per-link data units and distinct
    sender counts (keyed by dense RoutingIndex link id), per-endpoint
    receive units and fan-in, per-server reduce work."""
    link_units: dict
    link_nsend: dict
    recv_units: dict
    recv_fan: dict
    adds: dict
    mem: dict
    has_transfers: bool
    has_reduces: bool

    def merge(self, other: "LinkOccupancy") -> "LinkOccupancy":
        """Two rounds run concurrently: shared links serialize (units add),
        incast fan-ins sum, reduce work on a shared server queues."""
        def _sum(a, b):
            out = dict(a)
            for k, v in b.items():
                out[k] = out.get(k, 0) + v
            return out
        return LinkOccupancy(
            link_units=_sum(self.link_units, other.link_units),
            link_nsend=_sum(self.link_nsend, other.link_nsend),
            recv_units=_sum(self.recv_units, other.recv_units),
            recv_fan=_sum(self.recv_fan, other.recv_fan),
            adds=_sum(self.adds, other.adds),
            mem=_sum(self.mem, other.mem),
            has_transfers=self.has_transfers or other.has_transfers,
            has_reduces=self.has_reduces or other.has_reduces)


def link_occupancy(topo, step, unit_bytes: int = 4) -> LinkOccupancy:
    """Walk one Step's transfers over `topo.routing().path_link_ids` and
    accumulate the occupancy vector (pure Python — the reference path)."""
    rx = topo.routing()
    link_units: dict = {}
    link_senders: dict = {}
    recv_units: dict = {}
    recv_senders: dict = {}
    for t in step.transfers:
        for lid in rx.path_link_ids(t.src, t.dst):
            link_units[lid] = link_units.get(lid, 0.0) + t.size
            link_senders.setdefault(lid, set()).add(t.src)
        recv_units[t.dst] = recv_units.get(t.dst, 0.0) + t.size
        recv_senders.setdefault(t.dst, set()).add(t.src)
    adds: dict = {}
    mem: dict = {}
    for r in step.reduces:
        adds[r.server] = adds.get(r.server, 0.0) + r.adds
        mem[r.server] = mem.get(r.server, 0.0) + r.mem_ops
    return LinkOccupancy(
        link_units=link_units,
        link_nsend={k: len(v) for k, v in link_senders.items()},
        recv_units=recv_units,
        recv_fan={k: len(v) for k, v in recv_senders.items()},
        adds=adds, mem=mem,
        has_transfers=bool(step.transfers),
        has_reduces=bool(step.reduces))


def occupancy_time(topo, occ: LinkOccupancy,
                   params: "dict[str, GenModelParams] | None" = None,
                   unit_bytes: int = 4) -> float:
    """GenModel step time of one (possibly merged) occupancy vector —
    the same accounting as `FastEngine.step_cost`, dict-walked."""
    rx = topo.routing()
    tbl = params or PAPER_TABLE5
    psrv = tbl.get("server", GenModelParams())
    scale = unit_bytes / 4.0
    comm = 0.0
    alpha_eff = psrv.alpha if occ.has_transfers else 0.0
    for lid, units in occ.link_units.items():
        nid = lid >> 1            # both directed links share the node's bw
        p = tbl.get(rx.levels[rx.link_level[nid]], psrv)
        bw = rx.link_bw[nid]
        tpb = unit_bytes / bw if bw != 0.0 else 0.0
        extra = (max(occ.link_nsend.get(lid, 0) - p.w_t, 0)
                 * units * scale * p.epsilon)
        comm = max(comm, units * tpb + extra + rx.link_latency[nid])
        alpha_eff = max(alpha_eff, p.alpha)
    for dst, units in occ.recv_units.items():
        p = tbl.get(rx.levels[rx.srv_level[dst]], psrv)
        bw = rx.srv_bw[dst]
        tpb = unit_bytes / bw if bw != 0.0 else 0.0
        w = occ.recv_fan.get(dst, 0) + 1
        extra = max(w - p.w_t, 0) * units * scale * p.epsilon
        comm = max(comm, units * tpb + extra)
    comp = 0.0
    for srv in occ.adds.keys() | occ.mem.keys():
        comp = max(comp, (occ.adds.get(srv, 0.0) * psrv.gamma
                          + occ.mem.get(srv, 0.0) * psrv.delta) * scale)
    if occ.has_reduces and not occ.has_transfers:
        alpha_eff = max(alpha_eff, psrv.alpha)
    return alpha_eff + comm + comp


def concurrent_step_time(topo, steps,
                         params: "dict[str, GenModelParams] | None" = None,
                         unit_bytes: int = 4) -> float:
    """Contended time of ≥1 Steps running concurrently: merge their
    occupancy vectors and reprice. One step degenerates to its plain
    GenModel step cost."""
    occs = [link_occupancy(topo, st, unit_bytes) for st in steps if st]
    if not occs:
        return 0.0
    occ = occs[0]
    for other in occs[1:]:
        occ = occ.merge(other)
    return occupancy_time(topo, occ, params, unit_bytes)


def contended_pair_time(topo, plan_a: Plan, plan_b: Plan,
                        params: "dict[str, GenModelParams] | None" = None,
                        unit_bytes: int = 4,
                        precision: "Precision | None" = None) -> float:
    """Price plan A's rounds run concurrently with plan B's, round by
    round: round i of A merges with round i of B (shared links serialize,
    fan-ins sum); leftover rounds of the longer plan price alone. This is
    the reference contended estimate for the bucket pipeline's steady
    state (RS-of-bucket-k over AG-of-bucket-(k-1)) and for cross-family
    merges; `FastEngine.contended_pair_total` must agree ≤ 1e-9."""
    if precision is not None and precision.name != "f32":
        plan_a = compressed_plan(plan_a, precision)
        plan_b = compressed_plan(plan_b, precision)
    total = 0.0
    for i in range(max(len(plan_a.steps), len(plan_b.steps))):
        parts = []
        if i < len(plan_a.steps):
            parts.append(plan_a.steps[i])
        if i < len(plan_b.steps):
            parts.append(plan_b.steps[i])
        total += concurrent_step_time(topo, parts, params, unit_bytes)
    return total


# ---------------------------------------------------------------------------
# Per-term decomposition — the cost ledger's pricing side (DESIGN.md §11).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CostBreakdown:
    """Predicted time split into the five GenModel terms (Eq. 11):
    A·α + B·β + C·γ + D·δ + incast·ε.  ``total`` reproduces
    ``evaluate_plan`` exactly (same walk, same maxes — the winning
    server's split is attributed, not an average)."""
    alpha: float = 0.0
    beta: float = 0.0
    gamma: float = 0.0
    delta: float = 0.0
    incast: float = 0.0

    TERMS = ("alpha", "beta", "gamma", "delta", "incast")

    @property
    def total(self) -> float:
        return self.alpha + self.beta + self.gamma + self.delta + self.incast

    def as_dict(self) -> dict[str, float]:
        return {t: getattr(self, t) for t in self.TERMS}

    def shares(self) -> dict[str, float]:
        """Fractions of total per term (all-zero breakdown → zeros)."""
        tot = self.total
        if tot <= 0.0:
            return {t: 0.0 for t in self.TERMS}
        return {t: getattr(self, t) / tot for t in self.TERMS}

    def scaled_to(self, target_total: float) -> "CostBreakdown":
        """Rescale proportionally so ``total == target_total`` (used when a
        quoted prediction came from a different pricer — e.g. the
        Simulator's halves split — but term *proportions* come from the
        model walk).  A zero breakdown books everything under α."""
        tot = self.total
        if tot <= 0.0:
            return CostBreakdown(alpha=target_total)
        k = target_total / tot
        return CostBreakdown(self.alpha * k, self.beta * k, self.gamma * k,
                             self.delta * k, self.incast * k)


def evaluate_plan_terms(plan: Plan, p: GenModelParams,
                        precision: Precision | None = None) -> CostBreakdown:
    """``evaluate_plan`` with the ledger kept open: identical step walk and
    identical per-server maxes, but each step's winning comm/compute server
    contributes its β/ε (resp. γ/δ) split instead of a fused scalar. With a
    `precision`, the quant passes land in the γ/δ entries and the shrunk
    wire in β/ε — so PR 6's per-term drift attribution keeps working on
    compressed syncs."""
    cs = precision.comm_scale() if precision is not None else 1.0
    al = be = ga = de = inc = 0.0
    for st in plan.steps:
        send: dict[int, float] = {}
        for t in st.transfers:
            send[t.src] = send.get(t.src, 0.0) + t.size * cs
        recv = st.recv_bytes_by_dst()
        fi = st.fan_in_by_dst()
        comm = comm_b = comm_i = 0.0
        for srv in set(send) | set(recv):
            b = max(send.get(srv, 0.0), recv.get(srv, 0.0) * cs)
            w = fi.get(srv, 0) + 1 if srv in fi else 0  # w counts self
            b_term = b * p.beta
            i_term = _incast(w, recv.get(srv, 0.0) * cs, p)
            if b_term + i_term > comm:
                comm, comm_b, comm_i = b_term + i_term, b_term, i_term
        comp = comp_g = comp_d = 0.0
        by_srv: dict[int, tuple[float, float]] = {}
        for r in st.reduces:
            a, d = by_srv.get(r.server, (0.0, 0.0))
            qa = precision.extra_adds(r.size) if precision else 0.0
            qd = precision.extra_mem_ops(r.size) if precision else 0.0
            by_srv[r.server] = (a + r.adds + qa, d + r.mem_ops + qd)
        for a, d in by_srv.values():
            g_term, d_term = a * p.gamma, d * p.delta
            if g_term + d_term > comp:
                comp, comp_g, comp_d = g_term + d_term, g_term, d_term
        al += p.alpha
        be += comm_b
        inc += comm_i
        ga += comp_g
        de += comp_d
    return CostBreakdown(al, be, ga, de, inc)


# ---------------------------------------------------------------------------
# Model-driven plan-type choice for a flat group (used by GenTree §4.2).
# ---------------------------------------------------------------------------
def best_flat_plan(n: int, s: float, p: GenModelParams,
                   allow: tuple[str, ...] = ("cps", "hcps", "ring", "rhd"),
                   max_steps: int = 3) -> tuple[str, list[int] | None, float]:
    """Returns (name, hcps_factors_or_None, predicted_cost)."""
    cands: list[tuple[str, list[int] | None, float]] = []
    if "cps" in allow:
        cands.append(("cps", None, cost_cps(n, s, p)))
    if "ring" in allow and n >= 2:
        cands.append(("ring", None, cost_ring(n, s, p)))
    if "rhd" in allow and n >= 2:
        cands.append(("rhd", None, cost_rhd(n, s, p)))
    if "hcps" in allow:
        for fac in factorizations(n, max_steps=max_steps):
            cands.append(("hcps", fac, cost_hcps(fac, s, p)))
    # Deterministic tie-break: equal-cost candidates order by name, then
    # factors, so plan choice is stable across runs and platforms.
    cands.sort(key=lambda x: (x[2], x[0], tuple(x[1] or ())))
    return cands[0]
