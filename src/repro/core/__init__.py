"""Core: the paper's contribution — GenModel, GenTree, simulator, executor."""
from . import cost_model, fitting, gentree, optimality, plans, simulator, topology  # noqa: F401
